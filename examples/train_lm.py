"""End-to-end training driver example: a ~100M-param qwen3-family model
for a few hundred steps through the real production stack (config →
data pipeline → sharded train step → checkpointing).

Default invocation is CPU-sized (~25M params, 200 steps):
  PYTHONPATH=src python examples/train_lm.py
Full 100M:
  PYTHONPATH=src python examples/train_lm.py --hundred-m --steps 300
"""
import argparse
import dataclasses
import sys

from repro import configs
from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--hundred-m", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    base = configs.get_config("qwen3-8b")
    if args.hundred_m:
        cfg = dataclasses.replace(
            base, name="qwen3-100m", n_layers=16, d_model=640, n_heads=10,
            n_kv_heads=2, head_dim=64, d_ff=2560, vocab=16384)
    else:
        cfg = dataclasses.replace(
            base, name="qwen3-25m", n_layers=8, d_model=384, n_heads=6,
            n_kv_heads=2, head_dim=64, d_ff=1536, vocab=8192)
    pc = cfg.param_counts()
    print(f"model: {cfg.name} ({pc['total']/1e6:.1f}M params)")

    # Register the reduced config on the fly and drive the real launcher.
    import repro.configs as C
    mod_name = "examples_dynamic"
    import types
    m = types.ModuleType(mod_name)
    m.CONFIG = cfg
    sys.modules[f"repro.configs.{mod_name}"] = m
    C.ARCHS[cfg.name] = mod_name

    losses = train_mod.main([
        "--arch", cfg.name, "--steps", str(args.steps), "--batch", "8",
        "--seq", "256", "--lr", "6e-4", "--log-every", "20",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
    ])
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} over {args.steps} steps")


if __name__ == "__main__":
    main()

"""Quickstart: the `repro.ddc` estimator API on a Chameleon-like dataset.

The canonical snippet — one config, one facade, any backend:

    from repro.ddc import DDC, DDCConfig

    cfg = DDCConfig(eps=0.022, min_pts=4, backend="stream", shards=8,
                    ...).validate(sample=pts)     # DESIGN §7 sizing probe
    model = DDC(cfg).fit(pts)                     # phase 1 + phase 2
    model.labels_                                 # global cluster ids
    res = model.query(probes)                     # QueryResult (§12):
    res.labels, res.version, res.degraded         #   still duck-types as
    np.asarray(res)                               #   the labels ndarray
    model.query_tier.submit(probes); model.query_tier.drain()
    model.stats()                                 # typed ServiceStats
    model.partial_fit(shard, batch, t=now)        # streaming writes
    model.expire(now - window)                    # TTL eviction
    model.save(path); DDC.load(path)              # bit-identical resume

``--backend host`` is the paper-faithful NumPy oracle, ``jit`` the
shard_map collective pipeline (sync/async/tree schedules), ``stream``
the incremental delta-merge serve engine, ``dist`` the same engine with
per-shard buffers pinned to their own mesh devices (real axis-crossing
delta bytes).  All four produce the same global clustering.

  PYTHONPATH=src python examples/quickstart.py --backend host
  PYTHONPATH=src python examples/quickstart.py --backend jit --shards 8
  PYTHONPATH=src python examples/quickstart.py --backend stream
  PYTHONPATH=src python examples/quickstart.py --backend dist --shards 8
"""
import argparse
import os
import tempfile

ap = argparse.ArgumentParser()
ap.add_argument("--backend", choices=("host", "jit", "stream", "dist"),
                default="host")
ap.add_argument("--shards", type=int, default=8)
ap.add_argument("--n", type=int, default=6000)
args = ap.parse_args()

if args.backend in ("jit", "dist"):
    # These backends lay shards over jax devices; the CPU device count
    # must be pinned before jax initialises.
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={args.shards}"
    ).strip()

import numpy as np

from repro.core import dbscan, partitioner, simulate as sim
from repro.data import spatial
from repro.ddc import DDC, DDCConfig


def ascii_plot(pts, labels, width=72, height=24):
    chars = "ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghij"
    grid = [[" "] * width for _ in range(height)]
    for (x, y), l in zip(pts, labels):
        c = "." if l < 0 else chars[l % len(chars)]
        grid[int((1 - y) * (height - 1))][int(x * (width - 1))] = c
    return "\n".join("".join(row) for row in grid)


def main():
    n, k = args.n, args.shards
    pts = spatial.make_d1(n, seed=0, noise_frac=0.02)

    # One validated config drives every deployment style.  validate()
    # rejects backend/schedule mismatches and (with a sample) configs
    # whose merged contours would overflow the vertex budget (DESIGN §7).
    cfg = DDCConfig(
        eps=0.022, min_pts=4, grid=96, max_clusters=24, max_verts=320,
        backend=args.backend, shards=k,
    ).validate(sample=pts[::2])

    print(f"== DDC on D1-like dataset (n={n}, backend={cfg.backend}, "
          f"{k} shards) ==")
    # t=0.0 stamps the batch for TTL eviction (stream backend; ignored
    # by the batch backends) so later wall-clock expire() cutoffs and
    # the fitted data share one clock.
    model = DDC(cfg).fit(pts, t=0.0)
    glabels = model.labels_
    print(f"global clusters: {model.n_clusters_}   "
          f"noise: {(glabels < 0).sum()}")

    stats = model.comm_stats()
    if cfg.backend == "host":
        # The host oracle ships raw contour vertices: the paper's
        # data-reduction claim, measured directly.
        print(f"phase-2 wire bytes (host): {stats['bytes_total']} vs "
              f"{n * 8} of raw points — only contour representatives "
              f"cross the network")
    else:
        # The engine backends ship fixed-size (C, V)-padded ClusterSet
        # buffers per collective, metered exactly at trace time.
        print(f"phase-2 wire bytes ({cfg.backend}): "
              f"{stats['bytes_total']} across {stats['collectives']} "
              f"collectives ({stats['merge_steps']} merge steps) — "
              f"padded ClusterSet buffers, never raw points")

    # Read path: point -> global cluster id (DBSCAN's border rule).
    # query() returns a QueryResult (DESIGN §12): the labels plus the
    # snapshot version that answered, the degraded flag, and the routed
    # shard set — and it still duck-types as the labels ndarray.
    probes = np.array([[0.30, 0.65], [0.62, 0.22], [0.02, 0.98]])
    res = model.query(probes)
    print(f"query {probes.tolist()} -> {res.tolist()}   "
          f"(snapshot v{res.version}, degraded={res.degraded})")

    # The high-QPS tier: requests enter a bounded queue and are answered
    # from the last published snapshot in coalesced batched launches.
    tier = model.query_tier
    handles = [tier.submit(probes + 0.01 * i) for i in range(3)]
    tier.drain()
    st = model.stats()                  # the typed ServiceStats contract
    print(f"query tier: {st.counters.queries_served} served in "
          f"{st.counters.query_launches} launches "
          f"({st.counters.coalesced_requests} coalesced), "
          f"p.version={handles[-1].result.version}")

    if cfg.backend in ("stream", "dist"):
        # Streaming extras: timestamped writes, TTL eviction, and a
        # bit-identical snapshot/restore round-trip.
        model.partial_fit(0, pts[:64], t=1.0)
        model.expire(t=0.0)              # nothing older than t=0 yet
        with tempfile.TemporaryDirectory() as d:
            model.save(os.path.join(d, "ckpt"))
            restored = DDC.load(os.path.join(d, "ckpt"))
            same = np.array_equal(model.labels_, restored.labels_)
        print(f"snapshot -> restore: labels bit-identical = {same}")

        # Cluster tracking (DESIGN §14): with track=True the engine
        # assigns stable track IDs across refreshes and derives motion
        # analytics per track.  Play a drifting-blobs stream — one
        # tracked refresh per frame, sliding-window eviction — and read
        # the TrackSnapshot via model.tracks() (published at the same
        # version as the query tier's Snapshot).
        from repro.serve import tracking
        spec = spatial.TRAJECTORY_LAYOUTS["drifting_blobs"]
        traj = spec["make"](steps=10, n_per_step=spec["n_per_step"])
        tcap = spatial.trajectory_capacity(
            spec["n_per_step"], spec["window"], k)
        tcfg = DDCConfig(
            eps=spec["eps"], min_pts=spec["min_pts"], grid=spec["grid"],
            max_clusters=spec["max_clusters"],
            max_verts=spec["max_verts"], backend=cfg.backend, shards=k,
            capacity=tcap, max_batch=min(256, tcap), track=True,
        ).validate()
        snap = tracking.play(DDC(tcfg), traj.frames,
                             window=spec["window"])
        print(f"tracking: {len(snap.alive)} tracks over "
              f"{snap.generation} generations (births={snap.births} "
              f"deaths={snap.deaths} merges={snap.merges} "
              f"splits={snap.splits} "
              f"continuations={snap.continuations})")
        for t in snap.alive:
            print(f"  track {t.track_id}: size={t.size:3d} "
                  f"speed={t.speed:.4f}/gen "
                  f"heading={t.heading_deg:+6.1f}deg  {t.motion}")

    seq = dbscan.dbscan_ref(pts, cfg.eps, cfg.min_pts)
    # Micro-fragments (< 2*min_pts points) can fall below min_pts when a
    # partition boundary splits them — a known DDC property; compare the
    # real clusters.
    big = [c for c in set(seq[seq >= 0])
           if (seq == c).sum() >= 2 * cfg.min_pts]
    print(f"sequential DBSCAN finds {len(big)} clusters (+"
          f"{len(set(seq[seq >= 0])) - len(big)} micro-fragments) -> "
          f"{'MATCH' if len(big) == model.n_clusters_ else 'DIFFER'}")

    sample = np.random.default_rng(0).choice(n, 1200, replace=False)
    print(ascii_plot(pts[sample], glabels[sample]))

    print("\n== sync vs async on the paper's heterogeneous cluster ==")
    for scen in ("I", "IV"):
        sizes = partitioner.scenario_sizes(scen)
        s = sim.simulate(sim.PAPER_MACHINES, sizes, "sync").makespan
        a = sim.simulate(sim.PAPER_MACHINES, sizes, "async").makespan
        print(f"scenario {scen}: sync {s:8.0f} ms | async {a:8.0f} ms "
              f"({'async wins' if a < s else 'sync wins'})")


if __name__ == "__main__":
    main()

"""Quickstart: DDC on a Chameleon-like spatial dataset.

Runs the paper's full pipeline on one host:
  phase 1 — partition + per-shard DBSCAN + contour reduction,
  phase 2 — hierarchical merge of contours,
then compares against sequential DBSCAN and prints the sync-vs-async
wall-clock simulation for the paper's 8-machine cluster.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import dbscan, ddc, partitioner, simulate as sim
from repro.data import spatial


def ascii_plot(pts, labels, width=72, height=24):
    chars = "ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghij"
    grid = [[" "] * width for _ in range(height)]
    for (x, y), l in zip(pts, labels):
        c = "." if l < 0 else chars[l % len(chars)]
        grid[int((1 - y) * (height - 1))][int(x * (width - 1))] = c
    return "\n".join("".join(row) for row in grid)


def main():
    n, k = 6000, 8
    pts = spatial.make_d1(n, seed=0, noise_frac=0.02)
    eps, min_pts = 0.022, 4

    print(f"== DDC on D1-like dataset (n={n}, {k} partitions) ==")
    glabels, polys, _ = ddc.ddc_host(pts, k, eps=eps, min_pts=min_pts,
                                     contour="grid")
    # Hull contours give the compact wire representation (the grid run
    # above preserves non-convexity for the merge decisions).
    _, _, exchanged = ddc.ddc_host(pts, k, eps=eps, min_pts=min_pts,
                                   contour="hull")
    n_global = len(set(glabels[glabels >= 0]))
    print(f"global clusters: {n_global}   noise: {(glabels < 0).sum()}")
    print(f"data exchanged (hull representatives): {exchanged} vertices "
          f"= {exchanged / n:.2%} of the dataset (paper: 1-2%)")

    seq = dbscan.dbscan_ref(pts, eps, min_pts)
    # Micro-fragments (< 2*min_pts points) can fall below min_pts when a
    # partition boundary splits them — a known DDC property; compare the
    # real clusters.
    big = [c for c in set(seq[seq >= 0]) if (seq == c).sum() >= 2 * min_pts]
    print(f"sequential DBSCAN finds {len(big)} clusters (+"
          f"{len(set(seq[seq >= 0])) - len(big)} micro-fragments) -> "
          f"{'MATCH' if len(big) == n_global else 'DIFFER'}")

    sample = np.random.default_rng(0).choice(n, 1200, replace=False)
    print(ascii_plot(pts[sample], glabels[sample]))

    print("\n== sync vs async on the paper's heterogeneous cluster ==")
    for scen in ("I", "IV"):
        sizes = partitioner.scenario_sizes(scen)
        s = sim.simulate(sim.PAPER_MACHINES, sizes, "sync").makespan
        a = sim.simulate(sim.PAPER_MACHINES, sizes, "async").makespan
        print(f"scenario {scen}: sync {s:8.0f} ms | async {a:8.0f} ms "
              f"({'async wins' if a < s else 'sync wins'})")


if __name__ == "__main__":
    main()

"""Batched serving example: prefill + KV-cache decode over a batch of
requests, with greedy and sampled generation.

  PYTHONPATH=src python examples/serve_lm.py --requests 8 --gen 32
"""
import argparse

from repro.launch import serve as serve_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()
    serve_mod.main([
        "--arch", args.arch, "--tiny",
        "--requests", str(args.requests),
        "--prompt-len", "32", "--gen", str(args.gen),
        "--temperature", str(args.temperature),
    ])


if __name__ == "__main__":
    main()

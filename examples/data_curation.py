"""DDC-powered distributed data curation (the paper's technique inside
the LM data pipeline, DESIGN.md §4).

Embeds a synthetic skewed corpus, clusters the embeddings with DDC
(host path here; the identical shard_map path runs on the training mesh
— see tests/_dist_script.py), derives cluster-balanced sampling weights
and shows the resulting rebalanced batch mixture.

  PYTHONPATH=src python examples/data_curation.py
"""
import numpy as np

from repro.data import curation, pipeline


def main():
    dcfg = pipeline.DataConfig(vocab=4096, seq_len=64, global_batch=64,
                               n_latent_clusters=8, seed=0)
    emb, ids = pipeline.doc_embeddings(dcfg, n_docs=4000)
    # Skew the corpus: cluster 0 is rare, cluster 1 dominates.
    keep = np.ones(len(ids), bool)
    keep[(ids == 0) & (np.arange(len(ids)) % 8 != 0)] = False
    emb, ids = emb[keep], ids[keep]

    res = curation.curate(emb)
    print(f"DDC found {res.n_clusters} clusters over {len(emb)} docs "
          f"(true latent clusters: 8)")
    print(f"cluster sizes: {res.cluster_sizes.astype(int).tolist()}")
    print(f"balanced weights: {np.round(res.sample_weights, 3).tolist()}")
    print(f"exchanged {res.exchanged_fraction:.2%} of embedding bytes "
          f"across 'nodes' (paper: 1-2%)")

    before = pipeline.batch_at(dcfg, 0)
    dcfg2 = curation.apply_to_data_config(dcfg, res, ids)
    after = pipeline.batch_at(dcfg2, 0)
    rng = np.random.default_rng(0)

    def mixture(cfg):
        w = cfg.curation_weights
        if w is None:
            w = np.ones(cfg.n_latent_clusters)
        w = w / w.sum()
        return np.round(w, 3).tolist()

    print(f"sampling mixture before: {mixture(dcfg)}")
    print(f"sampling mixture after : {mixture(dcfg2)}")
    assert after["tokens"].shape == before["tokens"].shape
    print("pipeline batches regenerate deterministically under new weights ✓")


if __name__ == "__main__":
    main()

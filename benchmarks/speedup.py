"""Paper §5.5: DDC speedup vs sequential DBSCAN.

Two measurements:
1. *Measured on this host*: wall-clock of our JAX DBSCAN on the full
   dataset vs the DDC local phase on 1/p partitions (+ merge).  Since
   DBSCAN is O(n^2), clustering n/p points is ~p^2 cheaper — the paper's
   super-linear speedup argument, demonstrated with real timings.
2. *Simulated cluster*: the paper's own heterogeneous 8-machine setup
   (Table 6 / §5.5, reporting their measured 9x)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import dbscan as db
from repro.core import ddc, partitioner, simulate as sim
from repro.data import spatial


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def run(print_rows=True, n=8192, p=8):
    pts = spatial.make_d1(n, seed=0)
    eps, min_pts = 0.02, 4
    mask = jnp.ones(n, bool)

    seq_t = _time(lambda x: db.dbscan(x, mask, eps, min_pts).labels,
                  jnp.asarray(pts))

    # DDC phase 1 on one partition of n/p (parallel wall-clock = max over
    # equal shards = this), plus the phase-2 merge chain.
    cfg = ddc.DDCConfig(eps=eps, min_pts=min_pts, max_clusters=16,
                        max_verts=64, grid=64)
    shard = jnp.asarray(pts[: n // p])
    smask = jnp.ones(n // p, bool)
    local_t = _time(lambda x: jax.block_until_ready(
        ddc.local_phase(x, smask, cfg)[1].contours), shard)

    _, cs = ddc.local_phase(shard, smask, cfg)
    merge_t = _time(lambda a: ddc.merge_pair(a, a, cfg)[0].contours, cs)
    import math
    ddc_t = local_t + math.ceil(math.log2(p)) * merge_t
    measured = seq_t / ddc_t

    # Simulated homogeneous cluster: the clean super-linearity statement
    # (same machine, p shards of n/p: t = c*(n/p)^2 + merge overhead).
    import dataclasses as _dc
    base = sim.PAPER_MACHINES[0]
    t1 = sim.sequential_time(base, 10_000)
    homog = [_dc.replace(base, name=f"m{i}") for i in range(8)]
    tp = sim.simulate(homog, [1250] * 8, "async").makespan
    homog_speedup = t1 / tp

    # Paper §5.5 methodology: their T1 = 15841 ms (fastest machine on the
    # full 10k set, Table 5); Tp = balanced scenario IV total.
    paper_t1 = 15_841.0
    tp4 = sim.simulate(sim.PAPER_MACHINES,
                       partitioner.scenario_sizes("IV"), "sync").makespan
    paper_conv = paper_t1 / tp4

    if print_rows:
        print(f"measured  : seq(n={n}) {seq_t*1e3:8.1f} ms | DDC(p={p}) "
              f"{ddc_t*1e3:8.1f} ms (local {local_t*1e3:.1f} + merges "
              f"{merge_t*1e3:.1f}*log2(p)) | speedup {measured:6.1f}x "
              f"(p^2 = {p*p})")
        print(f"simulated homogeneous x8 : T1 {t1:8.0f} ms | Tp {tp:8.0f} ms "
              f"| speedup {homog_speedup:5.1f}x (> p=8: super-linear)")
        print(f"simulated paper §5.5 conv: T1 {paper_t1:8.0f} ms | Tp "
              f"{tp4:8.0f} ms | speedup {paper_conv:5.1f}x (paper reports 9x)")
    return [
        {"name": "speedup_measured", "seq_ms": seq_t * 1e3,
         "ddc_ms": ddc_t * 1e3, "speedup": measured, "p": p},
        {"name": "speedup_simulated_homog", "speedup": homog_speedup},
        {"name": "speedup_simulated_paper_conv", "speedup": paper_conv,
         "paper_speedup": 9.0},
    ]


if __name__ == "__main__":
    run()

"""The paper's §3.1 data-reduction claim: cluster representatives
(contours) are 1-2% of the dataset — measured on D1/D2 analogues with
both contour extractors, plus the distributed wire-format accounting
(sync all-gather vs async butterfly)."""
from __future__ import annotations


from repro.core import dbscan as db
from repro.core import ddc, geometry
from repro.data import spatial


def run(print_rows=True):
    rows = []
    for name, pts, eps in (
        ("D1", spatial.make_d1(10_000, seed=0), 0.02),
        ("D2", spatial.make_d2(30_000, seed=1), 0.02),
    ):
        labels = db.dbscan_ref(pts, eps, 4)
        hull_verts = grid_verts = 0
        for c in sorted(set(labels[labels >= 0])):
            members = pts[labels == c]
            hull_verts += len(geometry.convex_hull_np(members))
            grid_verts += len(geometry.grid_contour_np(members, (0, 0, 1, 1), 64))
        n = len(pts)
        n_clusters = len(set(labels[labels >= 0]))
        if print_rows:
            print(f"{name}: n={n} clusters={n_clusters} | hull verts "
                  f"{hull_verts} ({hull_verts/n:.2%}) | grid-64 verts "
                  f"{grid_verts} ({grid_verts/n:.2%})  [paper claims 1-2%]")
        rows.append({"name": f"comm_volume_{name}", "n": n,
                     "hull_frac": hull_verts / n, "grid_frac": grid_verts / n})

    # Wire format at production scale: a lane ships its fixed ClusterSet
    # buffer instead of its raw shard — the win grows with shard size.
    cfg = ddc.DDCConfig(max_clusters=32, max_verts=128)
    buf = cfg.buffer_bytes()
    for shard_pts in (10_000, 100_000, 1_000_000):
        raw = shard_pts * 2 * 4
        if print_rows:
            print(f"shard={shard_pts:>9,} pts: ClusterSet {buf:,} B vs raw "
                  f"{raw:,} B -> {buf/raw:.2%} of the shard crosses the wire "
                  f"per merge round (log2(K) rounds async, K-1 gathers sync)")
        rows.append({"name": f"wire_shard{shard_pts}", "buffer_bytes": buf,
                     "raw_bytes": raw, "fraction": buf / raw})
    return rows


if __name__ == "__main__":
    run()

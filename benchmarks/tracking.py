"""Cluster-tracking benchmark (DESIGN.md §14) — match latency, lifecycle
event counts, and ID stability of the ``ClusterTracker`` fold over the
streaming serve stack.

Two arms, both on the stream backend with ``track=True``:

* **layout** — every ``TRAJECTORY_LAYOUTS`` trajectory × {2, 4, 8}
  shards (smoke: 2 only) is played frame-by-frame (one tracked refresh
  per frame, sliding-window eviction), recording the per-refresh match
  latency (``ClusterTracker.last_update_ms``), the full lifecycle event
  census, and the **ID-stability rate**::

      continuations / (continuations + late_births + deaths
                       + merges + splits)

  i.e. the fraction of track transitions that kept an existing identity
  (first-generation births are the unavoidable cold start and are
  excluded).  On ``drifting_blobs`` — non-interacting groups by
  construction — stability below 0.95 HARD-FAILS the benchmark: a
  tracker that churns IDs on the easy layout is broken.

* **scaling** — match latency vs #clusters: drifting-blob streams with
  2/4/8 blobs in well-separated lanes (radius and eps shrunk so even 8
  lanes clear the merge radius), ``max_clusters`` scaled with the blob
  count so the (K·C) matching batch genuinely grows.  The mean excludes
  the first two generations (generation 1 is the all-births cold start
  and never matches; generation 2 pays the one-time jit compile of the
  match kernel).

Writes ``BENCH_tracking.json`` (schema ``tracking-bench/v1``,
``benchmarks/check_bench.py``).  ``--smoke`` trims both sweeps for CI.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _parse_args(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="tiny CI subset: 2 shards, 2/4-blob scaling only")
    p.add_argument("--out", default=None, help="output JSON path")
    return p.parse_args(argv)


_ARGS = None
if __name__ == "__main__":
    _ARGS = _parse_args()

import numpy as np                                    # noqa: E402

from repro.data import spatial                        # noqa: E402
from repro.ddc import DDC, DDCConfig                  # noqa: E402

SHARDS_FULL = (2, 4, 8)
SHARDS_SMOKE = (2,)
SCALING_BLOBS_FULL = (2, 4, 8)
SCALING_BLOBS_SMOKE = (2, 4)
STABILITY_FLOOR = 0.95
# The scaling arm's geometry: 8 lanes on [0.2, 0.8] sit 0.086 apart, so
# blob radius and eps must keep the inter-lane gap above the merge
# radius (eps + 1.5/grid = 0.031) — otherwise lane crossings would read
# as merge/split churn and the latency rows would measure the wrong
# regime.
SCALING = dict(eps=0.015, min_pts=3, grid=96, max_verts=96,
               steps=16, window=4, radius=0.02, speed=0.01,
               per_blob=24, shards=4)


def build(spec: dict, k: int, n_per_step: int, max_clusters: int) -> DDC:
    cap = spatial.trajectory_capacity(n_per_step, spec["window"], k)
    cfg = DDCConfig(
        eps=spec["eps"], min_pts=spec["min_pts"], grid=spec["grid"],
        max_clusters=max_clusters, max_verts=spec["max_verts"],
        backend="stream", shards=k, capacity=cap,
        max_batch=min(256, cap), track=True).validate()
    return DDC(cfg)


def play_timed(model: DDC, frames, window: int):
    """One tracked refresh per frame with sliding-window eviction,
    recording the tracker's per-refresh match latency."""
    k = model.config.shards
    tracker = model.service.tracker
    match_ms = []
    for step, frame in enumerate(frames):
        for shard, part in enumerate(np.array_split(frame, k)):
            if len(part):
                model.partial_fit(shard, part,
                                  t=float(step) * np.ones(len(part)))
        if step + 1 > window:
            model.expire(float(step - window + 1))
        model.service.refresh()
        match_ms.append(tracker.last_update_ms)
    return model.tracks(), match_ms


def stability(snap) -> float:
    """Fraction of track transitions that kept an existing identity.
    Generation-1 births are the cold start, not churn."""
    late_births = sum(1 for e in snap.events
                      if e.kind == "birth" and e.gen > 1)
    churn = late_births + snap.deaths + snap.merges + snap.splits
    denom = snap.continuations + churn
    return 1.0 if denom == 0 else snap.continuations / denom


def bench_row(kind: str, layout: str, spec: dict, frames, k: int,
              n_per_step: int, max_clusters: int, n_blobs: int) -> dict:
    model = build(spec, k, n_per_step, max_clusters)
    t0 = time.perf_counter()
    snap, match_ms = play_timed(model, frames, spec["window"])
    play_ms = (time.perf_counter() - t0) * 1e3
    # Generation 1 never matches (all-births cold start) and generation
    # 2 pays the one-time match-kernel compile — the steady mean starts
    # at generation 3.
    steady = match_ms[2:]
    return {
        "kind": kind,
        "layout": layout,
        "shards": k,
        "n_blobs": n_blobs,
        "generations": snap.generation,
        "n_clusters": len(snap.alive),
        "tracks_total": snap.next_track_id,
        "births": snap.births,
        "deaths": snap.deaths,
        "merges": snap.merges,
        "splits": snap.splits,
        "continuations": snap.continuations,
        "id_stability": round(stability(snap), 4),
        "match_ms_mean": round(float(np.mean(steady)), 3),
        "match_ms_last": round(match_ms[-1], 3),
        "play_ms": round(play_ms, 1),
    }


def run(smoke: bool = False, out_path: str | None = None,
        print_rows: bool = True):
    shards = SHARDS_SMOKE if smoke else SHARDS_FULL
    blobs = SCALING_BLOBS_SMOKE if smoke else SCALING_BLOBS_FULL
    rows = []

    for layout in sorted(spatial.TRAJECTORY_LAYOUTS):
        spec = spatial.TRAJECTORY_LAYOUTS[layout]
        traj = spec["make"](steps=spec["steps"],
                            n_per_step=spec["n_per_step"])
        for k in shards:
            row = bench_row("layout", layout, spec, traj.frames, k,
                            spec["n_per_step"], spec["max_clusters"],
                            n_blobs=traj.centers.shape[1])
            rows.append(row)
            if print_rows:
                print(f"track_{layout}_k{k}: stability="
                      f"{row['id_stability']} match="
                      f"{row['match_ms_mean']}ms events="
                      f"b{row['births']}/d{row['deaths']}/"
                      f"m{row['merges']}/s{row['splits']}/"
                      f"c{row['continuations']}")

    for b in blobs:
        n_per_step = SCALING["per_blob"] * b
        traj = spatial.make_drifting_blobs(
            steps=SCALING["steps"], n_per_step=n_per_step, n_blobs=b,
            seed=0, speed=SCALING["speed"], radius=SCALING["radius"])
        row = bench_row("scaling", "drifting_blobs", SCALING, traj.frames,
                        SCALING["shards"], n_per_step,
                        max_clusters=b + 4, n_blobs=b)
        rows.append(row)
        if print_rows:
            print(f"track_scaling_b{b}: clusters={row['n_clusters']} "
                  f"match={row['match_ms_mean']}ms "
                  f"stability={row['id_stability']}")

    drifting = [r for r in rows
                if r["kind"] == "layout" and r["layout"] == "drifting_blobs"]
    drifting_min = min(r["id_stability"] for r in drifting)
    summary = {
        "stability_floor": STABILITY_FLOOR,
        "drifting_stability_min": drifting_min,
        "stability_gate": drifting_min >= STABILITY_FLOOR,
        "n_layouts": len({r["layout"] for r in rows if r["kind"] == "layout"}),
        "max_shards": max(shards),
        "max_scaling_blobs": max(blobs),
        "mean_match_ms": round(float(np.mean(
            [r["match_ms_mean"] for r in rows])), 3),
    }
    out = {
        "schema": "tracking-bench/v1",
        "smoke": bool(smoke),
        "backend": "stream",
        "layouts": {name: {k: v for k, v in spec.items() if k != "make"}
                    for name, spec in spatial.TRAJECTORY_LAYOUTS.items()},
        "scaling": {k: v for k, v in SCALING.items()},
        "rows": rows,
        "summary": summary,
    }
    if out_path is None:
        out_path = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "BENCH_tracking.json")
    with open(out_path, "w") as f:
        json.dump(out, f, indent=1)
    if print_rows:
        print("summary:", json.dumps(summary))
        print("wrote", out_path)
    if not summary["stability_gate"]:
        print(f"TRACKING BENCH FAILED: drifting_blobs ID stability "
              f"{drifting_min} < {STABILITY_FLOOR}", file=sys.stderr)
        raise SystemExit(1)
    return rows


if __name__ == "__main__":
    run(smoke=_ARGS.smoke, out_path=_ARGS.out)

"""Kernel micro-benchmarks (CPU wall-time of the dispatched ops +
interpret-mode correctness spot checks).  On TPU these run the Pallas
kernels; here they time the jnp stand-ins, establishing the harness."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _bench(fn, *args, reps=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6  # us


def run(print_rows=True):
    rng = np.random.default_rng(0)
    rows = []

    x = jnp.asarray(rng.normal(size=(2048, 2)), jnp.float32)
    mask = jnp.ones(2048, bool)
    us = _bench(lambda x: ops.neighbor_count(x, mask, 0.05), x)
    flops = 2048 * 2048 * 2 * 2
    rows.append(("neighbor_count_2048", us, f"{flops/us/1e3:.2f}GF/s"))

    # Block-sparse variant on clustered points (active-pair list + gather).
    from repro.core import dbscan as db_mod
    from repro.data import spatial
    xs, ms, _ = db_mod.spatial_sort(
        jnp.asarray(spatial.make_clustered(2048)), mask, 256)
    pairs = ops.build_tile_pairs(xs, ms, 0.05, bt=256)
    us = _bench(
        lambda x: ops.neighbor_count_sparse(x, ms, 0.05, pairs, bt=256), xs)
    rows.append(("neighbor_count_sparse_2048", us,
                 f"frac={float(pairs.frac):.3f}"))

    q = jnp.asarray(rng.normal(size=(1, 8, 1024, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 2, 1024, 64)), jnp.bfloat16)
    us = _bench(lambda q, k: ops.flash_attention(q, k, k, causal=True), q, k)
    flops = 2 * 2 * 8 * 1024 * 1024 * 64 / 2
    rows.append(("flash_attn_1k_gqa", us, f"{flops/us/1e3:.2f}GF/s"))

    xs = jnp.asarray(rng.normal(size=(1, 4096, 8, 32)), jnp.float32)
    a = jnp.asarray(-np.abs(rng.normal(size=(1, 4096, 8))) * 0.1, jnp.float32)
    b = jnp.asarray(rng.normal(size=(1, 4096, 8, 16)), jnp.float32)
    us = _bench(lambda xs, a, b: ops.ssd_scan(xs, a, b, b), xs, a, b)
    rows.append(("ssd_scan_4k", us, ""))

    jit_jnp = jax.jit(lambda x: ref.pairwise_dist_sq(x, x))
    us = _bench(jit_jnp, x)
    rows.append(("pairwise_ref_2048", us, ""))

    if print_rows:
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
    return [{"name": n, "us_per_call": u, "derived": d} for n, u, d in rows]


if __name__ == "__main__":
    run()

"""Paper Figures 4-5: execution time vs number of machines for D1 (10k)
and D2 (30k points); phase-1 falls, phase-2 rises, total has an interior
optimum that moves right with dataset size."""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import simulate as sim


def run(print_rows=True):
    base = sim.PAPER_MACHINES[0]
    rows = []
    for dset, n in (("D1", 10_000), ("D2", 30_000)):
        if print_rows:
            print(f"\n== {dset} ({n} points) — log2(time ms) vs machines ==")
            print(f"{'machines':>8} {'phase1':>10} {'phase2':>10} {'total':>10}")
        times = []
        counts = [1, 2, 4, 8, 16, 32, 64]
        for k in counts:
            machines = [dataclasses.replace(base, name=f"m{i}") for i in range(k)]
            sizes = [n // k] * k
            r = sim.simulate(machines, sizes, "async")
            p1 = max(r.step1)
            total = r.makespan
            p2 = total - p1
            times.append(total)
            if print_rows:
                print(f"{k:>8} {np.log2(max(p1,1)):>10.2f} "
                      f"{np.log2(max(p2,1)):>10.2f} {np.log2(total):>10.2f}")
            rows.append({"name": f"scalability_{dset}", "machines": k,
                         "phase1_ms": p1, "phase2_ms": p2, "total_ms": total})
        opt = counts[int(np.argmin(times))]
        if print_rows:
            print(f"optimal machines for {dset}: {opt} "
                  f"(paper: 8 for D1, 16 for D2)")
        rows.append({"name": f"optimal_{dset}", "machines": opt})
    return rows


if __name__ == "__main__":
    run()

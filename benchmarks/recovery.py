"""Fault-tolerance benchmark — MTTR and degraded-mode query latency for
the serve engines (DESIGN.md §11).

Four spatial layouts (the shared ``PHASE2_LAYOUTS`` table) × shard
counts {2, 4, 8} × both serve engines (``stream`` host-driven, ``dist``
device-resident).  Per cell the service ingests the full layout, then a
seeded ``FaultPlan`` kills shard 0's lane mid-refresh:

* **healthy_query_ms** — steady-state routed query latency before the
  fault;
* **degraded_query_ms** — the same query batch while shard 0 is
  quarantined (healthy shards keep serving; the answer is flagged
  stale);
* **mttr_ms** — wall-clock of ``recover(0)`` (journal replay + lane
  re-upload) plus the refresh that folds the shard back in;
* **recovered_bitexact** — post-recovery global labels AND the cached
  pair-d2 matrix must equal a fault-free twin fed the identical ingest
  schedule, bit-for-bit.  The bench hard-fails otherwise: recovery
  speed is meaningless if the recovered state is wrong.

Writes ``BENCH_recovery.json`` (schema ``recovery-bench/v1``,
``benchmarks/check_bench.py``).  ``--smoke`` trims the sweep for CI;
``--backend`` picks stream/dist/both (dist forces an 8-device CPU
override before jax initialises).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _parse_args(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="tiny CI subset: 2/4 shards, one layout")
    p.add_argument("--backend", choices=("stream", "dist", "both"),
                   default="both", help="which serve engine(s) to bench")
    p.add_argument("--out", default=None, help="output JSON path")
    return p.parse_args(argv)


_ARGS = None
if __name__ == "__main__":
    # The dist engine pins one shard per device; the CPU device count
    # must be forced before jax initialises (i.e. before the repro
    # imports below).
    _ARGS = _parse_args()
    if _ARGS.backend in ("dist", "both"):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=8").strip()

import numpy as np                                    # noqa: E402

from repro.data import spatial                        # noqa: E402
from repro.ddc import DDC, DDCConfig                  # noqa: E402
from repro.serve import FaultEvent, FaultPlan         # noqa: E402

N = 2048
BATCH = 256
QUERIES = 256
LAYOUTS = spatial.PHASE2_LAYOUTS


def min_time(fn, reps: int) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, (time.perf_counter() - t0) * 1e3)
    return best


def build(spec: dict, k: int, backend: str, faults=None) -> DDC:
    cap = spatial.shard_capacity(N, k)
    cfg = DDCConfig(
        eps=spec["eps"], min_pts=spec["min_pts"], grid=spec["grid"],
        max_clusters=spec["max_clusters"], max_verts=spec["max_verts"],
        backend=backend, shards=k, capacity=cap,
        max_batch=min(BATCH, cap), max_queries=QUERIES).validate()
    return DDC(cfg, faults=faults)


def bench_cell(name: str, spec: dict, k: int, backend: str,
               reps: int = 3) -> dict:
    pts = spec["make"](N)
    batch = min(BATCH, spatial.shard_capacity(N, k))
    model = build(spec, k, backend)
    twin = build(spec, k, backend)
    for m in (model, twin):
        for shard, chunk in spatial.stream_batches(pts, k, batch):
            m.partial_fit(shard, chunk)
            m.service.refresh()
    svc = model.service

    rng = np.random.default_rng(0)
    q = rng.uniform(0, 1, (QUERIES, 2)).astype(np.float32)
    svc.query(q)   # compile
    healthy_ms = min_time(lambda: svc.query(q), reps)

    # Kill shard 0's lane on its next delta delivery; the twin sees the
    # identical ingest but no fault.
    svc.faults = FaultPlan(events=(FaultEvent("kill", shard=0),))
    for m in (model, twin):
        m.partial_fit(0, pts[:8])
        m.service.refresh()
    assert 0 in svc.quarantined, "kill fault did not quarantine shard 0"
    degraded_ms = min_time(lambda: svc.query(q), reps)
    assert svc.last_query_degraded, "degraded query not flagged stale"

    # MTTR: journal replay + lane re-upload + the refresh that folds the
    # recovered shard back into the global state.
    t0 = time.perf_counter()
    assert svc.recover(0)
    svc.refresh()
    mttr_ms = (time.perf_counter() - t0) * 1e3

    bitexact = (
        np.array_equal(model.labels_, twin.labels_)
        and np.array_equal(np.asarray(svc.pair_d2),
                           np.asarray(twin.service.pair_d2)))
    stats = svc.stats()
    return {
        "backend": backend,
        "layout": name,
        "shards": k,
        "n_live": int(svc.n_live()),
        "healthy_query_ms": round(healthy_ms, 3),
        "degraded_query_ms": round(degraded_ms, 3),
        "mttr_ms": round(mttr_ms, 3),
        "recovered_bitexact": bool(bitexact),
        "journal_entries": stats["journal_entries"],
        "quarantine_events": stats["quarantined_shards"],
        "degraded_queries": stats["degraded_queries"],
    }


def run(smoke: bool = False, out_path: str | None = None,
        backend: str = "both", print_rows: bool = True):
    shards = (2, 4) if smoke else (2, 4, 8)
    backends = ("stream", "dist") if backend == "both" else (backend,)
    layouts = dict(list(LAYOUTS.items())[:1]) if smoke else LAYOUTS
    rows = []
    layouts_meta = {}
    for name, spec in layouts.items():
        layouts_meta[name] = {
            key: spec[key] for key in ("eps", "min_pts", "grid", "max_verts",
                                       "max_clusters")
        } | {"n": N}
        for be in backends:
            for k in shards:
                row = bench_cell(name, spec, k, be)
                rows.append(row)
                if print_rows:
                    print(f"recovery_{be}_{name}_k{k}: "
                          f"mttr={row['mttr_ms']}ms "
                          f"healthy={row['healthy_query_ms']}ms "
                          f"degraded={row['degraded_query_ms']}ms "
                          f"bitexact={row['recovered_bitexact']}")

    all_bitexact = all(r["recovered_bitexact"] for r in rows)
    summary = {
        "all_recovered_bitexact": all_bitexact,
        "n_layouts": len(layouts),
        "max_shards": max(shards),
        "mean_mttr_ms": {
            be: round(float(np.mean(
                [r["mttr_ms"] for r in rows if r["backend"] == be])), 3)
            for be in backends},
    }
    out = {
        "schema": "recovery-bench/v1",
        "smoke": bool(smoke),
        "backend": "mixed" if backend == "both" else backend,
        "n": N,
        "batch": BATCH,
        "shards": list(shards),
        "layouts": layouts_meta,
        "rows": rows,
        "summary": summary,
    }
    if out_path is None:
        out_path = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "BENCH_recovery.json")
    with open(out_path, "w") as f:
        json.dump(out, f, indent=1)
    if print_rows:
        print("summary:", json.dumps(summary))
        print("wrote", out_path)
    if not all_bitexact:
        bad = [(r["backend"], r["layout"], r["shards"]) for r in rows
               if not r["recovered_bitexact"]]
        print("RECOVERY BENCH FAILED:", bad, file=sys.stderr)
        raise SystemExit(1)
    return rows


if __name__ == "__main__":
    run(smoke=_ARGS.smoke, out_path=_ARGS.out, backend=_ARGS.backend)

"""Benchmark driver — one section per paper table/figure + kernel
microbenches.  Prints human tables followed by a machine-readable
``name,us_per_call,derived`` CSV summary."""
from __future__ import annotations



def main() -> None:
    from benchmarks import comm_volume, kernels, scalability, scenarios, speedup

    print("#" * 72)
    print("# Paper Tables 3-6 — sync vs async, scenarios I-IV (simulator)")
    print("#" * 72)
    scen_rows = scenarios.run()

    print()
    print("#" * 72)
    print("# Paper §5.5 — speedup vs sequential DBSCAN")
    print("#" * 72)
    sp_rows = speedup.run()

    print()
    print("#" * 72)
    print("# Paper Figs 4-5 — scalability vs number of machines")
    print("#" * 72)
    sc_rows = scalability.run()

    print()
    print("#" * 72)
    print("# Paper §3.1 — contour data reduction / wire bytes")
    print("#" * 72)
    cv_rows = comm_volume.run()

    print()
    print("#" * 72)
    print("# MoE dispatch: epsum vs a2a vs a2a+int8 (beyond-paper, §Perf B)")
    print("#" * 72)
    from benchmarks import moe_dispatch
    md_rows = moe_dispatch.run()

    print()
    print("#" * 72)
    print("# Phase-1 block-sparse + pointer-doubling sweep (BENCH_phase1.json)")
    print("#" * 72)
    from benchmarks import phase1
    p1_rows = phase1.run()

    print()
    print("#" * 72)
    print("# Streaming serve engine: delta-merge vs full re-merge "
          "(stream only; side artifact, committed BENCH_serve.json "
          "untouched)")
    print("#" * 72)
    # Stream engine only: the dist engine needs a forced multi-device
    # CPU before jax initialises (python benchmarks/serve.py --backend
    # dist), which this in-process driver cannot retrofit.  Write to a
    # side path — the committed BENCH_serve.json is the mixed
    # stream+dist artifact and must not be clobbered by a stream-only
    # run.
    import os
    import tempfile

    from benchmarks import serve
    sv_rows = serve.run(
        backend="stream",
        out_path=os.path.join(tempfile.gettempdir(),
                              "BENCH_serve_stream.json"))

    print()
    print("#" * 72)
    print("# Kernel microbenches")
    print("#" * 72)
    k_rows = kernels.run(print_rows=False)

    print()
    print("name,us_per_call,derived")
    for r in scen_rows:
        print(f"{r['name']},{r['async_ms']*1e3:.0f},"
              f"async/sync={r['ratio']:.3f}|paper={r['paper_ratio']:.3f}")
    for r in sp_rows:
        extra = f"speedup={r['speedup']:.1f}x"
        print(f"{r['name']},{r.get('ddc_ms', 0)*1e3:.0f},{extra}")
    for r in sc_rows:
        if r["name"].startswith("optimal"):
            print(f"{r['name']},0,opt_machines={r['machines']}")
    for r in cv_rows:
        if "hull_frac" in r:
            print(f"{r['name']},0,hull={r['hull_frac']:.3%}|grid={r['grid_frac']:.3%}")
    for r in p1_rows:
        derived = f"frac={r['active_frac']:.3f}"
        if "sweep_reduction" in r:
            derived += f"|sweepx={r['sweep_reduction']:.1f}"
        us = f"{r['ms_doubling']*1e3:.0f}" if "ms_doubling" in r else ""
        print(f"phase1_{r['scenario']}_{r['n']},{us},{derived}")
    for r in sv_rows:
        print(f"serve_{r['backend']}_{r['layout']}_k{r['shards']},"
              f"{r['ingest_ms']*1e3:.0f},"
              f"delta/full_bytes={r['delta_bytes']}/{r['full_bytes']}"
              f"|query_us={r['query_ms']*1e3:.0f}")
    for r in k_rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    for r in md_rows:
        print(f"moe_dispatch_{r['impl']},0,coll_bytes={r['coll_bytes']:.0f}")


if __name__ == "__main__":
    main()

"""MoE dispatch collective comparison: epsum vs a2a vs a2a+int8.

Compiles the same MoE layer under each implementation on an 8-device
host mesh and reports per-device collective bytes from the HLO — the
paper's minimize-exchange thesis quantified on the MoE dispatch
(EXPERIMENTS.md §Perf cell B at pod scale; this is the laptop-scale
version that runs in the benchmark suite)."""
from __future__ import annotations

import os
import subprocess
import sys

SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from repro import configs
from repro.launch import hlo_cost, mesh as mesh_mod
from repro.models import layers as L
from repro.parallel import api as par

cfg = configs.get_config("llama4-scout-17b-a16e").tiny(
    n_experts=8, topk=2, d_model=256, moe_d_ff=512, shared_d_ff=0)
import dataclasses
cfg = dataclasses.replace(cfg, n_shared_experts=0, capacity_factor=1.25)
mesh = mesh_mod.make_mesh((2, 4), ("data", "model"))
key = jax.random.PRNGKey(0)
p = jax.eval_shape(lambda: L.moe_init(cfg, key))
x = jax.ShapeDtypeStruct((8, 128, cfg.d_model), jnp.bfloat16)

rows = []
for impl, int8 in (("epsum", False), ("a2a", False), ("a2a", True)):
    pctx = par.ParallelCtx(mesh=mesh, moe_impl=impl, a2a_int8=int8)
    def f(p, x):
        with par.use(pctx):
            y, aux = L.moe_apply(cfg, p, x)
            return y.sum() + aux
    g = jax.jit(jax.grad(f, argnums=1))
    hlo = g.lower(p, x).compile().as_text()
    res = hlo_cost.analyze_text(hlo)
    rows.append({
        "impl": impl + ("+int8" if int8 else ""),
        "coll_bytes": res["collective_bytes"],
        "detail": {k: v for k, v in res["collectives"].items() if v},
    })
print(json.dumps(rows))
'''


def run(print_rows=True):
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                         text=True, timeout=900, env=env)
    if out.returncode != 0:
        if print_rows:
            print("moe_dispatch bench failed:", out.stderr[-400:])
        return []
    import json
    rows = json.loads(out.stdout.strip().splitlines()[-1])
    if print_rows:
        base = rows[0]["coll_bytes"]
        for r in rows:
            print(f"{r['impl']:12s} coll_bytes/dev {r['coll_bytes']:>12,.0f} "
                  f"({base / max(r['coll_bytes'],1):.2f}x vs epsum) {r['detail']}")
        print("# NOTE: at toy scale (8 tiny experts, no FSDP weight gathers)"
              " epsum wins —")
        print("# the a2a layout pays dispatch traffic but saves nothing."
              " The crossover is")
        print("# weights-vs-tokens: at kimi-k2 scale (1T params) epsum"
              " re-gathers 3.9TB of")
        print("# weights per step and a2a wins 2.7x (train) / 4.5x (decode)"
              " — EXPERIMENTS.md §Perf B.")
    return rows


if __name__ == "__main__":
    run()

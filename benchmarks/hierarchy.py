"""Hierarchical vs flat aggregator benchmark (DESIGN.md §13) — the comm
bytes and refresh latency of the tree-of-aggregators against the flat
single-aggregator delta path, at shard counts the flat design was never
meant to reach.

Workload: a fixed blob layout (8 well-separated Gaussian clusters,
contiguous partition so a subtree covers a contiguous point range) is
clustered per shard with ``local_phase``, then the two aggregator
topologies fold the IDENTICAL (K, C, …) batch:

* **flat** — one ``merge_delta`` owner of the full (K·C)² cache; every
  refresh patches the dirty rows and re-runs the global closure, and the
  down-leg broadcasts a (C,) slot-map row to all K shards (the engine's
  ``_meter_maps_down`` model: K·C·4 bytes);
* **hier** — ``AggregatorTree`` at degree 2 and 4: a dirty shard patches
  its leaf and propagates only while summaries keep changing; bytes are
  the tree's own accounting (shard payloads + internal summary edges ×
  buffer_bytes, down map edges + changed shard rows × C·4).

Per cell (K ∈ 16–256, smoke 16/32) it measures the cold build, the
steady-state single-dirty refresh (the common serving case: one shard
re-ingested, global structure unchanged — the tree absorbs at the leaf,
the flat path must re-run the full closure to discover the same), and a
churn refresh (the dirty shard's summary genuinely changes, forcing a
full root path) — then hard-fails unless the tree's slot maps and the
root occupancy are BIT-IDENTICAL to flat, every node cache equals a
from-scratch rebuild, and the tree wins BOTH steady-state bytes and
latency at K ≥ 32.

Writes ``BENCH_hierarchy.json`` (schema ``hierarchy-bench/v1``,
``benchmarks/check_bench.py``).  ``--smoke`` trims the shard sweep for
CI.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _parse_args(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="tiny CI subset: 16/32 shards only")
    p.add_argument("--out", default=None, help="output JSON path")
    return p.parse_args(argv)


_ARGS = None
if __name__ == "__main__":
    _ARGS = _parse_args()

import jax                                            # noqa: E402
import jax.numpy as jnp                               # noqa: E402
import numpy as np                                    # noqa: E402

from repro.core import ddc                            # noqa: E402
from repro.serve.hierarchy import AggregatorTree      # noqa: E402

N = 8192
BLOBS = 8
DEGREES = (2, 4)
SHARDS_FULL = (16, 32, 64, 128, 256)
SHARDS_SMOKE = (16, 32)
# Small slot budgets on purpose: the flat cache is (K·C)² and the full
# closure O((K·C)²·V²), so production-sized C/V at K=256 is exactly the
# wall this benchmark demonstrates — the budgets only need to fit the
# blob layout (8 global clusters, ≤ a few fragments per shard).
CFG = ddc.DDCConfig(eps=0.03, min_pts=3, grid=48,
                    max_clusters=8, max_verts=24)


def make_points(n: int = N, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    centers = [(0.18 + 0.32 * (i % 3), 0.18 + 0.32 * (i // 3))
               for i in range(BLOBS)]
    per = n // BLOBS
    pts = np.concatenate([
        c + rng.normal(scale=0.018, size=(per, 2)) for c in centers])
    return np.clip(pts, 0.01, 0.99).astype(np.float32)


def shard_batch(pts: np.ndarray, k: int) -> ddc.ClusterSet:
    """Contiguous partition → per-shard ``local_phase`` → (K, C, …)."""
    slices = np.array_split(pts, k)
    cap = max(len(s) for s in slices)
    sets = []
    for sl in slices:
        buf = np.zeros((cap, 2), np.float32)
        buf[:len(sl)] = sl
        mask = np.zeros((cap,), bool)
        mask[:len(sl)] = True
        _, cs = ddc.local_phase(jnp.asarray(buf), jnp.asarray(mask), CFG)
        sets.append(cs)
    return jax.tree.map(lambda *xs: jnp.stack(xs), *sets)


def min_time(fn, reps: int = 3) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, (time.perf_counter() - t0) * 1e3)
    return best


def hier_refresh_bytes(stats: dict, bbytes: int, row: int) -> int:
    """The tree's wire model for one refresh: dirty shard payloads and
    internal summary pushes cost a ClusterSet each; down map edges and
    changed shard rows cost a (C,) i32 row each."""
    return (stats["up_shard_payloads"] * bbytes
            + stats["internal_up_edges"] * bbytes
            + stats["down_internal_edges"] * row
            + stats["down_shard_rows"] * row)


def flat_arm(batch, batch_alt, k: int, reps: int) -> dict:
    bbytes, row = CFG.buffer_bytes(), CFG.max_clusters * 4
    t0 = time.perf_counter()
    merged, maps, d2 = ddc.merge_delta(batch, None, None, CFG, None)
    jax.block_until_ready(maps)
    build_ms = (time.perf_counter() - t0) * 1e3
    state = {"d2": d2, "maps": maps, "merged": merged}

    def refresh(b):
        state["merged"], state["maps"], state["d2"] = ddc.merge_delta(
            b, state["d2"], [0], CFG, None)
        jax.block_until_ready(state["maps"])

    refresh(batch)                       # compile the patch path
    steady_ms = min_time(lambda: refresh(batch), reps)
    refresh(batch_alt)                   # compile nothing new; settle
    churn_ms = min_time(
        lambda: (refresh(batch), refresh(batch_alt)), reps) / 2
    refresh(batch)                       # end on the reference batch
    return {
        "build_ms": round(build_ms, 2),
        "steady_ms": round(steady_ms, 3),
        "churn_ms": round(churn_ms, 3),
        # one shard payload up + the engine's K-row map broadcast down
        "steady_bytes": bbytes + k * row,
        "churn_bytes": bbytes + k * row,
        "bottleneck_bytes": bbytes + k * row,
        "merged": state["merged"],
        "maps": np.asarray(state["maps"]),
    }


def hier_arm(batch, batch_alt, k: int, degree: int, reps: int) -> dict:
    bbytes, row = CFG.buffer_bytes(), CFG.max_clusters * 4
    tree = AggregatorTree(k, degree, CFG)
    t0 = time.perf_counter()
    tree.refresh(batch, None, None)
    jax.block_until_ready(tree.levels[-1][0].summary)
    build_ms = (time.perf_counter() - t0) * 1e3

    tree.refresh(batch, [0], None)       # compile the leaf patch path
    steady_ms = min_time(lambda: tree.refresh(batch, [0], None), reps)
    tree.refresh(batch, [0], None)
    steady_stats = dict(tree.last_stats)
    steady_bottleneck = steady_stats["bottleneck_bytes"]

    tree.refresh(batch_alt, [0], None)   # settle the toggle
    churn_ms = min_time(
        lambda: (tree.refresh(batch, [0], None),
                 tree.refresh(batch_alt, [0], None)), reps) / 2
    tree.refresh(batch, [0], None)
    churn_stats = dict(tree.last_stats)
    g, maps = tree.refresh(batch, [0], None)
    return {
        "degree": degree,
        "depth": tree.depth,
        "n_nodes": tree.n_nodes,
        "build_ms": round(build_ms, 2),
        "steady_ms": round(steady_ms, 3),
        "churn_ms": round(churn_ms, 3),
        "steady_bytes": hier_refresh_bytes(steady_stats, bbytes, row),
        "churn_bytes": hier_refresh_bytes(churn_stats, bbytes, row),
        "bottleneck_bytes": steady_bottleneck,
        "absorbed_steady": steady_stats["absorbed"],
        "cache_exact": tree.cache_exact(),
        "merged": g,
        "maps": np.asarray(maps),
    }


def bench_cell(pts, pts_alt, k: int, reps: int = 3) -> list:
    batch = shard_batch(pts, k)
    batch_alt = jax.tree.map(
        lambda b, a: b.at[0].set(a[0]), batch, shard_batch(pts_alt, k))
    flat = flat_arm(batch, batch_alt, k, reps)
    n_clusters = int(np.asarray(flat["merged"].valid).sum())
    rows = []
    for degree in DEGREES:
        hier = hier_arm(batch, batch_alt, k, degree, reps)
        rows.append({
            "shards": k,
            "degree": degree,
            "depth": hier["depth"],
            "n_nodes": hier["n_nodes"],
            "n_clusters": n_clusters,
            "flat_build_ms": flat["build_ms"],
            "hier_build_ms": hier["build_ms"],
            "flat_refresh_ms": flat["steady_ms"],
            "hier_refresh_ms": hier["steady_ms"],
            "flat_churn_ms": flat["churn_ms"],
            "hier_churn_ms": hier["churn_ms"],
            "flat_refresh_bytes": flat["steady_bytes"],
            "hier_refresh_bytes": hier["steady_bytes"],
            "flat_churn_bytes": flat["churn_bytes"],
            "hier_churn_bytes": hier["churn_bytes"],
            "flat_bottleneck_bytes": flat["bottleneck_bytes"],
            "hier_bottleneck_bytes": hier["bottleneck_bytes"],
            "buffer_bytes": CFG.buffer_bytes(),
            "absorbed_steady": hier["absorbed_steady"],
            "maps_match": bool(np.array_equal(hier["maps"], flat["maps"])),
            "valid_match": bool(np.array_equal(
                np.asarray(hier["merged"].valid),
                np.asarray(flat["merged"].valid))),
            "sizes_match": bool(np.array_equal(
                np.asarray(hier["merged"].sizes),
                np.asarray(flat["merged"].sizes))),
            "root_d2_exact": bool(hier["cache_exact"]),
            "overflow": bool(np.asarray(flat["merged"].overflow)
                             | np.asarray(hier["merged"].overflow)),
        })
    return rows


def run(smoke: bool = False, out_path: str | None = None,
        print_rows: bool = True):
    shards = SHARDS_SMOKE if smoke else SHARDS_FULL
    pts = make_points(seed=0)
    pts_alt = make_points(seed=1)        # churn variant for shard 0
    rows = []
    for k in shards:
        for row in bench_cell(pts, pts_alt, k):
            rows.append(row)
            if print_rows:
                print(f"hier_k{k}_d{row['degree']}: "
                      f"refresh flat={row['flat_refresh_ms']}ms/"
                      f"{row['flat_refresh_bytes']}B "
                      f"hier={row['hier_refresh_ms']}ms/"
                      f"{row['hier_refresh_bytes']}B "
                      f"churn flat={row['flat_churn_ms']}ms "
                      f"hier={row['hier_churn_ms']}ms "
                      f"maps={row['maps_match']} "
                      f"d2={row['root_d2_exact']}")

    all_equiv = all(r["maps_match"] and r["valid_match"] and r["sizes_match"]
                    and r["root_d2_exact"] and not r["overflow"]
                    for r in rows)
    high_k = [r for r in rows if r["shards"] >= 32]
    wins_bytes = all(r["hier_refresh_bytes"] < r["flat_refresh_bytes"]
                     for r in high_k)
    wins_latency = all(r["hier_refresh_ms"] < r["flat_refresh_ms"]
                       for r in high_k)
    summary = {
        "all_equiv_flat": all_equiv,
        "hier_wins_bytes_ge32": wins_bytes,
        "hier_wins_latency_ge32": wins_latency,
        "max_shards": max(shards),
        "mean_flat_over_hier_bytes": round(float(np.mean(
            [r["flat_refresh_bytes"] / r["hier_refresh_bytes"]
             for r in rows])), 2),
        "mean_flat_over_hier_ms": round(float(np.mean(
            [r["flat_refresh_ms"] / r["hier_refresh_ms"]
             for r in rows])), 2),
    }
    out = {
        "schema": "hierarchy-bench/v1",
        "smoke": bool(smoke),
        "n": N,
        "blobs": BLOBS,
        "shards": list(shards),
        "degrees": list(DEGREES),
        "cfg": {"eps": CFG.eps, "min_pts": CFG.min_pts, "grid": CFG.grid,
                "max_clusters": CFG.max_clusters,
                "max_verts": CFG.max_verts},
        "rows": rows,
        "summary": summary,
    }
    if out_path is None:
        out_path = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "BENCH_hierarchy.json")
    with open(out_path, "w") as f:
        json.dump(out, f, indent=1)
    if print_rows:
        print("summary:", json.dumps(summary))
        print("wrote", out_path)
    if not (all_equiv and wins_bytes and wins_latency):
        bad = [(r["shards"], r["degree"]) for r in rows
               if not (r["maps_match"] and r["valid_match"]
                       and r["sizes_match"] and r["root_d2_exact"]
                       and not r["overflow"])]
        bad += [(r["shards"], r["degree"], "bytes") for r in high_k
                if r["hier_refresh_bytes"] >= r["flat_refresh_bytes"]]
        bad += [(r["shards"], r["degree"], "latency") for r in high_k
                if r["hier_refresh_ms"] >= r["flat_refresh_ms"]]
        print("HIERARCHY BENCH FAILED:", bad, file=sys.stderr)
        raise SystemExit(1)
    return rows


if __name__ == "__main__":
    run(smoke=_ARGS.smoke, out_path=_ARGS.out)

"""Schema checker for the BENCH_*.json artifacts (CI benchmark-smoke gate).

No external schema library: the checks are hand-rolled assertions over
structure, types, and cross-field invariants.  Exit code 0 iff every
file passes.

    python benchmarks/check_bench.py BENCH_phase1.json BENCH_phase2.json

Files are recognised by shape: phase-1 artifacts carry a top-level
``bt``; phase-2 artifacts carry ``schema: "phase2-bench/v1"``.
"""
from __future__ import annotations

import json
import sys


class SchemaError(AssertionError):
    pass


def _require(cond: bool, msg: str):
    if not cond:
        raise SchemaError(msg)


def _typed(row: dict, key: str, types, ctx: str):
    _require(key in row, f"{ctx}: missing key {key!r}")
    _require(isinstance(row[key], types),
             f"{ctx}: {key!r} has type {type(row[key]).__name__}, "
             f"expected {types}")
    return row[key]


def check_phase1(doc: dict):
    _typed(doc, "bt", int, "phase1")
    _typed(doc, "min_pts", int, "phase1")
    rows = _typed(doc, "rows", list, "phase1")
    _require(len(rows) > 0, "phase1: rows is empty")
    smoke = bool(doc.get("smoke", False))
    for i, row in enumerate(rows):
        ctx = f"phase1.rows[{i}]"
        _require(_typed(row, "scenario", str, ctx) in
                 ("uniform", "clustered", "worm"), f"{ctx}: bad scenario")
        _require(_typed(row, "n", int, ctx) > 0, f"{ctx}: n <= 0")
        _require(_typed(row, "eps", (int, float), ctx) > 0, f"{ctx}: eps <= 0")
        frac = _typed(row, "active_frac", (int, float), ctx)
        _require(0.0 <= frac <= 1.0, f"{ctx}: active_frac {frac} not in [0,1]")
        _require(_typed(row, "n_active_pairs", int, ctx)
                 >= _typed(row, "tiles", int, ctx),
                 f"{ctx}: fewer active pairs than (always-active) diagonal")
        if "sweeps_doubling" in row:
            _require(row["sweeps_doubling"] >= 1, f"{ctx}: sweeps < 1")
        if "sweep_reduction" in row:
            _require(row["sweep_reduction"] >= 1.0,
                     f"{ctx}: pointer doubling increased sweeps")
    summary = _typed(doc, "summary", dict, "phase1")
    if not smoke:
        for key in ("clustered_active_frac_65536", "uniform_active_frac_65536"):
            _require(summary.get(key) is not None,
                     f"phase1.summary: {key} missing (non-smoke run)")


def check_phase2(doc: dict):
    _require(doc.get("schema") == "phase2-bench/v1",
             f"phase2: bad schema tag {doc.get('schema')!r}")
    smoke = bool(doc.get("smoke", False))
    rows = _typed(doc, "rows", list, "phase2")
    _require(len(rows) > 0, "phase2: rows is empty")
    layouts = _typed(doc, "layouts", dict, "phase2")
    _require(len(layouts) >= 3, "phase2: fewer than 3 layouts")
    _require(doc.get("backend") == "jit",
             f"phase2: backend tag {doc.get('backend')!r} != 'jit' — the "
             f"artifact must record which repro.ddc backend produced it")
    seen = set()
    for i, row in enumerate(rows):
        ctx = f"phase2.rows[{i}]"
        _require(_typed(row, "backend", str, ctx) == "jit",
                 f"{ctx}: backend {row['backend']!r} != 'jit'")
        layout = _typed(row, "layout", str, ctx)
        _require(layout in layouts, f"{ctx}: unknown layout {layout!r}")
        sched = _typed(row, "schedule", str, ctx)
        _require(sched in ("sync", "async", "tree"), f"{ctx}: bad schedule")
        k = _typed(row, "shards", int, ctx)
        _require(k >= 2, f"{ctx}: shards < 2")
        _require(_typed(row, "wall_ms", (int, float), ctx) > 0,
                 f"{ctx}: wall_ms <= 0")
        _require(_typed(row, "merge_steps", int, ctx) >= 1,
                 f"{ctx}: merge_steps < 1")
        _require(_typed(row, "bytes_exchanged", int, ctx) > 0,
                 f"{ctx}: bytes_exchanged <= 0")
        _require(_typed(row, "matches_host", bool, ctx) is True,
                 f"{ctx}: distributed clustering diverged from ddc_host")
        _require(row["bytes_exchanged"] % _typed(row, "buffer_bytes", int, ctx)
                 == 0,
                 f"{ctx}: bytes_exchanged not a multiple of the wire buffer")
        seen.add((layout, sched, k))
    for layout in layouts:
        for sched in ("sync", "async", "tree"):
            ks = {k for (lo, s, k) in seen if lo == layout and s == sched}
            _require(len(ks) > 0, f"phase2: no rows for {layout}/{sched}")
            if not smoke:
                _require(max(ks) >= 16,
                         f"phase2: {layout}/{sched} never reaches 16 shards")
    summary = _typed(doc, "summary", dict, "phase2")
    _require(summary.get("all_match_host") is True,
             "phase2.summary: all_match_host is not true")
    # Schedule comm-volume ordering must hold wherever both are present:
    # the butterfly moves strictly fewer bytes than the all-gather.
    for layout in layouts:
        for k in {k for (_, _, k) in seen}:
            by = {s: r["bytes_exchanged"] for r in rows for s in [r["schedule"]]
                  if r["layout"] == layout and r["shards"] == k}
            if "sync" in by and "async" in by and k > 2:
                _require(by["async"] < by["sync"],
                         f"phase2: async moved >= bytes than sync at "
                         f"{layout}/k={k}")


def check_serve(doc: dict):
    schema = doc.get("schema")
    _require(schema in ("serve-bench/v1", "serve-bench/v2"),
             f"serve: bad schema tag {schema!r}")
    v2 = schema == "serve-bench/v2"
    smoke = bool(doc.get("smoke", False))
    rows = _typed(doc, "rows", list, "serve")
    _require(len(rows) > 0, "serve: rows is empty")
    layouts = _typed(doc, "layouts", dict, "serve")
    _require(len(layouts) >= 3, "serve: fewer than 3 layouts")
    _require(doc.get("backend") in ("stream", "dist", "mixed"),
             f"serve: backend tag {doc.get('backend')!r} not one of "
             f"stream/dist/mixed — the artifact must record which "
             f"repro.ddc backend(s) produced it")
    seen = set()
    delta_by_cell: dict = {}
    for i, row in enumerate(rows):
        ctx = f"serve.rows[{i}]"
        be = _typed(row, "backend", str, ctx)
        _require(be in ("stream", "dist"),
                 f"{ctx}: backend {be!r} not 'stream' or 'dist'")
        layout = _typed(row, "layout", str, ctx)
        _require(layout in layouts, f"{ctx}: unknown layout {layout!r}")
        k = _typed(row, "shards", int, ctx)
        _require(k >= 2, f"{ctx}: shards < 2")
        for key in ("ingest_ms", "query_ms", "delta_refresh_ms",
                    "full_refresh_ms"):
            _require(_typed(row, key, (int, float), ctx) > 0,
                     f"{ctx}: {key} <= 0")
        delta = _typed(row, "delta_bytes", int, ctx)
        full = _typed(row, "full_bytes", int, ctx)
        _require(delta > 0, f"{ctx}: delta_bytes <= 0")
        _require(_typed(row, "delta_bytes_int8", int, ctx) < delta,
                 f"{ctx}: int8 wire footprint not smaller than f32")
        b = _typed(row, "buffer_bytes", int, ctx)
        _require(full >= k * b,
                 f"{ctx}: full re-merge moved fewer than K buffers")
        _require(_typed(row, "matches_host", bool, ctx) is True,
                 f"{ctx}: streaming clustering diverged from ddc_host")
        _require(_typed(row, "delta_equals_full", bool, ctx) is True,
                 f"{ctx}: delta-maintained matrix != full rebuild")
        if k >= 8:
            _require(delta < full,
                     f"{ctx}: delta-merge moved >= bytes than full "
                     f"re-merge at {k} shards")
        _require(_typed(row, "d2_pairs_delta", int, ctx)
                 <= _typed(row, "d2_pairs_full", int, ctx),
                 f"{ctx}: delta recomputed more slot pairs than full")
        if "query_shards_scanned" in row:
            _require(0 <= _typed(row, "query_shards_scanned", int, ctx)
                     <= _typed(row, "query_shards_possible", int, ctx),
                     f"{ctx}: scanned-shard counter exceeds the possible "
                     f"shard scans")
        if v2:
            # The high-QPS tier rows (DESIGN.md §12): latency quantiles,
            # sustained throughput, and the frozen-twin exactness gate.
            p50 = _typed(row, "p50_ms", (int, float), ctx)
            p99 = _typed(row, "p99_ms", (int, float), ctx)
            _require(0 < p50 <= p99,
                     f"{ctx}: latency quantiles disordered "
                     f"(p50={p50}, p99={p99})")
            _require(_typed(row, "qps", (int, float), ctx) > 0,
                     f"{ctx}: qps <= 0")
            _require(_typed(row, "query_launches", int, ctx) >= 1,
                     f"{ctx}: the tier never launched a kernel")
            _require(_typed(row, "coalesced_requests", int, ctx) >= 0,
                     f"{ctx}: negative coalesced_requests")
            _require(_typed(row, "snapshot_version", int, ctx) >= 1,
                     f"{ctx}: tier reads never saw a published snapshot")
            _require(_typed(row, "jit_cache_bound", int, ctx) >= 1,
                     f"{ctx}: jit_cache_bound < 1")
            _require(_typed(row, "snapshot_matches_sync", bool, ctx) is True,
                     f"{ctx}: snapshot-versioned reads diverged from the "
                     f"sync engine query on the frozen state")
        seen.add((layout, be, k))
        delta_by_cell[(layout, be, k)] = delta
    for layout in layouts:
        ks = {k for (lo, _, k) in seen if lo == layout}
        _require(len(ks) > 0, f"serve: no rows for {layout}")
        if not smoke:
            _require(max(ks) >= 16,
                     f"serve: {layout} never reaches 16 shards")
    # Wherever a stream and a dist row cover the same cell, the dist
    # engine's REAL axis-crossing bytes must not exceed the stream
    # engine's metered delta bound (the tentpole acceptance bound).
    for (layout, be, k), delta in delta_by_cell.items():
        if be != "dist":
            continue
        ref = delta_by_cell.get((layout, "stream", k))
        if ref is not None:
            _require(delta <= ref,
                     f"serve: dist axis bytes {delta} exceed the stream "
                     f"delta bound {ref} at {layout}/k={k}")
    summary = _typed(doc, "summary", dict, "serve")
    _require(summary.get("all_match_host") is True,
             "serve.summary: all_match_host is not true")
    if v2:
        _require(summary.get("all_snapshot_match_sync") is True,
                 "serve.summary: all_snapshot_match_sync is not true")
    _require(summary.get("delta_lt_full_at_high_shards") is True,
             "serve.summary: delta-merge did not beat full re-merge")
    if doc.get("backend") == "mixed":
        _require(summary.get("dist_axis_bytes_le_stream_delta") is True,
                 "serve.summary: dist axis bytes exceeded the stream "
                 "delta bound")


def check_hierarchy(doc: dict):
    _require(doc.get("schema") == "hierarchy-bench/v1",
             f"hierarchy: bad schema tag {doc.get('schema')!r}")
    smoke = bool(doc.get("smoke", False))
    rows = _typed(doc, "rows", list, "hierarchy")
    _require(len(rows) > 0, "hierarchy: rows is empty")
    _typed(doc, "cfg", dict, "hierarchy")
    seen = set()
    for i, row in enumerate(rows):
        ctx = f"hierarchy.rows[{i}]"
        k = _typed(row, "shards", int, ctx)
        _require(k >= 2, f"{ctx}: shards < 2")
        d = _typed(row, "degree", int, ctx)
        _require(d >= 2 and d & (d - 1) == 0,
                 f"{ctx}: degree {d} not a power of two >= 2")
        _require(_typed(row, "depth", int, ctx) >= 1, f"{ctx}: depth < 1")
        _require(_typed(row, "n_nodes", int, ctx) >= row["depth"],
                 f"{ctx}: fewer nodes than levels")
        for key in ("flat_build_ms", "hier_build_ms", "flat_refresh_ms",
                    "hier_refresh_ms", "flat_churn_ms", "hier_churn_ms"):
            _require(_typed(row, key, (int, float), ctx) > 0,
                     f"{ctx}: {key} <= 0")
        b = _typed(row, "buffer_bytes", int, ctx)
        for key in ("flat_refresh_bytes", "hier_refresh_bytes",
                    "flat_churn_bytes", "hier_churn_bytes",
                    "flat_bottleneck_bytes", "hier_bottleneck_bytes"):
            _require(_typed(row, key, int, ctx) >= b,
                     f"{ctx}: {key} below one wire buffer")
        # The §13 exactness gates: hierarchical must be indistinguishable
        # from flat except through the comm meter.
        for key in ("maps_match", "valid_match", "sizes_match",
                    "root_d2_exact"):
            _require(_typed(row, key, bool, ctx) is True,
                     f"{ctx}: {key} is not true — tree diverged from flat")
        _require(_typed(row, "overflow", bool, ctx) is False,
                 f"{ctx}: slot budget overflowed")
        # The §13 scaling gates: past 32 shards the tree must win BOTH
        # steady-state bytes and latency.
        if k >= 32:
            _require(row["hier_refresh_bytes"] < row["flat_refresh_bytes"],
                     f"{ctx}: tree moved >= bytes than flat at {k} shards")
            _require(row["hier_refresh_ms"] < row["flat_refresh_ms"],
                     f"{ctx}: tree refresh slower than flat at {k} shards")
        seen.add((k, d))
    ks = {k for (k, _) in seen}
    _require(len({d for (_, d) in seen}) >= 2,
             "hierarchy: fewer than 2 tree degrees")
    _require(max(ks) >= 32, "hierarchy: sweep never reaches 32 shards")
    if not smoke:
        _require(max(ks) >= 256, "hierarchy: full sweep never reaches "
                                 "256 shards")
    summary = _typed(doc, "summary", dict, "hierarchy")
    for key in ("all_equiv_flat", "hier_wins_bytes_ge32",
                "hier_wins_latency_ge32"):
        _require(summary.get(key) is True,
                 f"hierarchy.summary: {key} is not true")


def check_recovery(doc: dict):
    _require(doc.get("schema") == "recovery-bench/v1",
             f"recovery: bad schema tag {doc.get('schema')!r}")
    smoke = bool(doc.get("smoke", False))
    rows = _typed(doc, "rows", list, "recovery")
    _require(len(rows) > 0, "recovery: rows is empty")
    layouts = _typed(doc, "layouts", dict, "recovery")
    _require(len(layouts) >= 1, "recovery: no layouts recorded")
    _require(doc.get("backend") in ("stream", "dist", "mixed"),
             f"recovery: backend tag {doc.get('backend')!r} not one of "
             f"stream/dist/mixed")
    seen = set()
    for i, row in enumerate(rows):
        ctx = f"recovery.rows[{i}]"
        be = _typed(row, "backend", str, ctx)
        _require(be in ("stream", "dist"),
                 f"{ctx}: backend {be!r} not 'stream' or 'dist'")
        layout = _typed(row, "layout", str, ctx)
        _require(layout in layouts, f"{ctx}: unknown layout {layout!r}")
        k = _typed(row, "shards", int, ctx)
        _require(k >= 2, f"{ctx}: shards < 2")
        for key in ("mttr_ms", "healthy_query_ms", "degraded_query_ms"):
            _require(_typed(row, key, (int, float), ctx) > 0,
                     f"{ctx}: {key} <= 0")
        _require(_typed(row, "recovered_bitexact", bool, ctx) is True,
                 f"{ctx}: post-recovery state diverged from the "
                 f"fault-free twin")
        _require(_typed(row, "journal_entries", int, ctx) > 0,
                 f"{ctx}: the write-ahead journal recorded nothing")
        _require(_typed(row, "quarantine_events", int, ctx) >= 1,
                 f"{ctx}: the kill fault never quarantined a shard")
        seen.add((layout, be, k))
    for layout in layouts:
        ks = {k for (lo, _, k) in seen if lo == layout}
        _require(len(ks) > 0, f"recovery: no rows for {layout}")
        if not smoke:
            _require(max(ks) >= 8,
                     f"recovery: {layout} never reaches 8 shards")
    if not smoke:
        _require(len(layouts) >= 3, "recovery: fewer than 3 layouts "
                                    "(non-smoke run)")
    summary = _typed(doc, "summary", dict, "recovery")
    _require(summary.get("all_recovered_bitexact") is True,
             "recovery.summary: all_recovered_bitexact is not true")


def check_tracking(doc: dict):
    _require(doc.get("schema") == "tracking-bench/v1",
             f"tracking: bad schema tag {doc.get('schema')!r}")
    smoke = bool(doc.get("smoke", False))
    rows = _typed(doc, "rows", list, "tracking")
    _require(len(rows) > 0, "tracking: rows is empty")
    layouts = _typed(doc, "layouts", dict, "tracking")
    _require(len(layouts) >= 3, "tracking: fewer than 3 trajectory layouts")
    _require(doc.get("backend") == "stream",
             f"tracking: backend tag {doc.get('backend')!r} != 'stream'")
    seen_layouts, seen_blobs, max_k = set(), set(), 0
    for i, row in enumerate(rows):
        ctx = f"tracking.rows[{i}]"
        kind = _typed(row, "kind", str, ctx)
        _require(kind in ("layout", "scaling"), f"{ctx}: bad kind {kind!r}")
        layout = _typed(row, "layout", str, ctx)
        _require(layout in layouts, f"{ctx}: unknown layout {layout!r}")
        k = _typed(row, "shards", int, ctx)
        _require(k >= 2, f"{ctx}: shards < 2")
        _require(_typed(row, "generations", int, ctx) >= 2,
                 f"{ctx}: fewer than 2 tracked generations")
        _require(_typed(row, "births", int, ctx) >= 1, f"{ctx}: no births")
        for key in ("deaths", "merges", "splits", "continuations"):
            _require(_typed(row, key, int, ctx) >= 0, f"{ctx}: {key} < 0")
        _require(_typed(row, "n_clusters", int, ctx) >= 1,
                 f"{ctx}: no live clusters at end of run")
        _require(_typed(row, "tracks_total", int, ctx) >= row["births"],
                 f"{ctx}: fewer IDs issued than birth events — IDs reused")
        stab = _typed(row, "id_stability", (int, float), ctx)
        _require(0.0 <= stab <= 1.0,
                 f"{ctx}: id_stability {stab} not in [0,1]")
        _require(_typed(row, "match_ms_mean", (int, float), ctx) > 0,
                 f"{ctx}: match_ms_mean <= 0")
        if kind == "layout":
            seen_layouts.add(layout)
            max_k = max(max_k, k)
            if layout == "drifting_blobs":
                # The acceptance gate: stable IDs on the layout built to
                # have none of the churn excuses.
                _require(stab >= 0.95,
                         f"{ctx}: drifting_blobs id_stability {stab} < 0.95")
            if layout == "merging_crowds":
                _require(row["merges"] >= 1 and row["splits"] >= 1,
                         f"{ctx}: merging_crowds produced no merge/split")
        else:
            seen_blobs.add(_typed(row, "n_blobs", int, ctx))
    _require(seen_layouts >= set(layouts),
             f"tracking: layout rows missing {set(layouts) - seen_layouts}")
    _require(len(seen_blobs) >= 2,
             "tracking: scaling sweep covers < 2 cluster counts")
    if not smoke:
        _require(max_k >= 8, "tracking: layout sweep never reaches 8 shards")
        _require(max(seen_blobs) >= 8,
                 "tracking: scaling sweep never reaches 8 blobs")
    summary = _typed(doc, "summary", dict, "tracking")
    _require(summary.get("stability_gate") is True,
             "tracking.summary: stability_gate is not true")
    _require(_typed(summary, "drifting_stability_min", (int, float),
                    "tracking.summary") >= 0.95,
             "tracking.summary: drifting_blobs ID stability below 0.95")


def check_file(path: str):
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") == "phase2-bench/v1":
        check_phase2(doc)
        return "phase2"
    if doc.get("schema") in ("serve-bench/v1", "serve-bench/v2"):
        check_serve(doc)
        return "serve"
    if doc.get("schema") == "recovery-bench/v1":
        check_recovery(doc)
        return "recovery"
    if doc.get("schema") == "hierarchy-bench/v1":
        check_hierarchy(doc)
        return "hierarchy"
    if doc.get("schema") == "tracking-bench/v1":
        check_tracking(doc)
        return "tracking"
    if "bt" in doc:
        check_phase1(doc)
        return "phase1"
    raise SchemaError(f"{path}: unrecognised benchmark artifact")


def main(argv: list[str]) -> int:
    if not argv:
        print("usage: check_bench.py BENCH_*.json [...]", file=sys.stderr)
        return 2
    status = 0
    for path in argv:
        try:
            kind = check_file(path)
            print(f"OK {path} ({kind})")
        except (SchemaError, OSError, json.JSONDecodeError) as e:
            print(f"FAIL {path}: {e}", file=sys.stderr)
            status = 1
    return status


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))

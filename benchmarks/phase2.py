"""Phase-2 (contour aggregation) scenario sweep — the perf + comm-volume
baseline for the batched merge engine.

Four spatial layouts (rings with a nested disc, linked ovals, worm,
noise-heavy) × shard counts 2–32 × all three merge schedules
(sync all-gather, async butterfly, tree).  Per cell we record:

* **wall-clock** of the full distributed DDC call (CPU host devices —
  a proxy ordering, like BENCH_phase1.json: the MXU/ICI wins land on
  TPU, the CPU refs here prove the math and the schedule shapes);
* **merge-step count** and **bytes-exchanged** from the trace-time
  ``CommMeter`` (exact: permutation lists and buffer shapes are static);
* **matches_host** — the distributed labels must reproduce ``ddc_host``'s
  global clustering *bit-exactly* (identical partition of the points,
  identical noise set) on every cell.  The sweep hard-fails otherwise.

Writes ``BENCH_phase2.json`` next to the repo root so future PRs have a
trajectory to regress against.  ``--smoke`` runs a tiny subset for CI.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _parse_args(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="tiny CI subset: 2/4 shards only")
    p.add_argument("--out", default=None, help="output JSON path")
    return p.parse_args(argv)


_ARGS = _parse_args()
# Smoke keeps the full layouts (their eps/min_pts are tuned to the point
# density at N) and trims the shard sweep — the cost driver is the
# high-shard sync merge, not N.
SHARDS = (2, 4) if _ARGS.smoke else (2, 4, 8, 16, 32)
N = 2048
# The device count must be pinned before jax initialises; APPEND to any
# pre-existing XLA_FLAGS (setdefault would silently drop the override
# and every >1-device mesh below would fail).
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") +
    f" --xla_force_host_platform_device_count={max(SHARDS)}"
).strip()

import jax                     # noqa: E402
import jax.numpy as jnp        # noqa: E402
import numpy as np             # noqa: E402

from repro.core import ddc     # noqa: E402
from repro.data import spatial  # noqa: E402
from repro.ddc import DDC, DDCConfig  # noqa: E402

SCHEDULES = ("sync", "async", "tree")

# Per-layout generators + DDC parameters: the single shared table in
# data/spatial.py (also consumed by tests/_phase2_script.py, so the
# benchmark and the equivalence suite always run the same tuning).
LAYOUTS = spatial.PHASE2_LAYOUTS
same_partition = ddc.same_clustering


def bench_cell(pts: np.ndarray, spec: dict, k: int, schedule: str,
               host_labels: np.ndarray, reps: int) -> dict:
    cfg = DDCConfig(
        eps=spec["eps"], min_pts=spec["min_pts"], grid=spec["grid"],
        max_clusters=spec["max_clusters"], max_verts=spec["max_verts"],
        schedule=schedule, backend="jit", shards=k,
    ).validate()
    meter = ddc.CommMeter()
    model = DDC(cfg, meter=meter)
    run = model.backend.make_runner(len(pts))
    x = jnp.asarray(pts)
    msk = jnp.ones(len(pts), bool)
    compiled = run.lower(
        jax.ShapeDtypeStruct(x.shape, x.dtype),
        jax.ShapeDtypeStruct(msk.shape, bool),
    ).compile()

    t0 = time.perf_counter()
    out = compiled(x, msk)
    jax.block_until_ready(out)
    first_ms = (time.perf_counter() - t0) * 1e3

    best_ms = first_ms
    for _ in range(reps):
        t0 = time.perf_counter()
        out = compiled(x, msk)
        jax.block_until_ready(out)
        best_ms = min(best_ms, (time.perf_counter() - t0) * 1e3)

    glabels, gcs, _ = out
    labels = np.asarray(glabels)
    stats = meter.snapshot()
    return {
        "backend": cfg.backend,
        "schedule": schedule,
        "shards": k,
        "wall_ms": round(best_ms, 1),
        "first_call_ms": round(first_ms, 1),
        "merge_steps": stats["merge_steps"],
        "merge_slots": stats["merge_slots"],
        "bytes_exchanged": stats["bytes_total"],
        "collectives": stats["collectives"],
        "buffer_bytes": cfg.core().buffer_bytes(),
        "n_clusters": int(np.asarray(gcs.valid).sum()),
        "overflow": bool(np.asarray(gcs.overflow)),
        "matches_host": same_partition(labels, host_labels),
    }


def run(out_path: str | None = None, print_rows: bool = True):
    rows = []
    layouts_meta = {}
    for name, spec in LAYOUTS.items():
        pts = spec["make"](N)
        layouts_meta[name] = {
            k: spec[k] for k in ("eps", "min_pts", "grid", "max_verts",
                                 "max_clusters")
        } | {"n": len(pts)}
        for k in SHARDS:
            # The oracle goes through the same front door: the host
            # backend wraps ddc_host on the identical block partition.
            host_labels = DDC(DDCConfig(
                eps=spec["eps"], min_pts=spec["min_pts"], grid=spec["grid"],
                max_clusters=spec["max_clusters"],
                max_verts=spec["max_verts"], backend="host", shards=k,
            )).fit(pts).labels_
            for schedule in SCHEDULES:
                reps = 1 if k >= 32 else 2
                row = bench_cell(pts, spec, k, schedule, host_labels, reps)
                row["layout"] = name
                rows.append(row)
                if print_rows:
                    print(f"phase2_{name}_k{k}_{row['schedule']}: "
                          f"wall={row['wall_ms']}ms steps={row['merge_steps']} "
                          f"bytes={row['bytes_exchanged']} "
                          f"clusters={row['n_clusters']} "
                          f"match={row['matches_host']}")

    all_match = all(r["matches_host"] for r in rows)
    summary = {
        "all_match_host": all_match,
        "n_layouts": len(LAYOUTS),
        "max_shards": max(SHARDS),
        "schedules": list(SCHEDULES),
        "sync_vs_async_bytes_at_max": _bytes_ratio(rows, max(SHARDS)),
    }
    out = {
        "schema": "phase2-bench/v1",
        "smoke": bool(_ARGS.smoke),
        "backend": "jit",
        "n": N,
        "shards": list(SHARDS),
        "layouts": layouts_meta,
        "rows": rows,
        "summary": summary,
    }
    if out_path is None:
        out_path = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "BENCH_phase2.json")
    with open(out_path, "w") as f:
        json.dump(out, f, indent=1)
    if print_rows:
        print("summary:", json.dumps(summary))
        print("wrote", out_path)
    if not all_match:
        bad = [(r["layout"], r["shards"], r["schedule"])
               for r in rows if not r["matches_host"]]
        print("HOST MISMATCH:", bad, file=sys.stderr)
        raise SystemExit(1)
    return rows


def _bytes_ratio(rows, k):
    by = {r["schedule"]: r["bytes_exchanged"] for r in rows
          if r["shards"] == k and r["layout"] == next(iter(LAYOUTS))}
    if by.get("async"):
        return round(by["sync"] / by["async"], 2)
    return None


if __name__ == "__main__":
    run(out_path=_ARGS.out)

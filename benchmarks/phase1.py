"""Phase-1 (local DBSCAN) scenario sweep — the perf baseline for the
block-sparse + pointer-doubling optimisations.

Three spatial layouts × n ∈ {4k, 16k, 64k}:

* ``uniform``   — worst case for pruning (points everywhere);
* ``clustered`` — the paper's regime: compact blobs, most tile pairs
  provably farther than ε apart;
* ``worm``      — a long thin curve: core-graph diameter ~ curve length/ε,
  the worst case for plain label sweeping.

Per cell we record the **active-tile fraction** (share of tile pairs the
block-sparse kernels must touch — the MXU-work proxy; wall-clock savings
land on TPU, the CPU refs here only prove the math) and
**sweeps-to-convergence** with and without pointer doubling (full
clustering runs are capped at 16k points — a plain-sweep 64k run would be
hundreds of O(n²) sweeps on this CPU container).

Writes ``BENCH_phase1.json`` next to the repo root so future PRs have a
trajectory to regress against.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dbscan as db
from repro.data import spatial
from repro.kernels import ops

BT = 512
EPS = {"uniform": 0.008, "clustered": 0.02, "worm": 0.02}
MIN_PTS = 5
SWEEP_NS = (4096, 16384)       # full clustering runs
PLAIN_NS = (4096,)             # no-doubling runs (diameter-many sweeps)
FRAC_NS = (4096, 16384, 65536)
# CI smoke subset: one size per measurement family.
SMOKE_SWEEP_NS = (4096,)
SMOKE_PLAIN_NS = (4096,)
SMOKE_FRAC_NS = (4096,)


def make_points(scenario: str, n: int, seed: int = 0) -> np.ndarray:
    if scenario == "uniform":
        rng = np.random.default_rng(seed)
        return rng.uniform(0, 1, (n, 2)).astype(np.float32)
    if scenario == "clustered":
        return spatial.make_clustered(n, seed=seed)
    if scenario == "worm":
        return spatial.make_worm(n, seed=seed)
    raise ValueError(scenario)


def active_fraction(pts: np.ndarray, eps: float) -> tuple[float, int, int]:
    """Morton-sort + bbox prune only (cheap at any n) — same preamble the
    block-sparse dbscan path runs (dbscan.spatial_sort)."""
    sp, sm, _ = db.spatial_sort(jnp.asarray(pts), jnp.ones(len(pts), bool), BT)
    pairs = ops.build_tile_pairs(sp, sm, eps, bt=BT)
    return float(pairs.frac), int(pairs.n_active), sp.shape[0] // BT


def run_clustering(pts: np.ndarray, eps: float, doubling: bool):
    x = jnp.asarray(pts)
    m = jnp.ones(len(pts), bool)
    t0 = time.perf_counter()
    res = db.dbscan(x, m, eps, MIN_PTS, block_sparse="never",
                    pointer_doubling=doubling)
    jax.block_until_ready(res.labels)
    ms = (time.perf_counter() - t0) * 1e3
    return int(res.n_sweeps), int(res.n_clusters), ms


def run(print_rows: bool = True, out_path: str | None = None,
        smoke: bool = False):
    frac_ns = SMOKE_FRAC_NS if smoke else FRAC_NS
    sweep_ns = SMOKE_SWEEP_NS if smoke else SWEEP_NS
    plain_ns = SMOKE_PLAIN_NS if smoke else PLAIN_NS
    rows = []
    for scenario in ("uniform", "clustered", "worm"):
        eps = EPS[scenario]
        for n in frac_ns:
            pts = make_points(scenario, n)
            frac, n_active, tiles = active_fraction(pts, eps)
            row = {
                "scenario": scenario, "n": n, "eps": eps, "bt": BT,
                "tiles": tiles, "n_active_pairs": n_active,
                "active_frac": round(frac, 4),
            }
            if n in sweep_ns:
                sweeps, clusters, ms = run_clustering(pts, eps, doubling=True)
                row.update(sweeps_doubling=sweeps, n_clusters=clusters,
                           ms_doubling=round(ms, 1))
            if n in plain_ns:
                sweeps_p, _, ms_p = run_clustering(pts, eps, doubling=False)
                row.update(sweeps_plain=sweeps_p, ms_plain=round(ms_p, 1))
                if "sweeps_doubling" in row:  # PLAIN_NS need not ⊆ SWEEP_NS
                    row["sweep_reduction"] = round(
                        sweeps_p / max(row["sweeps_doubling"], 1), 2)
            rows.append(row)
            if print_rows:
                print(f"phase1_{scenario}_{n}: frac={frac:.3f} "
                      + " ".join(f"{k}={row[k]}" for k in
                                 ("sweeps_plain", "sweeps_doubling",
                                  "sweep_reduction") if k in row))

    # Summary entries are None when their size wasn't in this run's sweep
    # (smoke mode); check_bench.py only requires them on full runs.
    summary = {
        "worm_sweep_reduction_4096": next(
            (r["sweep_reduction"] for r in rows
             if r["scenario"] == "worm" and r["n"] == 4096
             and "sweep_reduction" in r), None),
        "clustered_active_frac_65536": next(
            (r["active_frac"] for r in rows
             if r["scenario"] == "clustered" and r["n"] == 65536), None),
        "uniform_active_frac_65536": next(
            (r["active_frac"] for r in rows
             if r["scenario"] == "uniform" and r["n"] == 65536), None),
    }
    out = {"bt": BT, "min_pts": MIN_PTS, "smoke": smoke, "rows": rows,
           "summary": summary}
    if out_path is None:
        out_path = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "BENCH_phase1.json")
    with open(out_path, "w") as f:
        json.dump(out, f, indent=1)
    if print_rows:
        print("summary:", json.dumps(summary))
        print("wrote", out_path)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI subset: n=4096 only")
    ap.add_argument("--out", default=None, help="output JSON path")
    args = ap.parse_args()
    run(out_path=args.out, smoke=args.smoke)

"""Paper Tables 3-6: per-machine step1/step2/total under sync vs async
communications, scenarios I-IV, on the calibrated heterogeneous-cluster
simulator (core/simulate.py).  Emits one table per scenario."""
from __future__ import annotations

from repro.core import partitioner, simulate as sim

PAPER_TOTALS = {  # (sync, async) total exec time, ms — from the paper
    "I": (22374, 21824),
    "II": (22243, 21865),
    "III": (57248, 57186),
    "IV": (1761, 1772),
}


def run(print_rows=True) -> list[dict]:
    rows = []
    for scen in ("I", "II", "III", "IV"):
        sizes = partitioner.scenario_sizes(scen)
        s = sim.simulate(sim.PAPER_MACHINES, sizes, "sync")
        a = sim.simulate(sim.PAPER_MACHINES, sizes, "async")
        if print_rows:
            print(f"\n== Scenario {scen} (sizes={sizes}) ==")
            print(f"{'machine':>8} {'DS':>6} | {'sync s1':>8} {'sync s2':>8} "
                  f"{'sync tot':>9} | {'async s1':>8} {'async s2':>8} {'async tot':>9}")
            for i, m in enumerate(sim.PAPER_MACHINES):
                print(f"{m.name[:8]:>8} {sizes[i]:>6} | {s.step1[i]:8.0f} "
                      f"{s.step2[i]:8.0f} {s.total[i]:9.0f} | {a.step1[i]:8.0f} "
                      f"{a.step2[i]:8.0f} {a.total[i]:9.0f}")
            ps, pa = PAPER_TOTALS[scen]
            print(f"   TOTAL          | sync {s.makespan:9.0f} (paper {ps}) | "
                  f"async {a.makespan:9.0f} (paper {pa}) | "
                  f"ratio {a.makespan/s.makespan:.3f} (paper {pa/ps:.3f})")
        rows.append({
            "name": f"scenario_{scen}",
            "sync_ms": s.makespan, "async_ms": a.makespan,
            "ratio": a.makespan / s.makespan,
            "paper_ratio": PAPER_TOTALS[scen][1] / PAPER_TOTALS[scen][0],
            "sync_idle_ms": sum(s.idle) / len(s.idle),
            "async_idle_ms": sum(a.idle) / len(a.idle),
        })
    return rows


if __name__ == "__main__":
    run()

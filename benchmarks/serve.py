"""Streaming serve-engine benchmark — the read/write latency and
delta-merge comm-volume baseline, for the host-driven ``stream`` engine
and the device-resident ``dist`` engine.

Four spatial layouts (the shared ``PHASE2_LAYOUTS`` table) × shard
counts 2–16.  Per cell the service ingests the full layout in
round-robin batches with an incremental refresh after every batch, then
measures steady state:

* **ingest_ms** — wall-clock of (ingest one batch + delta refresh);
* **query_ms** — wall-clock of a 256-point query batch (bbox-routed);
* **delta vs full** — bytes on the wire and wall-clock for a
  single-dirty-shard delta refresh against a from-scratch re-merge
  (both exact, same global state — the delta path's whole point).  For
  the ``stream`` rows the bytes are the host-metered model; for the
  ``dist`` rows they are REAL axis-crossing transfers (dirty
  ClusterSets up, slot-map rows down), so equal counts per cell are the
  tentpole claim: moving the data plane onto devices adds no bytes;
* **matches_host** — the final streaming labels must reproduce batch
  ``ddc_host`` on the live points bit-exactly (hard-fails otherwise),
  and the delta-maintained distance matrix must equal the recomputed
  one bit-for-bit (``delta_equals_full``);
* **p50/p99/QPS** — the high-QPS tier (DESIGN.md §12): a stream of
  small requests through the bounded ``QueryTier`` queue, coalesced
  into batched snapshot reads.  Every tier answer is re-checked
  bit-exactly against the sync engine query on the same frozen state
  (``snapshot_matches_sync``, hard-fails otherwise).  ``--qps`` raises
  the request count for a sustained-QPS measurement.

Writes ``BENCH_serve.json`` (schema ``serve-bench/v2``,
``benchmarks/check_bench.py``).  ``--smoke`` trims the shard sweep for
CI; ``--backend`` picks stream/dist/both (dist forces a CPU device-count
override before jax initialises: 8 for smoke, 16 for the full sweep).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _parse_args(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="tiny CI subset: 2/4 shards only")
    p.add_argument("--backend", choices=("stream", "dist", "both"),
                   default="both", help="which serve engine(s) to bench")
    p.add_argument("--qps", action="store_true",
                   help="raise the tier request count for a sustained-QPS "
                        "measurement (latency rows are always present)")
    p.add_argument("--out", default=None, help="output JSON path")
    return p.parse_args(argv)


_ARGS = None
if __name__ == "__main__":
    # The dist engine pins one shard per device; the CPU device count
    # must be forced before jax initialises (i.e. before the repro
    # imports below).
    _ARGS = _parse_args()
    if _ARGS.backend in ("dist", "both"):
        _n = 8 if _ARGS.smoke else 16
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={_n}").strip()

import numpy as np                                    # noqa: E402

from repro.core import ddc                            # noqa: E402
from repro.data import spatial                        # noqa: E402
from repro.ddc import DDC, DDCConfig                  # noqa: E402
from repro.parallel import compress                   # noqa: E402
from repro.serve import query_tier as qt              # noqa: E402

N = 2048
BATCH = 256
QUERIES = 256
REQ_POINTS = 32          # query points per tier request
LAYOUTS = spatial.PHASE2_LAYOUTS


def bench_tier(model, svc, k: int, n_requests: int) -> dict:
    """The high-QPS tier rows (DESIGN.md §12): p50/p99 request latency
    and sustained QPS through the bounded queue, answered from the
    published snapshot in coalesced pow2-bucketed launches — then every
    answer re-checked bit-exactly against the sync engine query on the
    same frozen state."""
    tier = qt.QueryTier(svc, max_queries=QUERIES,
                        max_staleness=float("inf"))
    svc.read_snapshot()          # publish the frozen state under test
    rng = np.random.default_rng(1)
    req_pts = [rng.uniform(0, 1, (REQ_POINTS, 2)).astype(np.float32)
               for _ in range(n_requests)]
    tier.query(req_pts[0])       # compile the bucketed kernel
    handles = []
    t0 = time.perf_counter()
    for off in range(0, n_requests, 8):
        burst = [tier.submit(p) for p in req_pts[off:off + 8]]
        tier.drain()
        handles.extend(burst)
    wall_s = time.perf_counter() - t0
    lat = np.array([h.result.latency_ms for h in handles])
    matches = all(
        np.array_equal(np.asarray(h.result), svc.query(p, legacy=True))
        for p, h in zip(req_pts, handles))
    counters = tier.counters()
    return {
        "qps_requests": n_requests,
        "qps": round(n_requests / wall_s, 1),
        "p50_ms": round(float(np.percentile(lat, 50)), 3),
        "p99_ms": round(float(np.percentile(lat, 99)), 3),
        "query_launches": counters["query_launches"],
        "coalesced_requests": counters["coalesced_requests"],
        "snapshot_version": handles[-1].result.version,
        "jit_cache_bound": tier.cache_bound(k),
        "snapshot_matches_sync": bool(matches),
    }


def bench_cell(name: str, spec: dict, k: int, backend: str,
               reps: int = 3, qps_requests: int = 24) -> dict:
    pts = spec["make"](N)
    cap = spatial.shard_capacity(N, k)
    batch = min(BATCH, cap)      # high shard counts shrink the buffers
    cfg = DDCConfig(
        eps=spec["eps"], min_pts=spec["min_pts"], grid=spec["grid"],
        max_clusters=spec["max_clusters"], max_verts=spec["max_verts"],
        backend=backend, shards=k, capacity=cap, max_batch=batch,
        max_queries=QUERIES).validate()
    meter = ddc.CommMeter()
    model = DDC(cfg, meter=meter)
    svc = model.service

    batches = spatial.stream_batches(pts, k, batch)
    # First batch+refresh compiles everything; time the rest.
    ingest_ms = []
    for i, (shard, chunk) in enumerate(batches):
        t0 = time.perf_counter()
        model.partial_fit(shard, chunk)
        svc.refresh()
        dt = (time.perf_counter() - t0) * 1e3
        if i > 0:
            ingest_ms.append(dt)

    # Steady-state single-dirty-shard delta refresh vs full re-merge.
    # Re-ingesting a duplicate point keeps the stream live; the final
    # equivalence check below runs on whatever is live, so duplicates
    # are counted on both sides.
    meter.reset()
    model.partial_fit(0, pts[:1])
    svc.refresh()
    delta_bytes = meter.snapshot()["bytes_total"]
    delta_ms = min_time(
        lambda: (model.partial_fit(0, pts[:1]), svc.refresh()), reps)

    # Exactness: the delta-maintained matrix vs a from-scratch rebuild of
    # the SAME state, then time the full path.
    d2_delta = np.asarray(svc.pair_d2)
    meter.reset()
    svc.remerge_full()
    full_bytes = meter.snapshot()["bytes_total"]
    d2_full = np.asarray(svc.pair_d2)
    full_ms = min_time(svc.remerge_full, reps)

    rng = np.random.default_rng(0)
    q = rng.uniform(0, 1, (QUERIES, 2)).astype(np.float32)
    model.query(q)   # compile
    query_ms = min_time(lambda: model.query(q), reps)
    routing = svc.routing_stats()

    tier_row = bench_tier(model, svc, k, qps_requests)

    live_pts, parts, labels = svc.live()
    host_labels, _, _ = ddc.ddc_host(
        live_pts, len(parts), spec["eps"], spec["min_pts"],
        partition=parts, contour="grid")

    return {
        "backend": cfg.backend,
        "layout": name,
        "shards": k,
        "n_live": int(len(live_pts)),
        "ingest_ms": round(float(np.mean(ingest_ms)), 2),
        "query_ms": round(query_ms, 2),
        "delta_refresh_ms": round(delta_ms, 2),
        "full_refresh_ms": round(full_ms, 2),
        "delta_bytes": delta_bytes,
        "full_bytes": full_bytes,
        "delta_bytes_int8": compress.pytree_wire_bytes_int8(svc.local_set(0))
        + k * cfg.max_clusters * 4,
        "buffer_bytes": cfg.core().buffer_bytes(),
        "d2_pairs_delta": cfg.max_clusters * k * cfg.max_clusters,
        "d2_pairs_full": (k * cfg.max_clusters) ** 2,
        "query_shards_scanned": routing["query_shards_scanned"],
        "query_shards_possible": routing["query_shards_possible"],
        "n_clusters": int(np.asarray(svc.global_set.valid).sum()),
        "matches_host": ddc.same_clustering(labels, host_labels),
        "delta_equals_full": bool(np.array_equal(d2_delta, d2_full)),
    } | tier_row


def min_time(fn, reps: int) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, (time.perf_counter() - t0) * 1e3)
    return best


def run(smoke: bool = False, out_path: str | None = None,
        backend: str = "both", print_rows: bool = True, qps: bool = False):
    shards = (2, 4) if smoke else (2, 4, 8, 16)
    backends = ("stream", "dist") if backend == "both" else (backend,)
    qps_requests = 96 if qps else 24
    rows = []
    layouts_meta = {}
    for name, spec in LAYOUTS.items():
        layouts_meta[name] = {
            key: spec[key] for key in ("eps", "min_pts", "grid", "max_verts",
                                       "max_clusters")
        } | {"n": N}
        for be in backends:
            for k in shards:
                row = bench_cell(name, spec, k, be,
                                 qps_requests=qps_requests)
                rows.append(row)
                if print_rows:
                    print(f"serve_{be}_{name}_k{k}: "
                          f"ingest={row['ingest_ms']}ms "
                          f"query={row['query_ms']}ms "
                          f"delta={row['delta_bytes']}B/{row['delta_refresh_ms']}ms "
                          f"full={row['full_bytes']}B/{row['full_refresh_ms']}ms "
                          f"p50={row['p50_ms']}ms p99={row['p99_ms']}ms "
                          f"qps={row['qps']} "
                          f"match={row['matches_host']} "
                          f"snap={row['snapshot_matches_sync']}")

    all_match = all(r["matches_host"] and r["delta_equals_full"] for r in rows)
    all_snap = all(r["snapshot_matches_sync"] for r in rows)
    high_k = [r for r in rows if r["shards"] >= 8]
    summary = {
        "all_match_host": all_match,
        "all_snapshot_match_sync": all_snap,
        "n_layouts": len(LAYOUTS),
        "max_shards": max(shards),
        "delta_lt_full_at_high_shards": all(
            r["delta_bytes"] < r["full_bytes"] for r in high_k) or not high_k,
        "mean_full_over_delta_bytes": round(float(np.mean(
            [r["full_bytes"] / r["delta_bytes"] for r in rows])), 2),
    }
    stream_cells = {(r["layout"], r["shards"]): r["delta_bytes"]
                    for r in rows if r["backend"] == "stream"}
    if backend == "both":
        # The tentpole claim: the device-resident engine's REAL
        # axis-crossing bytes never exceed the stream engine's metered
        # delta bound on the identical workload.
        dist_ok = all(
            r["delta_bytes"] <= stream_cells[(r["layout"], r["shards"])]
            for r in rows if r["backend"] == "dist")
        summary["dist_axis_bytes_le_stream_delta"] = dist_ok
    out = {
        "schema": "serve-bench/v2",
        "smoke": bool(smoke),
        "backend": "mixed" if backend == "both" else backend,
        "n": N,
        "batch": BATCH,
        "shards": list(shards),
        "layouts": layouts_meta,
        "rows": rows,
        "summary": summary,
    }
    if out_path is None:
        out_path = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "BENCH_serve.json")
    with open(out_path, "w") as f:
        json.dump(out, f, indent=1)
    if print_rows:
        print("summary:", json.dumps(summary))
        print("wrote", out_path)
    failed = not all_match or not all_snap \
        or not summary["delta_lt_full_at_high_shards"] \
        or not summary.get("dist_axis_bytes_le_stream_delta", True)
    if failed:
        bad = [(r["backend"], r["layout"], r["shards"]) for r in rows
               if not (r["matches_host"] and r["delta_equals_full"]
                       and r["snapshot_matches_sync"])]
        if backend == "both":
            bad += [("dist>stream", r["layout"], r["shards"])
                    for r in rows if r["backend"] == "dist"
                    and r["delta_bytes"]
                    > stream_cells[(r["layout"], r["shards"])]]
        print("SERVE BENCH FAILED:", bad, file=sys.stderr)
        raise SystemExit(1)
    return rows


if __name__ == "__main__":
    run(smoke=_ARGS.smoke, out_path=_ARGS.out, backend=_ARGS.backend,
        qps=_ARGS.qps)

"""Data partitioning policies for DDC phase 1 — including the paper's
capacity-aware split (Experiment IV), which doubles as the framework's
straggler-mitigation policy: slow hosts get smaller shards so all shards
finish phase 1 together.
"""
from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp
import numpy as np

MORTON_BITS = 10


def morton_code(points, bounds=None, bits: int = MORTON_BITS):
    """Interleaved grid-bit (Z-order) code per point — jnp, jit-traceable.

    points: (n, 2) in data units.  ``bounds`` = (x0, y0, x1, y1); when
    None the points' own bounding box is used (fine for sorting — only
    the relative order matters).  Nearby codes ⇒ nearby cells, which is
    what both the spatial shard split and the block-sparse DBSCAN tiling
    rely on.
    """
    pts = jnp.asarray(points, jnp.float32)
    if bounds is None:
        lo = jnp.min(pts, axis=0)
        hi = jnp.max(pts, axis=0)
    else:
        lo = jnp.asarray(bounds[:2], jnp.float32)
        hi = jnp.asarray(bounds[2:], jnp.float32)
    g = 1 << bits
    scale = jnp.where(hi > lo, hi - lo, 1.0)
    cell = ((pts - lo) / scale * g).astype(jnp.int32)
    cell = jnp.clip(cell, 0, g - 1)
    ix, iy = cell[:, 0], cell[:, 1]
    code = jnp.zeros(pts.shape[0], jnp.int32)
    for b in range(bits):
        code = code | (((ix >> b) & 1) << (2 * b + 1))
        code = code | (((iy >> b) & 1) << (2 * b))
    return code


def split_block(n: int, k: int) -> list[np.ndarray]:
    return np.array_split(np.arange(n), k)


def split_random(n: int, k: int, seed: int = 0) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    return np.array_split(perm, k)


def split_spatial(points: np.ndarray, k: int) -> list[np.ndarray]:
    """Morton-ish spatial split: sort by interleaved grid bits so shards
    are spatially compact (fewer cross-shard clusters to merge)."""
    code = np.asarray(morton_code(points, bounds=(0.0, 0.0, 1.0, 1.0)))
    order = np.argsort(code, kind="stable")
    return np.array_split(order, k)


def capacity_aware_sizes(
    n: int, speeds: Sequence[float], complexity_exp: float = 2.0
) -> np.ndarray:
    """Shard sizes that equalise phase-1 time under t_i = n_i^k / s_i.

    Equal time => n_i ∝ s_i^(1/k).  k=2 for DBSCAN (the paper's case).
    """
    s = np.asarray(speeds, np.float64) ** (1.0 / complexity_exp)
    sizes = np.floor(n * s / s.sum()).astype(int)
    sizes[: n - sizes.sum()] += 1
    return sizes


def split_capacity_aware(
    n: int, speeds: Sequence[float], complexity_exp: float = 2.0, seed: int = 0
) -> list[np.ndarray]:
    sizes = capacity_aware_sizes(n, speeds, complexity_exp)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    out, off = [], 0
    for sz in sizes:
        out.append(perm[off : off + sz])
        off += sz
    return out


# --- Paper experiment scenarios (sizes per machine, 8 machines) -----------

def scenario_sizes(which: str, n: int = 10_000, seed: int = 0,
                   speeds: Sequence[float] | None = None) -> list[int]:
    """Shard sizes for the paper's Experiments I–IV (section 5)."""
    rng = np.random.default_rng(seed)
    if which == "I":     # random chunks in [1500, 10000]; M1 gets the full set
        sizes = [10_000, 2_500, 3_275, 5_000, 1_666, 2_000, 5_000, 1_500]
    elif which == "II":  # one machine the whole dataset, the rest 1/8
        sizes = [n] + [n // 8] * 7
    elif which == "III":  # seven machines the whole dataset, one 1/8
        sizes = [n] * 7 + [n // 8]
    elif which == "IV":  # capacity-aware (paper Table 6 sizes)
        if speeds is None:
            sizes = [1_500, 1_660, 500, 1_000, 1_500, 1_400, 1_000, 1_500]
        else:
            sizes = capacity_aware_sizes(sum([1250] * 8), speeds).tolist()
    else:  # pragma: no cover
        raise ValueError(which)
    return [int(s) for s in sizes]

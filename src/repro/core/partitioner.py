"""Data partitioning policies for DDC phase 1 — including the paper's
capacity-aware split (Experiment IV), which doubles as the framework's
straggler-mitigation policy: slow hosts get smaller shards so all shards
finish phase 1 together.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np


def split_block(n: int, k: int) -> list[np.ndarray]:
    return np.array_split(np.arange(n), k)


def split_random(n: int, k: int, seed: int = 0) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    return np.array_split(perm, k)


def split_spatial(points: np.ndarray, k: int) -> list[np.ndarray]:
    """Morton-ish spatial split: sort by interleaved grid bits so shards
    are spatially compact (fewer cross-shard clusters to merge)."""
    g = 1 << 10
    ix = np.clip((points[:, 0] * g).astype(np.int64), 0, g - 1)
    iy = np.clip((points[:, 1] * g).astype(np.int64), 0, g - 1)
    code = np.zeros(len(points), np.int64)
    for b in range(10):
        code |= ((ix >> b) & 1) << (2 * b + 1)
        code |= ((iy >> b) & 1) << (2 * b)
    order = np.argsort(code, kind="stable")
    return np.array_split(order, k)


def capacity_aware_sizes(
    n: int, speeds: Sequence[float], complexity_exp: float = 2.0
) -> np.ndarray:
    """Shard sizes that equalise phase-1 time under t_i = n_i^k / s_i.

    Equal time => n_i ∝ s_i^(1/k).  k=2 for DBSCAN (the paper's case).
    """
    s = np.asarray(speeds, np.float64) ** (1.0 / complexity_exp)
    sizes = np.floor(n * s / s.sum()).astype(int)
    sizes[: n - sizes.sum()] += 1
    return sizes


def split_capacity_aware(
    n: int, speeds: Sequence[float], complexity_exp: float = 2.0, seed: int = 0
) -> list[np.ndarray]:
    sizes = capacity_aware_sizes(n, speeds, complexity_exp)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    out, off = [], 0
    for sz in sizes:
        out.append(perm[off : off + sz])
        off += sz
    return out


# --- Paper experiment scenarios (sizes per machine, 8 machines) -----------

def scenario_sizes(which: str, n: int = 10_000, seed: int = 0,
                   speeds: Sequence[float] | None = None) -> list[int]:
    """Shard sizes for the paper's Experiments I–IV (section 5)."""
    rng = np.random.default_rng(seed)
    if which == "I":     # random chunks in [1500, 10000]; M1 gets the full set
        sizes = [10_000, 2_500, 3_275, 5_000, 1_666, 2_000, 5_000, 1_500]
    elif which == "II":  # one machine the whole dataset, the rest 1/8
        sizes = [n] + [n // 8] * 7
    elif which == "III":  # seven machines the whole dataset, one 1/8
        sizes = [n] * 7 + [n // 8]
    elif which == "IV":  # capacity-aware (paper Table 6 sizes)
        if speeds is None:
            sizes = [1_500, 1_660, 500, 1_000, 1_500, 1_400, 1_000, 1_500]
        else:
            sizes = capacity_aware_sizes(sum([1250] * 8), speeds).tolist()
    else:  # pragma: no cover
        raise ValueError(which)
    return [int(s) for s in sizes]

"""Computational-geometry primitives for DDC.

Two families live here:

* ``*_np`` — host-side NumPy reference implementations (exact, dynamic
  shapes).  These are the oracles used by tests and by the host
  (paper-faithful) DDC path.
* JAX functions — static-shape, mask-aware, TPU-friendly versions used by
  the distributed ``shard_map`` DDC path.  Contours are fixed-size padded
  buffers so they can cross TPU collectives.

The paper extracts non-convex cluster boundaries with a triangulation
algorithm (O(n log n)).  On TPU we replace triangulation with an
occupancy-grid boundary (rasterise + morphological erosion, conv-style),
which vectorises; the exact convex hull (monotone chain / Jarvis march)
is kept both as a compact fallback and as the test oracle.  See
DESIGN.md §3.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

# ---------------------------------------------------------------------------
# NumPy reference geometry (host path + oracles)
# ---------------------------------------------------------------------------


def convex_hull_np(points: np.ndarray) -> np.ndarray:
    """Andrew's monotone chain.  Returns hull vertices in CCW order.

    ``points``: (n, 2).  Handles degenerate inputs (n <= 2, collinear).
    """
    pts = np.unique(np.asarray(points, dtype=np.float64), axis=0)
    n = len(pts)
    if n <= 2:
        return pts
    order = np.lexsort((pts[:, 1], pts[:, 0]))
    pts = pts[order]

    def cross(o, a, b):
        return (a[0] - o[0]) * (b[1] - o[1]) - (a[1] - o[1]) * (b[0] - o[0])

    lower: list = []
    for p in pts:
        while len(lower) >= 2 and cross(lower[-2], lower[-1], p) <= 0:
            lower.pop()
        lower.append(p)
    upper: list = []
    for p in pts[::-1]:
        while len(upper) >= 2 and cross(upper[-2], upper[-1], p) <= 0:
            upper.pop()
        upper.append(p)
    return np.array(lower[:-1] + upper[:-1])


def point_in_polygon_np(query: np.ndarray, poly: np.ndarray) -> np.ndarray:
    """Crossing-number point-in-polygon test.

    ``query``: (m, 2); ``poly``: (v, 2) ordered vertices.  Returns (m,) bool.
    """
    query = np.atleast_2d(query)
    x, y = query[:, 0], query[:, 1]
    v = len(poly)
    inside = np.zeros(len(query), dtype=bool)
    j = v - 1
    for i in range(v):
        xi, yi = poly[i]
        xj, yj = poly[j]
        crosses = ((yi > y) != (yj > y)) & (
            x < (xj - xi) * (y - yi) / (yj - yi + 1e-30) + xi
        )
        inside ^= crosses
        j = i
    return inside


def _segments_intersect_np(p1, p2, q1, q2) -> bool:
    def orient(a, b, c):
        val = (b[0] - a[0]) * (c[1] - a[1]) - (b[1] - a[1]) * (c[0] - a[0])
        return 0 if abs(val) < 1e-12 else (1 if val > 0 else -1)

    o1, o2 = orient(p1, p2, q1), orient(p1, p2, q2)
    o3, o4 = orient(q1, q2, p1), orient(q1, q2, p2)
    if o1 != o2 and o3 != o4:
        return True

    def on_seg(a, b, c):
        return (
            min(a[0], b[0]) - 1e-12 <= c[0] <= max(a[0], b[0]) + 1e-12
            and min(a[1], b[1]) - 1e-12 <= c[1] <= max(a[1], b[1]) + 1e-12
        )

    if o1 == 0 and on_seg(p1, p2, q1):
        return True
    if o2 == 0 and on_seg(p1, p2, q2):
        return True
    if o3 == 0 and on_seg(q1, q2, p1):
        return True
    if o4 == 0 and on_seg(q1, q2, p2):
        return True
    return False


def polygons_overlap_np(a: np.ndarray, b: np.ndarray) -> bool:
    """Exact polygon-overlap test: bbox prefilter, then containment /
    edge-intersection.  This is the paper's phase-2 merge predicate."""
    if len(a) == 0 or len(b) == 0:
        return False
    if len(a) < 3 or len(b) < 3:
        # Degenerate: fall back to proximity of point sets.
        d = np.linalg.norm(a[:, None, :] - b[None, :, :], axis=-1)
        return bool(d.min() < 1e-9)
    if (a[:, 0].max() < b[:, 0].min() or b[:, 0].max() < a[:, 0].min()
            or a[:, 1].max() < b[:, 1].min() or b[:, 1].max() < a[:, 1].min()):
        return False
    if point_in_polygon_np(a[:1], b)[0] or point_in_polygon_np(b[:1], a)[0]:
        return True
    na, nb = len(a), len(b)
    for i in range(na):
        p1, p2 = a[i], a[(i + 1) % na]
        for j in range(nb):
            q1, q2 = b[j], b[(j + 1) % nb]
            if _segments_intersect_np(p1, p2, q1, q2):
                return True
    return False


def grid_contour_np(
    points: np.ndarray, bounds: Tuple[float, float, float, float], grid: int
) -> np.ndarray:
    """Occupancy-grid boundary of a point set (NumPy oracle for the JAX
    version).  Returns boundary-cell centres, unordered."""
    x0, y0, x1, y1 = bounds
    sx = (grid - 1) / max(x1 - x0, 1e-12)
    sy = (grid - 1) / max(y1 - y0, 1e-12)
    ix = np.clip(((points[:, 0] - x0) * sx).astype(int), 0, grid - 1)
    iy = np.clip(((points[:, 1] - y0) * sy).astype(int), 0, grid - 1)
    occ = np.zeros((grid, grid), dtype=bool)
    occ[ix, iy] = True
    padded = np.pad(occ, 1)
    interior = np.ones_like(occ)
    for dx, dy in ((1, 0), (-1, 0), (0, 1), (0, -1)):
        interior &= padded[1 + dx : 1 + dx + grid, 1 + dy : 1 + dy + grid]
    boundary = occ & ~interior
    bx, by = np.nonzero(boundary)
    cx = x0 + (bx + 0.5) / sx
    cy = y0 + (by + 0.5) / sy
    return np.stack([cx, cy], axis=-1)


# ---------------------------------------------------------------------------
# JAX geometry — static shapes, mask-aware
# ---------------------------------------------------------------------------

BIG = 1e30


def grid_occupancy(
    points: Array,
    mask: Array,
    bounds: Tuple[float, float, float, float],
    grid: int,
) -> Array:
    """Rasterise masked points onto a (grid, grid) bool occupancy map.

    Bounds are *global* (config-static) so cells align across shards.
    """
    x0, y0, x1, y1 = bounds
    sx = (grid - 1) / max(x1 - x0, 1e-12)
    sy = (grid - 1) / max(y1 - y0, 1e-12)
    ix = jnp.clip(((points[:, 0] - x0) * sx), 0, grid - 1).astype(jnp.int32)
    iy = jnp.clip(((points[:, 1] - y0) * sy), 0, grid - 1).astype(jnp.int32)
    flat = ix * grid + iy
    occ = jnp.zeros((grid * grid,), jnp.int32)
    occ = occ.at[flat].add(mask.astype(jnp.int32), mode="drop")
    return (occ > 0).reshape(grid, grid)


def grid_boundary(occ: Array) -> Array:
    """Boundary cells: occupied with at least one unoccupied 4-neighbour
    (morphological erosion by a plus-shaped structuring element)."""
    occ_i = occ.astype(jnp.int32)
    padded = jnp.pad(occ_i, 1)
    g = occ.shape[0]
    interior = (
        padded[2:, 1:-1] * padded[:-2, 1:-1] * padded[1:-1, 2:] * padded[1:-1, :-2]
    )
    return occ & (interior == 0)


def cells_to_points(
    cells: Array, bounds: Tuple[float, float, float, float], max_verts: int
) -> Tuple[Array, Array]:
    """Select up to ``max_verts`` active cells and return their centres.

    Returns (points (max_verts, 2), count ()).  Deterministic: row-major
    top-k on the active flag.
    """
    grid = cells.shape[0]
    x0, y0, x1, y1 = bounds
    sx = (grid - 1) / max(x1 - x0, 1e-12)
    sy = (grid - 1) / max(y1 - y0, 1e-12)
    flat = cells.reshape(-1)
    n_active = jnp.sum(flat.astype(jnp.int32))
    # Rank active cells first while preserving row-major order.
    keys = jnp.where(flat, jnp.arange(flat.shape[0]), flat.shape[0] + jnp.arange(flat.shape[0]))
    chosen_flat = -jax.lax.top_k(-keys, max_verts)[0]
    valid = chosen_flat < flat.shape[0]
    chosen = jnp.where(valid, chosen_flat, 0)
    bx = chosen // grid
    by = chosen % grid
    cx = x0 + (bx.astype(jnp.float32) + 0.5) / sx
    cy = y0 + (by.astype(jnp.float32) + 0.5) / sy
    pts = jnp.stack([cx, cy], axis=-1)
    pts = jnp.where(valid[:, None], pts, 0.0)
    return pts, jnp.minimum(n_active, max_verts)


def extract_contour(
    points: Array,
    mask: Array,
    bounds: Tuple[float, float, float, float],
    grid: int,
    max_verts: int,
) -> Tuple[Array, Array]:
    """Grid-based contour of a masked point set.

    Returns (contour (max_verts, 2), n_verts ()).  This is DDC's data
    reduction: the contour is the cluster's network representation.
    """
    occ = grid_occupancy(points, mask, bounds, grid)
    boundary = grid_boundary(occ)
    return cells_to_points(boundary, bounds, max_verts)


def convex_hull_jax(points: Array, mask: Array, max_verts: int) -> Tuple[Array, Array]:
    """Jarvis-march (gift wrapping) convex hull with static shapes.

    O(max_verts * n) — fine for the contour budgets DDC uses.  Returns
    (hull (max_verts, 2) CCW from the lowest point, count ()).  Masked-out
    points are ignored.
    """
    n = points.shape[0]
    inf_pt = jnp.array([BIG, BIG], points.dtype)
    pts = jnp.where(mask[:, None], points, inf_pt)

    # Start: lexicographically smallest (y, then x).
    key = pts[:, 1] * (2 * BIG) + pts[:, 0]
    start = jnp.argmin(key)

    def cross(o, a, b):
        return (a[0] - o[0]) * (b[1] - o[1]) - (a[1] - o[1]) * (b[0] - o[0])

    def step(carry, _):
        cur, done, count = carry
        o = pts[cur]

        def better(cand, i):
            # candidate i beats current candidate `cand` if it is more
            # clockwise (cross < 0), or collinear and farther.
            c = cross(o, pts[cand], pts[i])
            d_cand = jnp.sum((pts[cand] - o) ** 2)
            d_i = jnp.sum((pts[i] - o) ** 2)
            valid = mask[i] & (i != cur)
            take = valid & ((c < 0) | ((jnp.abs(c) < 1e-12) & (d_i > d_cand)))
            invalid_cand = ~mask[cand] | (cand == cur)
            return jnp.where(take | (invalid_cand & valid), i, cand)

        nxt = jax.lax.fori_loop(0, n, lambda i, cand: better(cand, i), cur)
        emit = jnp.where(done, inf_pt, o)
        new_done = done | (nxt == start)
        return (nxt, new_done, count + (~done).astype(jnp.int32)), emit

    (_, _, count), hull = jax.lax.scan(
        step, (start, jnp.array(False), jnp.array(0, jnp.int32)), None, length=max_verts
    )
    hull = jnp.where(hull >= BIG, 0.0, hull)
    return hull, count


def vert_validity(counts: Array, valid: Array, max_verts: int) -> Array:
    """(m, max_verts) per-vertex validity of padded contour buffers: the
    first ``counts[i]`` vertices of each valid slot are real, the rest are
    padding.  Shared by the phase-2 merge matrix and slot matching."""
    return (jnp.arange(max_verts)[None, :] < counts[:, None]) & valid[:, None]


def min_cross_distance_sq(
    a: Array, a_count: Array, b: Array, b_count: Array
) -> Array:
    """Minimum squared distance between two padded point buffers."""
    va = jnp.arange(a.shape[0]) < a_count
    vb = jnp.arange(b.shape[0]) < b_count
    d2 = jnp.sum((a[:, None, :] - b[None, :, :]) ** 2, axis=-1)
    d2 = jnp.where(va[:, None] & vb[None, :], d2, BIG)
    return jnp.min(d2)


def farthest_point_subsample(
    points: Array, mask: Array, k: int
) -> Tuple[Array, Array]:
    """Greedy k-centre subsampling of a masked point buffer.

    Used when a merged cluster's contour union exceeds the vertex budget:
    keeps the outline's extremes first.  Returns (subset (k, 2), count ()).
    """
    n = points.shape[0]
    inf_pt = jnp.array([BIG, BIG], points.dtype)
    pts = jnp.where(mask[:, None], points, inf_pt)
    n_valid = jnp.sum(mask.astype(jnp.int32))

    start = jnp.argmax(mask)  # first valid point
    d2 = jnp.where(mask, jnp.sum((pts - pts[start]) ** 2, axis=-1), -1.0)

    def step(carry, _):
        d2, last = carry
        nxt = jnp.argmax(d2)
        emit = pts[nxt]
        nd = jnp.sum((pts - pts[nxt]) ** 2, axis=-1)
        d2 = jnp.minimum(d2, jnp.where(mask, nd, -1.0))
        return (d2, nxt), emit

    (_, _), subset = jax.lax.scan(step, (d2, start), None, length=k - 1)
    subset = jnp.concatenate([pts[start][None], subset], axis=0)
    count = jnp.minimum(n_valid, k)
    valid = jnp.arange(k) < count
    subset = jnp.where(valid[:, None], subset, 0.0)
    return subset, count

"""Dynamic Distributed Clustering (DDC) — the paper's contribution.

Phase 1 (SPMD, zero communication): every shard clusters its local points
(DBSCAN or K-Means) and reduces each cluster to a fixed-size *contour*
buffer — the paper's 1–2 % data-reduction step.

Phase 2 (hierarchical aggregation): contour buffers are merged across
shards.  Two schedules:

* ``sync``  — barrier all-gather of every shard's contours, then one fold
  (the paper's synchronous model: everyone waits for the slowest, then
  merges).  Collective bytes per lane: (K-1)·B.
* ``async`` — butterfly / recursive-doubling: log2(K) rounds of pairwise
  ``ppermute`` exchange + merge; merge compute of round ℓ overlaps the
  round ℓ+1 permute in XLA's schedule (the paper's asynchronous model:
  neighbours merge as soon as both are ready).  Collective bytes per
  lane: log2(K)·B.

Both schedules produce identical global clusters (a paper claim we test).

Static shapes throughout: a shard's clusters live in a ``ClusterSet``
(C clusters × V contour vertices, padded + masked) so buffers can cross
TPU collectives.  ``merge_pair`` returns slot-mappings so each shard can
relabel its local points to global cluster ids without any extra
communication.

Host path: ``ddc_host`` (NumPy, exact polygon-overlap merge) is the
paper-faithful oracle; ``dbscan_ref`` on the unpartitioned data is the
sequential baseline T1 used for the speedup experiments.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.core import dbscan as dbscan_mod
from repro.core import geometry, kmeans
from repro.kernels import ops

SENTINEL = 2**30


@dataclasses.dataclass(frozen=True)
class DDCConfig:
    """Static configuration of the DDC pipeline (hashable, jit-static)."""

    eps: float = 0.05                  # DBSCAN radius (data units)
    min_pts: int = 5
    bounds: Tuple[float, float, float, float] = (0.0, 0.0, 1.0, 1.0)
    grid: int = 128                    # contour raster resolution
    max_clusters: int = 32             # C: per-shard cluster budget
    max_verts: int = 128               # V: per-cluster contour budget
    merge_eps: float | None = None     # contour-overlap distance; default eps
    local_algo: str = "dbscan"         # "dbscan" | "kmeans"
    kmeans_k: int = 8
    schedule: str = "async"            # "sync" | "async" | "tree"
    tree_degree: int = 2               # D for the paper's Algorithm-2 tree
    merge_refine: str = "grid"         # "grid" | "fps"
    block_sparse: str = "auto"         # phase-1 spatial pruning (dbscan.py)
    block_tile: int = 512              # tile size for the block-sparse path

    @property
    def merge_radius(self) -> float:
        # Contours are grid-cell centres; two touching clusters' boundary
        # cells are within one cell diagonal + eps of each other.
        cell = max(
            (self.bounds[2] - self.bounds[0]) / self.grid,
            (self.bounds[3] - self.bounds[1]) / self.grid,
        )
        base = self.merge_eps if self.merge_eps is not None else self.eps
        return base + 1.5 * cell

    def buffer_bytes(self) -> int:
        """Bytes a ClusterSet occupies on the wire (the 1–2 % claim)."""
        c, v = self.max_clusters, self.max_verts
        return c * v * 2 * 4 + c * 4 + c * 4 + c * 1 + 1


class ClusterSet(NamedTuple):
    """Fixed-size representation of a shard's clusters (network format)."""

    contours: jax.Array  # (C, V, 2) f32 — padded contour vertices
    counts: jax.Array    # (C,)     i32 — valid vertices per cluster
    sizes: jax.Array     # (C,)     i32 — member-point counts
    valid: jax.Array     # (C,)     bool
    overflow: jax.Array  # ()       bool — cluster budget exceeded somewhere


@functools.lru_cache(maxsize=None)
def _empty_clusterset(c: int, v: int) -> ClusterSet:
    return ClusterSet(
        contours=jnp.zeros((c, v, 2), jnp.float32),
        counts=jnp.zeros((c,), jnp.int32),
        sizes=jnp.zeros((c,), jnp.int32),
        valid=jnp.zeros((c,), bool),
        overflow=jnp.asarray(False),
    )


def empty_clusterset(cfg: DDCConfig) -> ClusterSet:
    """The all-invalid ClusterSet for ``cfg``'s budgets.  Cached per
    (C, V): callers hit this on every empty-shard code path, so repeated
    calls must not rebuild (or retrace over) fresh device buffers."""
    return _empty_clusterset(cfg.max_clusters, cfg.max_verts)


# ---------------------------------------------------------------------------
# Phase 1 — local clustering + contour reduction
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("cfg",))
def local_phase(
    points: jax.Array, mask: jax.Array, cfg: DDCConfig, key: jax.Array | None = None
) -> Tuple[jax.Array, ClusterSet]:
    """Cluster a shard's points and reduce to contours.

    Returns (dense local labels (n,), ClusterSet).  Zero communication.
    """
    n = points.shape[0]
    c_budget = cfg.max_clusters
    if cfg.local_algo == "dbscan":
        res = dbscan_mod.dbscan(
            points, mask, cfg.eps, cfg.min_pts,
            block_sparse=cfg.block_sparse, bt=cfg.block_tile,
        )
        dense = dbscan_mod.relabel_dense(res.labels, c_budget)
        n_clusters = res.n_clusters
    elif cfg.local_algo == "kmeans":
        if key is None:
            key = jax.random.PRNGKey(0)
        km = kmeans.kmeans(key, points, mask, min(cfg.kmeans_k, c_budget))
        dense = km.labels
        n_clusters = jnp.asarray(min(cfg.kmeans_k, c_budget), jnp.int32)
    else:  # pragma: no cover
        raise ValueError(cfg.local_algo)

    sizes = jnp.zeros((c_budget,), jnp.int32).at[jnp.clip(dense, 0)].add(
        (dense >= 0).astype(jnp.int32), mode="drop"
    )
    valid = sizes > 0

    def one_contour(cid):
        m = mask & (dense == cid)
        pts, cnt = geometry.extract_contour(
            points, m, cfg.bounds, cfg.grid, cfg.max_verts
        )
        return pts, cnt

    contours, counts = jax.vmap(one_contour)(jnp.arange(c_budget))
    cs = ClusterSet(
        contours=contours,
        counts=jnp.where(valid, counts, 0),
        sizes=sizes,
        valid=valid,
        overflow=n_clusters > c_budget,
    )
    return dense, cs


# ---------------------------------------------------------------------------
# Phase 2 — batched ClusterSet merge engine (the aggregation kernel)
# ---------------------------------------------------------------------------


def _components(overlap: jax.Array, valid: jax.Array) -> jax.Array:
    """Min-label connected components over an (M, M) overlap graph.

    Each iteration does one neighbour-min sweep followed by
    ``ceil(log2 M)`` pointer-doubling shortcut steps
    (``labels ← min(labels, labels[labels])`` — the same hook-and-compress
    trick as phase 1, DESIGN.md §5), so convergence takes O(log M)
    sweeps instead of O(component diameter).  For a valid node i,
    ``labels[i]`` is always the index of a valid node in the same
    component with label ≤ i, so jumping through the representative stays
    in-component and the fixed point (sweep-stability) still forces every
    member to the component minimum.
    """
    m = overlap.shape[0]
    idx = jnp.arange(m, dtype=jnp.int32)
    labels = jnp.where(valid, idx, SENTINEL).astype(jnp.int32)
    n_shortcut = max(1, (m - 1).bit_length())

    def cond(state):
        labels, changed = state
        return changed

    def body(state):
        labels, _ = state
        neigh = jnp.where(overlap, labels[None, :], SENTINEL)
        new = jnp.minimum(labels, jnp.min(neigh, axis=1))
        new = jnp.where(valid, new, SENTINEL)

        def shortcut(_, lab):
            jump = lab[jnp.clip(lab, 0, m - 1)]
            return jnp.where(valid, jnp.minimum(lab, jump), lab)

        new = jax.lax.fori_loop(0, n_shortcut, shortcut, new)
        return new, jnp.any(new != labels)

    labels, _ = jax.lax.while_loop(cond, body, (labels, jnp.asarray(True)))
    return labels


def contour_pair_d2(batch: ClusterSet, cfg: DDCConfig) -> jax.Array:
    """The (K·C, K·C) slot×slot min-contour-distance matrix of a stacked
    ClusterSet batch — one kernel call (``ops.contour_min_d2``), no
    per-pair row scans.  Factored out of ``merge_many`` so the streaming
    delta path (serve/cluster_service.py) can cache it and refresh only
    dirty rows/columns (``update_pair_d2``)."""
    c, v = cfg.max_clusters, cfg.max_verts
    m = batch.valid.shape[0] * c
    return ops.contour_min_d2(
        batch.contours.reshape(m, v, 2),
        batch.counts.reshape(m),
        batch.valid.reshape(m),
    )


def cross_min_d2(ca: jax.Array, cnta: jax.Array, va: jax.Array,
                 cb: jax.Array, cntb: jax.Array, vb: jax.Array) -> jax.Array:
    """Rectangular min squared distance between two padded contour
    buffers: (A, V, 2) × (B, V, 2) → (A, B), 1e30 where either slot is
    empty.  Memory-bounded (one A-row at a time) and written in the same
    difference form as ``kernels/ref.py::contour_min_d2``, so a row
    computed here is bit-identical to the corresponding row of the full
    matrix on the reference backend — the invariant the delta-merge
    exactness argument rests on (DESIGN.md §8)."""
    a, v, _ = ca.shape
    b = cb.shape[0]
    pa = geometry.vert_validity(cnta, va, v)                    # (A, V)
    pb = geometry.vert_validity(cntb, vb, v).reshape(b * v)     # (B·V,)
    flat = cb.astype(jnp.float32).reshape(b * v, 2)
    pts = ca.astype(jnp.float32)

    def row(i):
        d2 = jnp.sum((pts[i][:, None, :] - flat[None, :, :]) ** 2, axis=-1)
        d2 = jnp.where(pa[i][:, None] & pb[None, :], d2, geometry.BIG)
        return jnp.min(d2.reshape(v, b, v), axis=(0, 2))        # (B,)

    return jax.lax.map(row, jnp.arange(a))


@functools.partial(jax.jit, static_argnames=("cfg",))
def contour_pair_d2_exact(batch: ClusterSet, cfg: DDCConfig) -> jax.Array:
    """``contour_pair_d2`` in the difference form on every backend.

    The kernel path behind ``contour_pair_d2`` matches the reference only
    within tolerance on TPU (centred MXU expansion), while the delta
    patches (``update_pair_d2``) are always difference-form — mixing the
    two in one cached matrix would break the streaming engine's
    bit-exactness contract near the merge threshold.  The engine
    therefore builds its full matrix here: same math, backend-stable, and
    bit-identical to the rows ``cross_min_d2`` patches in later."""
    c, v = cfg.max_clusters, cfg.max_verts
    m = batch.valid.shape[0] * c
    contours = batch.contours.reshape(m, v, 2)
    counts = batch.counts.reshape(m)
    valid = batch.valid.reshape(m)
    return cross_min_d2(contours, counts, valid, contours, counts, valid)


@functools.partial(jax.jit, static_argnames=("cfg",), donate_argnums=(0,))
def update_pair_d2(pair_d2: jax.Array, batch: ClusterSet, shard,
                   cfg: DDCConfig) -> jax.Array:
    """Refresh one shard's rows + columns of a cached slot×slot distance
    matrix after that shard's ClusterSet changed (the streaming
    delta-merge path: O(C·M·V²) work instead of the full O(M²·V²)
    rebuild).  ``shard`` may be a traced index, so one compilation serves
    every dirty shard.  d2 is symmetric under IEEE ((a−b)² == (b−a)²), so
    mirroring the freshly computed rows into the columns keeps the matrix
    bit-identical to ``contour_pair_d2`` recomputed from scratch."""
    c, v = cfg.max_clusters, cfg.max_verts
    m = batch.valid.shape[0] * c
    contours = batch.contours.reshape(m, v, 2)
    counts = batch.counts.reshape(m)
    valid = batch.valid.reshape(m)
    row0 = shard * c
    bc = jax.lax.dynamic_slice(contours, (row0, 0, 0), (c, v, 2))
    bcnt = jax.lax.dynamic_slice(counts, (row0,), (c,))
    bval = jax.lax.dynamic_slice(valid, (row0,), (c,))
    rows = cross_min_d2(bc, bcnt, bval, contours, counts, valid)   # (C, M)
    pair_d2 = jax.lax.dynamic_update_slice(pair_d2, rows, (row0, 0))
    return jax.lax.dynamic_update_slice(pair_d2, rows.T, (0, row0))


@functools.partial(jax.jit, static_argnames=("cfg",), donate_argnums=(0,))
def update_pair_d2_many(pair_d2: jax.Array, batch: ClusterSet, shards,
                        cfg: DDCConfig) -> jax.Array:
    """Batched ``update_pair_d2``: refresh the rows + columns of EVERY
    shard in ``shards`` ((m,) i32, traced) with one rectangular
    ``cross_min_d2`` over the m·C dirty rows.  Replaces the sequential
    per-shard patch loop, which recomputed every dirty×dirty block once
    per dirty shard (m× redundant work) and paid m kernel dispatches.

    Bit-exact vs the loop: each dirty row is the identical per-row
    difference-form computation over the identical batch (the dirty rows
    were all replaced before any patch runs), and the column mirror is
    exact under IEEE symmetry — so scatter order cannot matter, even for
    duplicated indices (callers pad ``shards`` to a power of two by
    repeating an entry; the duplicate writes carry bit-identical values).
    """
    c, v = cfg.max_clusters, cfg.max_verts
    m = batch.valid.shape[0] * c
    contours = batch.contours.reshape(m, v, 2)
    counts = batch.counts.reshape(m)
    valid = batch.valid.reshape(m)
    rows_idx = (shards[:, None] * c
                + jnp.arange(c, dtype=jnp.int32)[None, :]).reshape(-1)
    rows = cross_min_d2(contours[rows_idx], counts[rows_idx],
                        valid[rows_idx], contours, counts, valid)  # (mC, M)
    pair_d2 = pair_d2.at[rows_idx].set(rows)
    return pair_d2.at[:, rows_idx].set(rows.T)


@functools.partial(jax.jit, static_argnames=("cfg",))
def merge_from_d2(batch: ClusterSet, pair_d2: jax.Array,
                  cfg: DDCConfig,
                  exclude: jax.Array | None = None
                  ) -> Tuple[ClusterSet, jax.Array]:
    """The merge fold given a precomputed slot×slot distance matrix:
    overlap predicate → transitive closure → ranked rebuild.  Everything
    downstream of the matrix is a pure function of (batch, pair_d2), so
    feeding a cached-and-patched matrix (streaming delta path) yields the
    exact same global clustering as a from-scratch ``merge_many``.

    ``exclude`` (optional, (K,) bool) masks whole shards out of the fold
    without touching the cached matrix — the degraded-merge path for
    quarantined shards: their slots are treated as invalid (maps row all
    -1, their sizes and overflow flags ignored), so healthy shards keep
    merging and the matrix stays pristine for a bit-exact rejoin.
    ``exclude=None`` traces separately and is the identical healthy
    path."""
    c, v = cfg.max_clusters, cfg.max_verts
    k = batch.valid.shape[0]
    m = k * c
    contours = batch.contours.reshape(m, v, 2)
    counts = batch.counts.reshape(m)
    sizes = batch.sizes.reshape(m)
    valid = batch.valid.reshape(m)
    if exclude is not None:
        valid = valid & ~jnp.repeat(exclude, c)
    r = cfg.merge_radius
    overlap = (pair_d2 <= r * r) & valid[:, None] & valid[None, :]
    overlap = overlap | (jnp.eye(m, dtype=bool) & valid[:, None])

    comp = _components(overlap, valid)                         # (M,)
    roots = valid & (comp == jnp.arange(m, dtype=jnp.int32))
    comp_safe = jnp.clip(comp, 0, m - 1)
    comp_size = jnp.zeros((m,), jnp.int32).at[comp_safe].add(
        jnp.where(valid, sizes, 0)
    )

    # Rank component roots by size (desc); keep top C.
    rank_key = jnp.where(roots, comp_size, -1)
    order = jnp.argsort(-rank_key)                             # (M,) root idx by size
    new_slot_of_root = jnp.full((m,), -1, jnp.int32)
    kept = jnp.arange(m) < c
    new_slot_of_root = new_slot_of_root.at[order].set(
        jnp.where(kept & (rank_key[order] > 0), jnp.arange(m, dtype=jnp.int32), -1)
    )
    slot_of_old = jnp.where(valid, new_slot_of_root[comp_safe], -1)  # (M,)

    n_components = jnp.sum(roots.astype(jnp.int32))
    shard_overflow = batch.overflow if exclude is None \
        else batch.overflow & ~exclude
    overflow = jnp.any(shard_overflow) | (n_components > c)

    # Build merged contours per new slot.
    flat_pts = contours.reshape(m * v, 2)
    vert_valid = geometry.vert_validity(counts, valid, v)       # (M, V)

    def build(slot):
        member = slot_of_old == slot                            # (M,)
        pmask = (vert_valid & member[:, None]).reshape(m * v)
        if cfg.merge_refine == "grid":
            pts, cnt = geometry.extract_contour(
                flat_pts, pmask, cfg.bounds, cfg.grid, v
            )
        else:
            pts, cnt = geometry.farthest_point_subsample(flat_pts, pmask, v)
        size = jnp.sum(jnp.where(member, sizes, 0))
        return pts, cnt, size, size > 0

    nc, ncnt, nsize, nvalid = jax.vmap(build)(jnp.arange(c))
    merged = ClusterSet(
        contours=nc,
        counts=jnp.where(nvalid, ncnt, 0),
        sizes=nsize,
        valid=nvalid,
        overflow=overflow,
    )
    return merged, slot_of_old.reshape(k, c)


def merge_delta(batch: ClusterSet, pair_d2: jax.Array | None,
                dirty, cfg: DDCConfig,
                exclude: jax.Array | None = None
                ) -> Tuple[ClusterSet, jax.Array, jax.Array]:
    """The aggregator side of a delta exchange: fold axis-gathered dirty
    ClusterSets into a cached slot-distance matrix and re-close the merge.

    ``batch`` is the aggregator's mirror of every shard's ClusterSet with
    the ``dirty`` rows already replaced by the freshly exchanged deltas
    (the only payload that crossed the axis).  With a cached ``pair_d2``
    the matrix is patched in one batched update over every dirty shard
    (``update_pair_d2_many``; a single dirty shard keeps the narrower
    ``update_pair_d2`` kernel, and the dirty list is padded to a power of
    two so compilations stay bounded at log2(K) per config);
    with ``pair_d2=None`` (or ``dirty=None``) it is rebuilt from scratch
    in the same difference form (``contour_pair_d2_exact``), so both
    paths produce the bit-identical matrix — the DESIGN.md §8 exactness
    argument.  Shared by the host-driven streaming engine
    (serve/cluster_service.py) and the device-resident ``dist`` data
    plane (serve/dist_service.py); returns (global, maps, pair_d2).

    ``exclude`` ((K,) bool or None) is the quarantine mask forwarded to
    ``merge_from_d2``: excluded shards never patch the matrix (they are
    not in ``dirty``) and are masked out of the fold, but their cached
    rows stay intact so recovery is one ordinary row patch.
    """
    if pair_d2 is None or dirty is None:
        pair_d2 = contour_pair_d2_exact(batch, cfg)
    else:
        dirty = [int(i) for i in dirty]
        if len(dirty) == 1:
            pair_d2 = update_pair_d2(pair_d2, batch, dirty[0], cfg)
        elif len(dirty) > 1:
            width = 1 << (len(dirty) - 1).bit_length()
            padded = dirty + [dirty[-1]] * (width - len(dirty))
            pair_d2 = update_pair_d2_many(
                pair_d2, batch, jnp.asarray(padded, jnp.int32), cfg)
    merged, maps = merge_from_d2(batch, pair_d2, cfg, exclude)
    return merged, maps, pair_d2


@functools.partial(jax.jit, static_argnames=("cfg",))
def merge_many(batch: ClusterSet, cfg: DDCConfig) -> Tuple[ClusterSet, jax.Array]:
    """Fold an arbitrary batch of ClusterSets into one (the paper's
    polygon-overlay step, batched).

    ``batch``: a ClusterSet whose leaves carry a leading stack axis —
    contours (K, C, V, 2), counts/sizes/valid (K, C), overflow (K,).  All
    K·C slots are merged in one shot: the slot×slot min-distance matrix
    comes from one kernel call (``contour_pair_d2``), components are
    the transitive closure of the overlap predicate (contours within
    ``merge_radius`` — the TPU-friendly stand-in for exact polygon
    intersection, DESIGN.md §3/§7; the host oracle uses the exact test),
    and merged contours are re-extracted once per output slot
    (``merge_from_d2``).

    Returns (merged, maps) where maps (K, C) sends every input slot to
    its output slot (or -1) so each contributor can relabel its points
    locally.  Deterministic and order-equivariant: permuting the batch
    permutes ``maps`` rows but yields the identical merged clustering
    (components are ranked by total member count, ties by slot index).
    """
    return merge_from_d2(batch, contour_pair_d2(batch, cfg), cfg)


def merge_pair(
    a: ClusterSet, b: ClusterSet, cfg: DDCConfig
) -> Tuple[ClusterSet, jax.Array, jax.Array]:
    """Merge two ClusterSets — a batch-2 ``merge_many``.

    Returns (merged, map_a, map_b): old-slot → new-slot (or -1) mappings
    so each side can relabel its points locally.  Deterministic and
    symmetric: merge_pair(a, b) and the (b, a) maps agree through
    composition.
    """
    batch = jax.tree.map(lambda x, y: jnp.stack([x, y]), a, b)
    merged, maps = merge_many(batch, cfg)
    return merged, maps[0], maps[1]


# ---------------------------------------------------------------------------
# Phase 2 schedules — thin collective schedules over merge_many
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CommMeter:
    """Trace-time comm-volume accounting for the phase-2 schedules.

    Schedules call the ``add_*`` hooks while they trace.  Every quantity
    is static (permutation lists, gather widths, and buffer shapes are
    all known at trace time), so the meter is exact without instrumenting
    the compiled program.  Fill it by tracing once (e.g.
    ``jit(fn).lower(...)``) and read ``snapshot()``; re-tracing the same
    function re-counts, so ``reset()`` between traces.

    ``bytes_total`` sums message bytes over every lane→lane link (an
    all-gather among K lanes of a B-byte buffer counts K·(K−1)·B, a
    ppermute counts B per (src, dst) pair).  ``merge_steps`` counts
    merge_many invocations on the critical path; ``merge_slots`` sums the
    K·C slot counts those merges closed over.
    """

    bytes_total: int = 0
    collectives: int = 0
    merge_steps: int = 0
    merge_slots: int = 0

    def add_collective(self, links: int, nbytes: int) -> None:
        self.bytes_total += links * nbytes
        self.collectives += 1

    def add_merge(self, batch: int, slots: int) -> None:
        self.merge_steps += 1
        self.merge_slots += batch * slots

    def reset(self) -> None:
        self.bytes_total = self.collectives = 0
        self.merge_steps = self.merge_slots = 0

    def snapshot(self) -> dict:
        return dataclasses.asdict(self)


def _wire_bytes(cs: ClusterSet) -> int:
    from repro.parallel import compress
    return compress.pytree_wire_bytes(cs)


def _permute(tree, axis: str, perm, meter: CommMeter | None):
    if meter is not None:
        meter.add_collective(len(perm), _wire_bytes(tree))
    return jax.tree.map(lambda x: jax.lax.ppermute(x, axis, perm), tree)


def merge_sync(cs: ClusterSet, cfg: DDCConfig, axis: str,
               meter: CommMeter | None = None):
    """Barrier schedule: all-gather every shard's ClusterSet, then ONE
    batched merge_many over all K·C slots (the paper's synchronous model:
    everyone waits for the slowest, then merges).  Collective bytes per
    lane: (K−1)·B.  Returns (global ClusterSet, local-slot → global-slot
    map (C,)).
    """
    k = compat.axis_size(axis)
    me = jax.lax.axis_index(axis)
    if meter is not None:
        meter.add_collective(k * (k - 1), _wire_bytes(cs))
        meter.add_merge(k, cfg.max_clusters)
    gathered = jax.lax.all_gather(cs, axis)   # pytree: leaves (K, ...)
    gcs, maps = merge_many(gathered, cfg)
    my_map = jnp.take(maps, me, axis=0)
    return gcs, jnp.where(cs.valid, my_map, -1)


def merge_async(cs: ClusterSet, cfg: DDCConfig, axis: str,
                meter: CommMeter | None = None):
    """Butterfly (recursive-doubling) schedule: log2(K) ppermute + batch-2
    merge rounds; merge compute of round ℓ overlaps the round ℓ+1 permute
    in XLA's schedule.  Matches the paper's asynchronous model (merge as
    soon as the partner is ready).  Collective bytes per lane: log2(K)·B.
    """
    k = compat.axis_size(axis)
    assert k & (k - 1) == 0, f"async schedule needs power-of-two shards, got {k}"
    me = jax.lax.axis_index(axis)
    my_map = jnp.arange(cfg.max_clusters, dtype=jnp.int32)
    my_map = jnp.where(cs.valid, my_map, -1)

    acc = cs
    rounds = k.bit_length() - 1
    for level in range(rounds):
        stride = 1 << level
        perm = [(i, i ^ stride) for i in range(k)]
        partner_cs = _permute(acc, axis, perm, meter)
        low = (me & stride) == 0
        a = jax.tree.map(lambda s, p: jnp.where(low, s, p), acc, partner_cs)
        b = jax.tree.map(lambda s, p: jnp.where(low, p, s), acc, partner_cs)
        # `a`/`b` ordering is lane-consistent, so both sides compute the
        # identical merged buffer (deterministic merge).
        if meter is not None:
            meter.add_merge(2, cfg.max_clusters)
        acc, map_a, map_b = merge_pair(a, b, cfg)
        mine = jnp.where(low, map_a, map_b)
        my_map = jnp.where(my_map >= 0, mine[jnp.clip(my_map, 0)], -1)
    return acc, my_map


def merge_tree(cs: ClusterSet, cfg: DDCConfig, axis: str,
               meter: CommMeter | None = None):
    """The paper's Algorithm 2: nodes join groups of D, elect the
    lowest-index member as leader, members SEND their contours to the
    leader (ppermute); the leader folds its whole group in ONE batch-D
    merge_many; repeat up the tree until the root holds the global
    clusters, then broadcast down.

    Wire cost per level: each member sends one ClusterSet to its leader
    ((D-1)/D of lanes send), + one broadcast at the end — between sync's
    (K-1)·B all-gather and async's log2(K)·B butterfly.  Unlike the
    butterfly, non-leaders idle above their level (the paper's Fig. 1).
    """
    k = compat.axis_size(axis)
    d = cfg.tree_degree
    me = jax.lax.axis_index(axis)
    my_map = jnp.where(cs.valid, jnp.arange(cfg.max_clusters, dtype=jnp.int32), -1)

    acc = cs
    stride = 1
    while stride < k:
        # Group = lanes {base, base+stride, ..., base+(D-1)*stride};
        # leader = base.  Members send to the leader (one ppermute per
        # member rank — ppermute sources must be unique); the leader
        # closes over the whole group in a single batched merge.
        batch = [acc]
        for j in range(1, d):
            src_off = j * stride
            if src_off >= k:
                break
            perm = [(i, i - src_off) for i in range(k) if i - src_off >= 0
                    and (i // stride) % d == j and (i - src_off) // (stride * d) == i // (stride * d)]
            batch.append(_permute(acc, axis, perm, meter))
        is_leader = (me // stride) % d == 0
        if meter is not None:
            meter.add_merge(len(batch), cfg.max_clusters)
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *batch)
        merged, maps = merge_many(stacked, cfg)
        # Leaders fold; everyone else keeps their acc (their map will be
        # resolved by the broadcast below).  Slot 0 of the batch is the
        # leader's own accumulator.
        acc = jax.tree.map(
            lambda m, a: jnp.where(is_leader, m, a), merged, acc)
        my_map = jnp.where(is_leader & (my_map >= 0),
                           maps[0][jnp.clip(my_map, 0)], my_map)
        stride *= d

    # Root (lane 0) broadcasts the global ClusterSet down the same tree
    # (one ppermute per (level, member) hop — ppermute sources must be
    # unique, so a flat one-to-all broadcast is not expressible).
    gcs = acc
    strides = []
    s = 1
    while s < k:
        strides.append(s)
        s *= d
    for stride in reversed(strides):      # top of the tree first
        for j in range(1, d):
            if j * stride >= k:
                continue
            perm = [(b, b + j * stride) for b in range(0, k, stride * d)
                    if b + j * stride < k]
            moved = _permute(gcs, axis, perm, meter)
            is_receiver = (me % (stride * d)) == j * stride
            gcs = jax.tree.map(
                lambda g, mv: jnp.where(is_receiver, mv, g), gcs, moved)
    # Non-root lanes resolve their local slots against the global set by
    # contour proximity (their intermediate maps stopped at their last
    # leader level).
    resolved = match_to_global(cs, gcs, cfg)
    my_map = jnp.where(me == 0, my_map, resolved)
    return gcs, my_map


def match_to_global(cs: ClusterSet, gcs: ClusterSet, cfg: DDCConfig) -> jax.Array:
    """Map each local cluster to the nearest global cluster (by min
    contour distance, within merge_radius).  Returns (C,) slot ids/-1.

    Short-circuits on empty inputs: when either side has no valid slots
    (an empty shard, or a shard whose points were all noise) the result
    is all -1 by definition, so the per-slot distance scans are skipped
    entirely at runtime (``lax.cond``) instead of being computed eagerly.
    """
    c, v = cfg.max_clusters, cfg.max_verts
    gvalid_pts = geometry.vert_validity(gcs.counts, gcs.valid, v).reshape(c * v)
    gflat = gcs.contours.reshape(c * v, 2)

    def one(i):
        d2 = jnp.sum((cs.contours[i][:, None, :] - gflat[None, :, :]) ** 2, -1)
        vi = (jnp.arange(v) < cs.counts[i]) & cs.valid[i]
        d2 = jnp.where(vi[:, None] & gvalid_pts[None, :], d2, geometry.BIG)
        per_g = jnp.min(d2.reshape(v, c, v), axis=(0, 2))        # (C,)
        best = jnp.argmin(per_g)
        r = cfg.merge_radius
        ok = cs.valid[i] & (per_g[best] <= r * r)
        return jnp.where(ok, best, -1).astype(jnp.int32)

    def compute(_):
        return jax.lax.map(one, jnp.arange(c))

    def empty(_):
        return jnp.full((c,), -1, jnp.int32)

    any_work = jnp.any(cs.valid) & jnp.any(gcs.valid)
    return jax.lax.cond(any_work, compute, empty, None)


def ddc_shard(
    points: jax.Array,
    mask: jax.Array,
    cfg: DDCConfig,
    axis: str,
    key: jax.Array | None = None,
    meter: CommMeter | None = None,
):
    """Full DDC inside ``shard_map``: phase 1 locally, phase 2 across
    ``axis``.  Returns (global labels for local points (n,),
    global ClusterSet, local→global slot map)."""
    dense, cs = local_phase(points, mask, cfg, key)
    if cfg.schedule == "sync":
        gcs, my_map = merge_sync(cs, cfg, axis, meter)
    elif cfg.schedule == "tree":
        gcs, my_map = merge_tree(cs, cfg, axis, meter)
    else:
        gcs, my_map = merge_async(cs, cfg, axis, meter)
    glabels = jnp.where(dense >= 0, my_map[jnp.clip(dense, 0)], -1)
    return glabels, gcs, my_map


def make_ddc_fn(mesh, axis: str, cfg: DDCConfig, meter: CommMeter | None = None):
    """Build the jit-able distributed DDC entry point over ``mesh``.

    points: (N, 2) sharded along ``axis``; mask: (N,).  An optional
    ``meter`` collects static comm-volume counters while the function
    traces (see CommMeter).
    """
    from jax.sharding import PartitionSpec as P

    @jax.jit
    def run(points, mask):
        fn = compat.shard_map(
            lambda p, m: ddc_shard(p, m, cfg, axis, meter=meter),
            mesh=mesh,
            in_specs=(P(axis, None), P(axis)),
            out_specs=(P(axis), P(), P(axis)),
            check_vma=False,
        )
        return fn(points, mask)

    return run


def same_clustering(a: np.ndarray, b: np.ndarray) -> bool:
    """True iff two label arrays describe the IDENTICAL clustering: the
    same noise set (label < 0) and a bijection between cluster labels.
    This is the bit-exactness predicate the phase-2 benchmarks and the
    schedule-equivalence tests apply between the distributed path and
    ``ddc_host``."""
    a = np.asarray(a)
    b = np.asarray(b)
    if ((a < 0) != (b < 0)).any():
        return False
    m = a >= 0
    pairs = set(zip(a[m].tolist(), b[m].tolist()))
    return len(pairs) == len(set(a[m].tolist())) == len(set(b[m].tolist()))


# ---------------------------------------------------------------------------
# Host (paper-faithful) path — NumPy oracle + sequential baseline
# ---------------------------------------------------------------------------


def ddc_host(
    points: np.ndarray,
    n_partitions: int,
    eps: float,
    min_pts: int,
    partition: str = "block",
    contour: str = "hull",
):
    """Reference DDC on the host: dbscan_ref per partition, exact
    polygon-overlap merge (paper's phase-2 predicate).

    ``partition``: "block" (contiguous array_split), "strided", or an
    explicit list of index arrays (one per shard — the streaming serve
    tests hand over the engine's exact per-shard membership, including
    holes left by eviction; ``n_partitions`` is ignored then).

    Returns (global labels (n,), list of merged-cluster polygons,
    exchanged_points: how many contour vertices crossed the 'network' —
    drives the 1–2 % exchange claim).
    """
    n = len(points)
    if isinstance(partition, (list, tuple)):
        parts = [np.asarray(p, dtype=np.int64) for p in partition]
    elif partition == "block":
        parts = np.array_split(np.arange(n), n_partitions)
    else:
        parts = [np.arange(n)[i::n_partitions] for i in range(n_partitions)]
    labels = np.full(n, -1, np.int64)
    polys: list = []       # (part, local_cluster, polygon, member_idx)
    exchanged = 0
    for pi, idx in enumerate(parts):
        if len(idx) == 0:
            continue
        local = dbscan_mod.dbscan_ref(points[idx], eps, min_pts)
        for cid in sorted(set(local[local >= 0])):
            members = idx[local == cid]
            if contour == "hull":
                poly = geometry.convex_hull_np(points[members])
            else:
                x0, y0 = points[:, 0].min(), points[:, 1].min()
                x1, y1 = points[:, 0].max(), points[:, 1].max()
                poly = geometry.grid_contour_np(points[members], (x0, y0, x1, y1), 128)
            polys.append({"members": members, "poly": poly})
            exchanged += len(poly)

    # Union-find over polygons by exact overlap (dilated by eps: two
    # clusters merge when their polygons overlap or come within eps).
    m = len(polys)
    parent = list(range(m))

    def find(i):
        while parent[i] != i:
            parent[i] = parent[parent[i]]
            i = parent[i]
        return i

    def union(i, j):
        ri, rj = find(i), find(j)
        if ri != rj:
            parent[max(ri, rj)] = min(ri, rj)

    for i in range(m):
        for j in range(i + 1, m):
            a, b = polys[i]["poly"], polys[j]["poly"]
            # Hull contours are ordered polygons: exact overlap test.
            # Grid contours are unordered boundary samples: proximity only
            # (this is what preserves non-convexity — a convex hull would
            # wrongly merge a cluster with one that surrounds it, the
            # paper's motivating D1 case).
            if contour == "hull":
                hit = polygons_near(a, b, eps)
            else:
                d = np.sqrt(((a[:, None, :] - b[None, :, :]) ** 2).sum(-1)).min()
                hit = bool(d <= eps * 1.5)
            if hit:
                union(i, j)

    global_ids = {}
    for i in range(m):
        r = find(i)
        gid = global_ids.setdefault(r, len(global_ids))
        labels[polys[i]["members"]] = gid
    return labels, polys, exchanged


def polygons_near(a: np.ndarray, b: np.ndarray, eps: float) -> bool:
    """Exact overlap OR min vertex-to-vertex distance <= eps (clusters
    that touch across a partition boundary merge, matching DBSCAN)."""
    if len(a) == 0 or len(b) == 0:
        return False
    if geometry.polygons_overlap_np(a, b):
        return True
    d = np.sqrt(((a[:, None, :] - b[None, :, :]) ** 2).sum(-1)).min()
    return bool(d <= eps)

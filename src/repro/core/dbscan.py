"""DBSCAN — the paper's local clustering algorithm, in two forms.

* ``dbscan_ref`` — classic BFS DBSCAN in NumPy (the oracle; O(n^2) with
  blockwise distance computation, matching the paper's complexity model).
* ``dbscan`` — TPU-native JAX version: ε-neighbour counts and min-label
  propagation are blocked matmuls (kernels/pairwise_dist.py), cluster
  labels converge by fixed-point iteration under ``lax.while_loop``.

Two composed optimisations make the JAX version near-linear on clustered
spatial data (DESIGN.md §4–§5):

* **Block-sparse spatial pruning** (``block_sparse``): points are sorted
  by Morton code so ε-neighbours land in nearby tiles, per-tile bounding
  boxes prune provably-far tile pairs, and the sweeps run gathered-grid
  kernels over the active-pair list only (dense-kernel fallback when the
  active fraction is high).  Labels come back in caller order, bit-exact
  with the dense path.
* **Pointer doubling** (``pointer_doubling``): each sweep is followed by
  ``labels <- min(labels, labels[labels])`` shortcut steps, collapsing
  label-chase chains so convergence needs O(log n) sweeps instead of
  O(core-graph diameter) — a worm-shaped cluster needs tens, not
  hundreds, of O(n²)-cost sweeps.

Semantics (both): a point is *core* iff its ε-neighbourhood (self
included) has >= min_pts points.  Core points within ε of each other share
a cluster; border points adopt the smallest neighbouring core label;
everything else is noise (-1).  Labels are canonicalised to the smallest
point index in the cluster, so the two implementations agree exactly up
to the tie-break rule for border points shared by several clusters —
both use min-label, making outputs identical.
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import partitioner
from repro.kernels import ops

NOISE = -1
SENTINEL = 2**30

# Runtime dense fallback: when more than this fraction of tile pairs is
# active, bounding-box pruning cannot pay for its gather overhead and the
# sweeps use the dense kernels instead (same math, same results).
DENSE_FALLBACK_FRAC = 0.5


def dbscan_ref(points: np.ndarray, eps: float, min_pts: int) -> np.ndarray:
    """NumPy oracle.  Returns labels (n,) int32, noise = -1, labels are
    the minimum point index of each cluster's core set."""
    pts = np.asarray(points, dtype=np.float64)
    n = len(pts)
    if n == 0:
        return np.zeros((0,), np.int32)
    d2 = ((pts[:, None, :] - pts[None, :, :]) ** 2).sum(-1)
    adj = d2 <= eps * eps
    counts = adj.sum(1)
    core = counts >= min_pts

    labels = np.full(n, SENTINEL, np.int64)
    # Connected components over core points (edges between core pairs).
    for i in range(n):
        if not core[i] or labels[i] != SENTINEL:
            continue
        stack = [i]
        labels[i] = i
        while stack:
            u = stack.pop()
            for v in np.nonzero(adj[u] & core)[0]:
                if labels[v] == SENTINEL:
                    labels[v] = i
                    stack.append(v)
    # Canonicalise: min core index per component.
    for comp in set(labels[core]):
        members = np.nonzero(core & (labels == comp))[0]
        labels[members] = members.min()
    # Border points: min label among core neighbours.
    for i in range(n):
        if core[i]:
            continue
        neigh = np.nonzero(adj[i] & core)[0]
        labels[i] = labels[neigh].min() if len(neigh) else SENTINEL
    labels[labels == SENTINEL] = NOISE
    return labels.astype(np.int32)


class DBSCANResult(NamedTuple):
    labels: jax.Array   # (n,) int32; -1 noise, else min core index
    core: jax.Array     # (n,) bool
    n_clusters: jax.Array  # () int32
    n_sweeps: jax.Array  # () int32 — propagation sweeps to convergence


def _shortcut(labels: jax.Array, steps: int) -> jax.Array:
    """Pointer-doubling: ``labels <- min(labels, labels[labels])``, ``steps``
    times.  Valid because for core i, labels[i] is always the index of a
    core point in the same cluster (so the jump stays in-cluster and is
    monotone non-increasing); SENTINEL entries (non-core / padding, all
    >= n) never jump.  ``steps`` = ceil(log2 n) fully compresses any
    label chain a sweep can produce."""
    n = labels.shape[0]

    def body(_, l):
        jumped = jnp.take(l, jnp.where(l < n, l, 0))
        return jnp.minimum(l, jnp.where(l < n, jumped, l))

    return jax.lax.fori_loop(0, steps, body, labels)


def spatial_sort(points: jax.Array, mask: jax.Array, bt: int):
    """Block-sparse preamble: pad to a ``bt`` multiple and Morton-sort.

    Bounds for the Morton grid come from *masked* points only — padding
    zeros or masked garbage must not stretch the grid (offset data would
    otherwise collapse into one cell and defeat the pruning entirely).
    Masked/padding points sort to the tail tiles.  Returns
    (sorted_points, sorted_mask, order); shared by the benchmark so the
    measured sort is the shipped sort."""
    n = points.shape[0]
    pad = (-n) % bt
    pp = jnp.pad(points, ((0, pad), (0, 0)))
    mm = jnp.pad(mask, (0, pad))
    big = jnp.float32(3.4e38)
    lo = jnp.min(jnp.where(mm[:, None], pp, big), axis=0)
    hi = jnp.max(jnp.where(mm[:, None], pp, -big), axis=0)
    code = partitioner.morton_code(pp, bounds=(lo[0], lo[1], hi[0], hi[1]))
    code = jnp.where(mm, code, jnp.int32(2**30))
    order = jnp.argsort(code)
    return jnp.take(pp, order, axis=0), jnp.take(mm, order), order


def _propagate(sweep_fn, init: jax.Array, core: jax.Array, max_iters: int,
               doubling_steps: int):
    """Iterate min-label sweeps (+ optional pointer doubling) to fixed
    point.  Returns (labels, n_sweeps)."""

    def cond(state):
        _, changed, it = state
        return changed & (it < max_iters)

    def body(state):
        labels, _, it = state
        swept = sweep_fn(labels)
        new = jnp.where(core, jnp.minimum(labels, swept), labels)
        if doubling_steps:
            new = _shortcut(new, doubling_steps)
        return new, jnp.any(new != labels), it + 1

    labels, _, n_sweeps = jax.lax.while_loop(
        cond, body, (init, jnp.asarray(True), jnp.asarray(0, jnp.int32))
    )
    return labels, n_sweeps


@functools.partial(
    jax.jit,
    static_argnames=("min_pts", "max_iters", "block_sparse", "bt",
                     "pointer_doubling", "dense_fallback_frac"),
)
def dbscan(
    points: jax.Array,
    mask: jax.Array,
    eps: float | jax.Array,
    min_pts: int,
    max_iters: int = 512,
    *,
    block_sparse: str = "auto",
    bt: int = 512,
    pointer_doubling: bool = True,
    dense_fallback_frac: float = DENSE_FALLBACK_FRAC,
) -> DBSCANResult:
    """TPU-native DBSCAN on a padded point buffer.

    points: (n, d); mask: (n,) bool (padding excluded everywhere).
    Label propagation: L_i <- min(L_i, min_{j in N(i) ∩ core} L_j) for core
    i, iterated to fixed point; pointer-doubling shortcut steps after each
    sweep bound the sweep count by O(log n) instead of the core-graph
    diameter.

    ``block_sparse``: "never" | "auto" | "always".  "auto" engages the
    Morton-sorted block-sparse path once there are enough points for more
    than one tile pair to exist; within that path, sweeps fall back to
    the dense kernels at runtime when the active-tile fraction exceeds
    ``dense_fallback_frac`` (the sparse and dense paths are bit-identical
    either way).
    """
    assert block_sparse in ("never", "auto", "always"), block_sparse
    n = points.shape[0]
    # Centre on the masked bbox midpoint: d2 is translation-invariant, but
    # the kernels' xx+yy-2xy expansion is cancellation-prone — at coord
    # magnitude ~100 its f32 error rivals eps² and could disagree with the
    # (difference-based, accurate) bbox pruning near the eps boundary.
    # Centring both paths keeps them bit-identical to each other and
    # accurate at any offset.  Masked rows are zeroed so padding never
    # carries large values into the tiles.
    big = jnp.float32(3.4e38)
    lo = jnp.min(jnp.where(mask[:, None], points, big), axis=0)
    hi = jnp.max(jnp.where(mask[:, None], points, -big), axis=0)
    center = jnp.where(hi >= lo, (lo + hi) * 0.5, 0.0)
    points = jnp.where(mask[:, None], points - center, 0.0)
    doubling_steps = max(1, math.ceil(math.log2(max(n, 2)))) if pointer_doubling else 0
    # "auto" engages the sparse path only with enough points for several
    # tiles AND a Pallas backend — on pure-jnp reference backends the
    # sparse fold is sequential, so dense matmuls are the faster CPU path.
    use_sparse_path = block_sparse == "always" or (
        block_sparse == "auto" and n >= 2 * bt and ops.use_pallas_backend()
    )
    if use_sparse_path:
        return _dbscan_block_sparse(
            points, mask, eps, min_pts, max_iters, bt=bt,
            doubling_steps=doubling_steps,
            dense_fallback_frac=dense_fallback_frac,
        )

    counts = ops.neighbor_count(points, mask, eps)
    core = (counts >= min_pts) & mask
    init = jnp.where(core, jnp.arange(n, dtype=jnp.int32), SENTINEL)
    labels, n_sweeps = _propagate(
        lambda l: ops.min_label_sweep(points, mask, l, core, eps),
        init, core, max_iters, doubling_steps,
    )

    # Border points: min core-neighbour label (non-core, in-mask).
    swept = ops.min_label_sweep(points, mask, labels, core, eps)
    labels = jnp.where(core, labels, swept)
    labels = jnp.where(mask & (labels < SENTINEL), labels, SENTINEL)

    # Count clusters: labels that are their own index and core.
    is_root = core & (labels == jnp.arange(n, dtype=jnp.int32))
    n_clusters = jnp.sum(is_root.astype(jnp.int32))
    labels = jnp.where(labels == SENTINEL, NOISE, labels)
    return DBSCANResult(labels, core, n_clusters, n_sweeps)


def _dbscan_block_sparse(
    points: jax.Array,
    mask: jax.Array,
    eps: float | jax.Array,
    min_pts: int,
    max_iters: int,
    *,
    bt: int,
    doubling_steps: int,
    dense_fallback_frac: float,
) -> DBSCANResult:
    """Block-sparse DBSCAN: Morton sort -> bbox tile pruning -> gathered
    sweeps -> canonicalise -> inverse permutation.  Bit-identical to the
    dense path (see DESIGN.md §4 for the argument)."""
    n = points.shape[0]
    sp, sm, order = spatial_sort(points, mask, bt)
    npad = sp.shape[0]

    pairs = ops.build_tile_pairs(sp, sm, eps, bt=bt)
    use_sparse = pairs.frac <= dense_fallback_frac

    def sweep(labels, core):
        return jax.lax.cond(
            use_sparse,
            lambda l, c: ops.min_label_sweep_sparse(sp, sm, l, c, eps, pairs, bt=bt),
            lambda l, c: ops.min_label_sweep(sp, sm, l, c, eps),
            labels, core,
        )

    counts = jax.lax.cond(
        use_sparse,
        lambda: ops.neighbor_count_sparse(sp, sm, eps, pairs, bt=bt),
        lambda: ops.neighbor_count(sp, sm, eps),
    )
    core = (counts >= min_pts) & sm
    init = jnp.where(core, jnp.arange(npad, dtype=jnp.int32), SENTINEL)
    labels, n_sweeps = _propagate(
        lambda l: sweep(l, core), init, core, max_iters, doubling_steps
    )

    # Canonicalise: converged labels hold min *sorted* index per cluster;
    # remap every cluster to its min ORIGINAL index so output labels (and
    # the border-point tie-break below) match the dense path bit-exactly.
    orig = order.astype(jnp.int32)              # sorted slot -> original idx
    root = jnp.where(core, labels, 0)
    min_orig = jnp.full((npad,), SENTINEL, jnp.int32).at[root].min(
        jnp.where(core, orig, SENTINEL)
    )
    canon = jnp.where(core, jnp.take(min_orig, root), SENTINEL)

    # Border points: min canonical core-neighbour label.
    swept = sweep(canon, core)
    labels_s = jnp.where(core, canon, swept)
    labels_s = jnp.where(sm & (labels_s < SENTINEL), labels_s, SENTINEL)

    # Inverse permutation: results back in caller order.
    labels = jnp.zeros((npad,), jnp.int32).at[order].set(labels_s)[:n]
    core_o = jnp.zeros((npad,), bool).at[order].set(core)[:n]

    is_root = core_o & (labels == jnp.arange(n, dtype=jnp.int32))
    n_clusters = jnp.sum(is_root.astype(jnp.int32))
    labels = jnp.where(labels == SENTINEL, NOISE, labels)
    return DBSCANResult(labels, core_o, n_clusters, n_sweeps)


def relabel_dense(labels: jax.Array, max_clusters: int) -> jax.Array:
    """Map arbitrary min-index labels to dense ids [0, max_clusters) by
    cluster-root order; -1 stays -1.  Clusters beyond the budget map to -1
    (callers size ``max_clusters`` generously; overflow is reported by
    ddc.py)."""
    n = labels.shape[0]
    is_root = labels == jnp.arange(n)
    # Rank roots by index.
    root_rank = jnp.cumsum(is_root.astype(jnp.int32)) - 1  # rank at root pos
    dense_at_root = jnp.where(is_root, root_rank, 0)
    safe = jnp.clip(labels, 0, n - 1)
    dense = jnp.take(dense_at_root, safe)
    dense = jnp.where(labels == NOISE, NOISE, dense)
    dense = jnp.where(dense >= max_clusters, NOISE, dense)
    return dense.astype(jnp.int32)

"""DBSCAN — the paper's local clustering algorithm, in two forms.

* ``dbscan_ref`` — classic BFS DBSCAN in NumPy (the oracle; O(n^2) with
  blockwise distance computation, matching the paper's complexity model).
* ``dbscan`` — TPU-native JAX version: ε-neighbour counts and min-label
  propagation are blocked matmuls (kernels/pairwise_dist.py), cluster
  labels converge by fixed-point iteration under ``lax.while_loop``.

Semantics (both): a point is *core* iff its ε-neighbourhood (self
included) has >= min_pts points.  Core points within ε of each other share
a cluster; border points adopt the smallest neighbouring core label;
everything else is noise (-1).  Labels are canonicalised to the smallest
point index in the cluster, so the two implementations agree exactly up
to the tie-break rule for border points shared by several clusters —
both use min-label, making outputs identical.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

NOISE = -1
SENTINEL = 2**30


def dbscan_ref(points: np.ndarray, eps: float, min_pts: int) -> np.ndarray:
    """NumPy oracle.  Returns labels (n,) int32, noise = -1, labels are
    the minimum point index of each cluster's core set."""
    pts = np.asarray(points, dtype=np.float64)
    n = len(pts)
    if n == 0:
        return np.zeros((0,), np.int32)
    d2 = ((pts[:, None, :] - pts[None, :, :]) ** 2).sum(-1)
    adj = d2 <= eps * eps
    counts = adj.sum(1)
    core = counts >= min_pts

    labels = np.full(n, SENTINEL, np.int64)
    # Connected components over core points (edges between core pairs).
    for i in range(n):
        if not core[i] or labels[i] != SENTINEL:
            continue
        stack = [i]
        labels[i] = i
        while stack:
            u = stack.pop()
            for v in np.nonzero(adj[u] & core)[0]:
                if labels[v] == SENTINEL:
                    labels[v] = i
                    stack.append(v)
    # Canonicalise: min core index per component.
    for comp in set(labels[core]):
        members = np.nonzero(core & (labels == comp))[0]
        labels[members] = members.min()
    # Border points: min label among core neighbours.
    for i in range(n):
        if core[i]:
            continue
        neigh = np.nonzero(adj[i] & core)[0]
        labels[i] = labels[neigh].min() if len(neigh) else SENTINEL
    labels[labels == SENTINEL] = NOISE
    return labels.astype(np.int32)


class DBSCANResult(NamedTuple):
    labels: jax.Array   # (n,) int32; -1 noise, else min core index
    core: jax.Array     # (n,) bool
    n_clusters: jax.Array  # () int32


@functools.partial(jax.jit, static_argnames=("min_pts", "max_iters"))
def dbscan(
    points: jax.Array,
    mask: jax.Array,
    eps: float | jax.Array,
    min_pts: int,
    max_iters: int = 512,
) -> DBSCANResult:
    """TPU-native DBSCAN on a padded point buffer.

    points: (n, d); mask: (n,) bool (padding excluded everywhere).
    Label propagation: L_i <- min(L_i, min_{j in N(i) ∩ core} L_j) for core
    i, iterated to fixed point.  Each sweep is a fused blocked matmul
    (never materialises the n×n adjacency in HBM); sweep count is bounded
    by the core-graph diameter and by ``max_iters``.
    """
    n = points.shape[0]
    counts = ops.neighbor_count(points, mask, eps)
    core = (counts >= min_pts) & mask

    init = jnp.where(core, jnp.arange(n, dtype=jnp.int32), SENTINEL)

    def cond(state):
        labels, changed, it = state
        return changed & (it < max_iters)

    def body(state):
        labels, _, it = state
        swept = ops.min_label_sweep(points, mask, labels, core, eps)
        new = jnp.where(core, jnp.minimum(labels, swept), labels)
        return new, jnp.any(new != labels), it + 1

    labels, _, _ = jax.lax.while_loop(
        cond, body, (init, jnp.asarray(True), jnp.asarray(0, jnp.int32))
    )

    # Border points: min core-neighbour label (non-core, in-mask).
    swept = ops.min_label_sweep(points, mask, labels, core, eps)
    labels = jnp.where(core, labels, swept)
    labels = jnp.where(mask & (labels < SENTINEL), labels, SENTINEL)

    # Count clusters: labels that are their own index and core.
    is_root = core & (labels == jnp.arange(n, dtype=jnp.int32))
    n_clusters = jnp.sum(is_root.astype(jnp.int32))
    labels = jnp.where(labels == SENTINEL, NOISE, labels)
    return DBSCANResult(labels, core, n_clusters)


def relabel_dense(labels: jax.Array, max_clusters: int) -> jax.Array:
    """Map arbitrary min-index labels to dense ids [0, max_clusters) by
    cluster-root order; -1 stays -1.  Clusters beyond the budget map to -1
    (callers size ``max_clusters`` generously; overflow is reported by
    ddc.py)."""
    n = labels.shape[0]
    is_root = labels == jnp.arange(n)
    # Rank roots by index.
    root_rank = jnp.cumsum(is_root.astype(jnp.int32)) - 1  # rank at root pos
    dense_at_root = jnp.where(is_root, root_rank, 0)
    safe = jnp.clip(labels, 0, n - 1)
    dense = jnp.take(dense_at_root, safe)
    dense = jnp.where(labels == NOISE, NOISE, dense)
    dense = jnp.where(dense >= max_clusters, NOISE, dense)
    return dense.astype(jnp.int32)

"""Discrete-event simulator of DDC on a heterogeneous cluster.

This container is a single CPU host; the paper's experiments run on eight
heterogeneous desktops (Table 1).  To reproduce the paper's wall-clock
behaviour (Tables 3–6: sync vs async, waiting time, load skew) we model
the cluster explicitly:

* machine i runs phase 1 in  t1_i = c_i * n_i^2  (DBSCAN, O(n^2)) plus a
  contour term  d_i * c log c  — coefficients calibrated from the paper's
  own Table 3 (measured step-1 times vs shard sizes);
* phase 2 is a binary merge tree over machines.  ``sync``: nobody merges
  before the global barrier at max_i(t1_i) (the paper's synchronous
  model; step 2 *includes waiting*, which is how the paper reports it).
  ``async``: each merge fires as soon as both inputs are ready
  (event-driven), so fast machines finish long before stragglers.

The simulator is also used forward-looking: the same event engine with
TPU-pod coefficients drives the straggler-mitigation analysis for the
training framework (capacity-aware sharding, DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence



@dataclasses.dataclass(frozen=True)
class MachineSpec:
    name: str
    step1_coeff: float        # ms per point^2 (DBSCAN)
    contour_coeff: float = 2e-4   # ms per point*log(point) (reduction)
    merge_ms: float = 150.0   # ms per pairwise contour merge
    link_ms: float = 40.0     # ms per contour transfer (latency + tiny payload)
    async_poll_ms: float = 20.0  # readiness bookkeeping per async merge
    # (paper §5.4: "in the asynchronous model the machines still need to
    # execute the algorithm that checks which one finished first", which
    # is why sync wins slightly when loads are balanced — Table 6)


# Coefficients calibrated from paper Table 3 (step1 time / size^2):
PAPER_MACHINES = [
    MachineSpec("M1-XPS", 21270 / 10000**2),
    MachineSpec("M2-Insp3721", 1060 / 2500**2),
    MachineSpec("M3-Insp3521", 5093 / 3275**2),
    MachineSpec("M4-iMac2010", 4592 / 5000**2),
    MachineSpec("M5-Insp5559", 227 / 1666**2),
    MachineSpec("M6-iMac2009", 292 / 2000**2),
    MachineSpec("M7-MacAir", 7520 / 5000**2),
    MachineSpec("M8-extra", 200 / 1500**2),
]


@dataclasses.dataclass
class SimResult:
    step1: list[float]        # per-machine phase-1 compute time (ms)
    step2: list[float]        # per-machine phase-2 time incl. waiting (ms)
    total: list[float]        # per-machine completion time (ms)
    makespan: float           # overall completion (ms)
    idle: list[float]         # per-machine waiting time inside step 2


def phase1_time(m: MachineSpec, n_points: int) -> float:
    t = m.step1_coeff * n_points * n_points
    c = max(int(0.02 * n_points), 2)  # contour input: the cluster points
    return t + m.contour_coeff * c * math.log2(c)


def simulate(
    machines: Sequence[MachineSpec],
    sizes: Sequence[int],
    schedule: str = "async",
) -> SimResult:
    """Simulate one DDC run.  Binary merge tree over machine index
    (leader = lower index of each pair, as in the paper's leader election).
    """
    k = len(machines)
    assert k == len(sizes) and k & (k - 1) == 0, (k, len(sizes))
    t1 = [phase1_time(m, n) for m, n in zip(machines, sizes)]

    done = list(t1)  # completion time per machine (leaf done when sent)

    def merge_cost(m: MachineSpec, combined_shards: int) -> float:
        # Merging accumulates contours: deeper merges handle more clusters
        # (paper: phase-2 complexity grows with total contour vertices).
        import math
        return m.merge_ms * (1 + 0.75 * max(math.log2(combined_shards) - 1, 0))

    if schedule == "sync":
        # Barrier at max(t1), then a fixed binary merge tree (the paper's
        # synchronous model: nobody merges before everyone finished).
        barrier = max(t1)
        ready = [barrier] * k
        level = 1
        while level < k:
            for base in range(0, k, 2 * level):
                leader, peer = base, base + level
                arrive = ready[peer] + machines[peer].link_ms
                start = max(ready[leader], arrive)
                ready[leader] = start + merge_cost(machines[leader], 2 * level)
            level *= 2
        makespan = ready[0]
        # Paper convention: in the sync model every machine blocks until
        # the global merge finishes (Tables 3–5 report near-equal totals).
        done = [makespan] * k
    else:
        # Event-driven: repeatedly merge the two earliest-ready contours
        # ("machines which finished early can advance to the next step").
        # The later-arriving side pays the link; the waiting side leads the
        # merge and pays merge + poll bookkeeping (paper §5.4).
        frontier = [(t1[i], i, 1) for i in range(k)]
        while len(frontier) > 1:
            frontier.sort()
            (r1, i1, s1), (r2, i2, s2) = frontier[0], frontier[1]
            leader, peer = i1, i2              # earliest-ready leads
            arrive = r2 + machines[peer].link_ms
            start = max(r1, arrive)
            finish = (start + merge_cost(machines[leader], s1 + s2)
                      + machines[leader].async_poll_ms)
            done[peer] = max(done[peer], arrive)
            done[leader] = finish
            frontier = frontier[2:] + [(finish, leader, s1 + s2)]
        makespan = frontier[0][0]

    step2 = [d - t for d, t in zip(done, t1)]
    busy2 = [machines[i].merge_ms * _merges_led(i, k) for i in range(k)]
    idle = [max(s - b, 0.0) for s, b in zip(step2, busy2)]
    return SimResult(
        step1=t1, step2=step2, total=list(done), makespan=makespan, idle=idle
    )


def _merges_led(i: int, k: int) -> int:
    led = 0
    level = 1
    while level < k:
        if i % (2 * level) == 0:
            led += 1
        level *= 2
    return led


def sequential_time(machine: MachineSpec, n_points: int) -> float:
    """T1 for the speedup experiment: full dataset on one machine, no
    reduction / aggregation (paper §5.5)."""
    return machine.step1_coeff * n_points * n_points

"""DDC — the paper's primary contribution.

- dbscan / kmeans: local clustering (phase 1 compute)
- geometry: contours (the 1–2 % reduction) + overlap predicates
- ddc: ClusterSet buffers, merge_pair, sync/async phase-2 schedules,
  shard_map distributed entry point, host oracle
- partitioner: block / random / spatial / capacity-aware splits
- simulate: heterogeneous-cluster event simulator (paper Tables 3–6)
"""
from . import dbscan, ddc, geometry, kmeans, partitioner, simulate  # noqa: F401

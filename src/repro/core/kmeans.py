"""K-Means (Lloyd) — the paper's second local-clustering algorithm.

DDC is algorithm-agnostic in phase 1; the paper evaluates both K-Means
and DBSCAN.  This is a masked, static-shape JAX implementation with
k-means++ seeding, used by the data-curation pipeline (embedding
clustering) and by DDC when cfg.local_algo == "kmeans".
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops


class KMeansResult(NamedTuple):
    labels: jax.Array      # (n,) int32
    centroids: jax.Array   # (k, d)
    inertia: jax.Array     # () f32


def kmeanspp_init(key: jax.Array, points: jax.Array, mask: jax.Array, k: int) -> jax.Array:
    """k-means++ seeding on a masked buffer (vectorised, O(k·n·d))."""
    n, d = points.shape
    big = 1e30

    def pick(key, weights):
        return jax.random.categorical(key, jnp.log(jnp.maximum(weights, 1e-30)))

    k0, key = jax.random.split(key)
    first = pick(k0, mask.astype(jnp.float32))
    cents = jnp.zeros((k, d), points.dtype).at[0].set(points[first])
    d2 = jnp.where(mask, jnp.sum((points - points[first]) ** 2, -1), 0.0)

    def body(i, state):
        key, cents, d2 = state
        ki, key = jax.random.split(key)
        nxt = pick(ki, d2)
        cents = cents.at[i].set(points[nxt])
        nd = jnp.where(mask, jnp.sum((points - points[nxt]) ** 2, -1), 0.0)
        return key, cents, jnp.minimum(d2, nd)

    _, cents, _ = jax.lax.fori_loop(1, k, body, (key, cents, d2))
    return cents


@functools.partial(jax.jit, static_argnames=("k", "iters"))
def kmeans(
    key: jax.Array, points: jax.Array, mask: jax.Array, k: int, iters: int = 25
) -> KMeansResult:
    cents = kmeanspp_init(key, points, mask, k)

    def step(cents, _):
        d2 = ops.pairwise_dist_sq(points, cents)           # (n, k)
        d2 = jnp.where(mask[:, None], d2, 0.0)
        labels = jnp.argmin(d2, axis=1)
        onehot = jax.nn.one_hot(labels, k, dtype=points.dtype) * mask[:, None]
        sums = onehot.T @ points                            # (k, d)
        cnts = jnp.sum(onehot, axis=0)[:, None]
        new = jnp.where(cnts > 0, sums / jnp.maximum(cnts, 1), cents)
        return new, None

    cents, _ = jax.lax.scan(step, cents, None, length=iters)
    d2 = ops.pairwise_dist_sq(points, cents)
    labels = jnp.where(mask, jnp.argmin(d2, axis=1), -1).astype(jnp.int32)
    inertia = jnp.sum(jnp.where(mask, jnp.min(d2, axis=1), 0.0))
    return KMeansResult(labels, cents, inertia)

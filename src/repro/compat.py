"""Version-compatibility shims for jax APIs that moved between releases.

The repo targets the modern surface (``jax.shard_map``,
``jax.sharding.AxisType``, ``jax.make_mesh(..., axis_types=...)``); older
installs (e.g. 0.4.x) expose the same functionality under
``jax.experimental.shard_map`` with a ``check_rep`` kwarg and build meshes
without axis types.  Everything in the repo that touches these APIs routes
through here so the difference lives in exactly one module.
"""
from __future__ import annotations

import jax

# Optional in older jax: mesh axis types (Auto/Explicit/Manual).
AxisType = getattr(jax.sharding, "AxisType", None)

_HAS_TOPLEVEL_SHARD_MAP = hasattr(jax, "shard_map")
if not _HAS_TOPLEVEL_SHARD_MAP:
    from jax.experimental.shard_map import shard_map as _exp_shard_map


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with graceful fallback to the experimental API.

    ``check_vma`` (new name) and ``check_rep`` (old name) gate the same
    replication check; callers use the new name only.
    """
    if _HAS_TOPLEVEL_SHARD_MAP:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    return _exp_shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma)


def axis_size(axis: str):
    """``jax.lax.axis_size`` fallback: psum of a unit constant folds to the
    (static) mesh axis size on older jax."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis)
    return jax.lax.psum(1, axis)


def _auto_axis_types(n: int):
    if AxisType is None:
        return None
    return (AxisType.Auto,) * n


def make_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types where supported."""
    shape = tuple(shape)
    axes = tuple(axes)
    types = _auto_axis_types(len(axes))
    if types is not None:
        try:
            return jax.make_mesh(shape, axes, axis_types=types)
        except TypeError:
            pass  # make_mesh predates the axis_types kwarg
    return jax.make_mesh(shape, axes)


def abstract_mesh(shape, axes):
    """Device-free ``AbstractMesh`` across both constructor generations."""
    from jax.sharding import AbstractMesh

    shape = tuple(shape)
    axes = tuple(axes)
    types = _auto_axis_types(len(axes))
    if types is not None:
        try:
            return AbstractMesh(shape, axes, axis_types=types)
        except TypeError:
            pass  # old signature: a single tuple of (name, size) pairs
    return AbstractMesh(tuple(zip(axes, shape)))

"""kimi-k2-1t-a32b [moe] — trillion-param MoE (paper-table config).

61L d_model=7168 64H (GQA kv=8) vocab=163840, MoE 384 experts top-8,
expert d_ff=2048, +1 shared expert [arXiv:2501.kimi2].  head_dim=128
(decoupled from d_model/heads=112 for MXU alignment — noted).  Adam
state for 1T params exceeds pod HBM; the training recipe for this arch
defaults to Adafactor + bf16 params (EXPERIMENTS.md).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=2048,           # expert hidden dim per assignment
    vocab=163840,
    n_experts=384,
    topk=8,
    moe_d_ff=2048,
    n_shared_experts=1,
    shared_d_ff=2048,
)

"""llama4-scout-17b-a16e [moe] — MoE 16 experts top-1 + shared expert.

48L d_model=5120 40H (GQA kv=8) expert d_ff=8192 vocab=202048
[hf:meta-llama/Llama-4-Scout-17B-16E].  Early-fusion multimodality is
out of scope for the LM backbone cells (text path only).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=202048,
    n_experts=16,
    topk=1,
    moe_d_ff=8192,
    n_shared_experts=1,
    shared_d_ff=8192,
)

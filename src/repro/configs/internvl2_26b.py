"""internvl2-26b [vlm] — InternLM2-20B language backbone; InternViT
frontend is a STUB (input_specs() provides 256 precomputed patch
embeddings as a prefix).

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553 [arXiv:2404.16821].
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=92553,
    frontend="vision_stub",
    prefix_len=256,
)

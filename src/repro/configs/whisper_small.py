"""whisper-small [audio] — enc-dec, conv frontend stubbed.

12L d_model=768 12H (GQA kv=12) d_ff=3072 vocab=51865 [arXiv:2212.04356].
The audio conv frontend is a STUB: input_specs() provides precomputed
frame embeddings (B, 1500, d).  Positional: sinusoid on both stacks
(whisper's decoder uses learned positions up to 448; we use sinusoid so
the assigned 32k-seq stress shapes are well-defined — noted in DESIGN.md).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab=51865,
    encoder_layers=12,
    frontend="audio_stub",
    frontend_seq=1500,
    pos_embed="sinusoid",
    act="gelu",
    norm="layernorm",
)

"""mamba2-1.3b [ssm] — attention-free SSD (state-space duality).

48L d_model=2048 d_ff=0 vocab=50280 ssm_state=128 [arXiv:2405.21060].
Pure Mamba-2 blocks (no MLP sublayer), d_inner = 2*d_model, head_dim 64.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    n_layers=48,
    d_model=2048,
    n_heads=1,
    n_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab=50280,
    block_pattern=("mamba",),
    ssm_state=128,
    ssm_head_dim=64,
    tie_embeddings=True,
)

"""Assigned input-shape set (same 4 shapes for every LM arch).

``train_*`` lowers train_step; ``prefill_*`` lowers the serving prefill;
``decode_*`` / ``long_*`` lower serve_step (one new token against a KV
cache of seq_len).  long_500k applies only to sub-quadratic archs
(SSM / hybrid) — full-attention archs skip it (DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def applicable(arch_cfg, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether (arch, shape) is a valid cell, and why not if not."""
    if shape.name == "long_500k":
        subquad = any(k == "mamba" for k in arch_cfg.block_pattern) or (
            arch_cfg.long_window is not None
        )
        if not subquad:
            return False, "pure full-attention arch: O(S^2) at 500k — skipped per assignment"
    return True, ""

"""Architecture registry: ``--arch <id>`` resolves here.

All 10 assigned architectures + the paper's own spatial-clustering
configuration (``ddc_spatial``) for the DDC dry-run.
"""
from __future__ import annotations

import importlib

from repro.configs.shapes import SHAPES, ShapeConfig, applicable  # noqa: F401

ARCHS = {
    "whisper-small": "whisper_small",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "minicpm3-4b": "minicpm3_4b",
    "qwen3-8b": "qwen3_8b",
    "granite-20b": "granite_20b",
    "jamba-1.5-large-398b": "jamba_1_5_large",
    "kimi-k2-1t-a32b": "kimi_k2",
    "llama4-scout-17b-a16e": "llama4_scout",
    "internvl2-26b": "internvl2_26b",
    "mamba2-1.3b": "mamba2_1_3b",
}


def get_config(arch: str):
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch]}")
    return mod.CONFIG


def all_archs() -> list[str]:
    return list(ARCHS)

"""minicpm3-4b [dense] — MLA (multi-head latent attention).

62L d_model=2560 40H d_ff=6400 vocab=73448 [hf:openbmb/MiniCPM3-4B].
MLA dims follow the MiniCPM3 defaults: q_lora 768, kv_lora 256,
rope 32, nope 64, v_head 64.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    head_dim=64,
    d_ff=6400,
    vocab=73448,
    attn_kind="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_rope_dim=32,
    qk_nope_dim=64,
    v_head_dim=64,
)

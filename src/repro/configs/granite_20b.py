"""granite-20b [dense] — llama-arch code model with MQA (kv=1).

52L d_model=6144 48H (kv=1) d_ff=24576 vocab=49152 [arXiv:2405.04324].
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab=49152,
    act="gelu",  # GPT-BigCode-style 2-matrix MLP (brings totals to ~20B)
)

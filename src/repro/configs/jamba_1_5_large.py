"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave, MoE.

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2
[arXiv:2403.19887].  Pattern group = 8 layers (attn at position 4, the
rest Mamba-2/SSD — we use SSD for all SSM blocks, DESIGN.md §3); MoE on
every other layer (even pattern positions).  At long_500k the attention
layers switch to a 4k local window (ring cache) — Mamba layers carry the
long-range state.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab=65536,
    n_experts=16,
    topk=2,
    moe_d_ff=24576,
    moe_pattern=(0, 2, 4, 6),
    block_pattern=("mamba", "mamba", "mamba", "mamba", "attn",
                   "mamba", "mamba", "mamba"),
    ssm_state=128,
    ssm_head_dim=64,
    long_window=4096,
)

"""Data substrate: synthetic spatial benchmarks, token pipeline, DDC-driven
curation."""
from . import spatial  # noqa: F401

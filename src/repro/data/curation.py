"""DDC-driven data curation — the paper's clustering as a first-class
feature of the training framework (DESIGN.md §4).

Documents are embedded (here: provided 2-D embeddings; in production,
any encoder) and clustered with *distributed* DDC on the training mesh:
each data shard clusters its local embeddings (phase 1, zero comm), the
1–2 % contour representatives are hierarchically merged (phase 2), and
the resulting global clusters drive:

* cluster-balanced sampling weights (upweight rare clusters), and
* dedup candidates (documents in the same dense cluster core).

This is exactly the paper's pitch — analyse big data where it lives,
exchange only representatives — applied to LM data pipelines.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import ddc
from repro.data.pipeline import DataConfig


@dataclasses.dataclass
class CurationResult:
    labels: np.ndarray          # (n_docs,) global cluster id (-1 noise)
    n_clusters: int
    cluster_sizes: np.ndarray
    sample_weights: np.ndarray  # per-cluster balanced sampling weights
    exchanged_fraction: float   # bytes exchanged / raw embedding bytes


def curate(
    embeddings: np.ndarray,
    mesh=None,
    axis: str = "data",
    cfg: ddc.DDCConfig | None = None,
    temperature: float = 0.5,
) -> CurationResult:
    """Cluster document embeddings with DDC and derive sampling weights.

    With a mesh: distributed shard_map DDC across ``axis``; without: the
    host path.  Weights ∝ (1 / cluster_size)^temperature, normalised —
    temperature=0 keeps natural frequency, 1 is fully balanced.
    """
    n = len(embeddings)
    cfg = cfg or ddc.DDCConfig(
        eps=0.04, min_pts=4, grid=128, max_clusters=64, max_verts=64
    )
    if mesh is not None:
        k = mesh.shape[axis]
        pad = (-n) % k
        pts = np.pad(embeddings, ((0, pad), (0, 0)))
        mask = np.arange(len(pts)) < n
        run = ddc.make_ddc_fn(mesh, axis, cfg)
        glabels, gcs, _ = run(jnp.asarray(pts), jnp.asarray(mask))
        labels = np.asarray(glabels)[:n]
        wire = cfg.buffer_bytes() * (k.bit_length() - 1 if cfg.schedule == "async" else k - 1)
        exchanged = wire / (n * embeddings.itemsize * embeddings.shape[1])
    else:
        labels, polys, exch_pts = ddc.ddc_host(
            embeddings, 8, eps=cfg.eps, min_pts=cfg.min_pts
        )
        exchanged = exch_pts / max(n, 1)

    ids = sorted(set(labels[labels >= 0]))
    remap = {c: i for i, c in enumerate(ids)}
    labels = np.array([remap.get(l, -1) for l in labels])
    sizes = np.bincount(labels[labels >= 0], minlength=len(ids)).astype(np.float64)
    w = (1.0 / np.maximum(sizes, 1)) ** temperature
    w = w / w.sum() if len(w) else np.ones(1)
    return CurationResult(
        labels=labels,
        n_clusters=len(ids),
        cluster_sizes=sizes,
        sample_weights=w,
        exchanged_fraction=float(exchanged),
    )


def apply_to_data_config(dcfg: DataConfig, result: CurationResult,
                         doc_clusters: np.ndarray) -> DataConfig:
    """Map DDC clusters onto the synthetic pipeline's latent clusters and
    install balanced weights."""
    k = dcfg.n_latent_clusters
    weights = np.ones(k)
    for latent in range(k):
        members = result.labels[doc_clusters == latent]
        members = members[members >= 0]
        if len(members):
            ddc_cluster = np.bincount(members).argmax()
            weights[latent] = result.sample_weights[ddc_cluster]
    weights /= weights.sum()
    return dataclasses.replace(dcfg, curation_weights=weights)

"""Token data pipeline: deterministic, restart-safe, shardable.

Synthetic corpus (offline container) with structure: a mixture of
"documents" drawn from latent clusters so that DDC-based curation has
real signal to find.  The pipeline is stateless-by-construction — batch
``i`` is a pure function of (seed, i) — so checkpoint/restart needs no
iterator state (fault tolerance) and any host can produce exactly its
own shard (multi-host determinism).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_latent_clusters: int = 16
    frontend: str = "none"
    frontend_seq: int = 0
    prefix_len: int = 0
    d_model: int = 0
    curation_weights: np.ndarray | None = None  # per-cluster sample weights


def _doc_tokens(rng: np.random.Generator, cluster: int, cfg: DataConfig) -> np.ndarray:
    """A 'document': cluster-specific unigram distribution (Zipf-ish)."""
    base = np.arange(cfg.vocab, dtype=np.float64) + 1.0
    probs = 1.0 / base ** 1.1
    crng = np.random.default_rng(1000 + cluster)
    boost_ids = crng.choice(cfg.vocab, 64, replace=False)
    probs[boost_ids] *= 50.0
    probs /= probs.sum()
    return rng.choice(cfg.vocab, cfg.seq_len, p=probs).astype(np.int32)


def batch_at(cfg: DataConfig, index: int) -> dict:
    """Batch ``index`` as numpy host arrays (pure function of seed+index)."""
    rng = np.random.default_rng((cfg.seed, index))
    weights = cfg.curation_weights
    if weights is None:
        weights = np.ones(cfg.n_latent_clusters)
    p = np.asarray(weights, np.float64)
    p = p / p.sum()
    clusters = rng.choice(cfg.n_latent_clusters, cfg.global_batch, p=p)
    tokens = np.stack([_doc_tokens(rng, int(c), cfg) for c in clusters])
    batch = {"tokens": tokens}
    if cfg.frontend == "audio_stub":
        batch["frames"] = rng.normal(
            0, 0.3, (cfg.global_batch, cfg.frontend_seq, cfg.d_model)
        ).astype(np.float32)
    if cfg.prefix_len:
        batch["prefix"] = rng.normal(
            0, 0.3, (cfg.global_batch, cfg.prefix_len, cfg.d_model)
        ).astype(np.float32)
    return batch


def iterate(cfg: DataConfig, start_step: int = 0) -> Iterator[dict]:
    i = start_step
    while True:
        yield batch_at(cfg, i)
        i += 1


def doc_embeddings(cfg: DataConfig, n_docs: int, dim: int = 2,
                   seed: int = 7) -> tuple[np.ndarray, np.ndarray]:
    """2-D embeddings of synthetic docs (cluster structure preserved) —
    the input to DDC curation.  Returns (embeddings, true cluster ids)."""
    rng = np.random.default_rng(seed)
    k = cfg.n_latent_clusters
    g = int(np.ceil(np.sqrt(k)))
    centers = (np.stack(np.meshgrid(np.arange(g), np.arange(g)), -1)
               .reshape(-1, 2)[:k] + 0.5) / g
    ids = rng.integers(0, k, n_docs)
    emb = centers[ids] + rng.normal(0, 0.02, (n_docs, 2))
    return np.clip(emb, 0, 1).astype(np.float32), ids.astype(np.int32)

"""Synthetic spatial benchmark datasets.

The paper uses two Chameleon benchmark sets (D1: 10 000 points, nested
shapes; D2: 30 000 points, circles + linked ovals) from
http://cs.uef.fi/sipu/datasets/ — not downloadable in this offline
container, so we synthesise datasets with the same described structure and
sizes (noted in DESIGN.md): shape mixes with clusters surrounded by
other clusters, plus background noise.  All generators are deterministic
in ``seed`` and return float32 (n, 2) in [0, 1]^2.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np


def _ring(rng, n, cx, cy, r, width):
    theta = rng.uniform(0, 2 * np.pi, n)
    rad = r + rng.normal(0, width, n)
    return np.stack([cx + rad * np.cos(theta), cy + rad * np.sin(theta)], -1)


def _blob(rng, n, cx, cy, sx, sy=None, rot=0.0):
    sy = sx if sy is None else sy
    pts = rng.normal(0, 1, (n, 2)) * [sx, sy]
    c, s = np.cos(rot), np.sin(rot)
    pts = pts @ np.array([[c, -s], [s, c]]).T
    return pts + [cx, cy]


def _moon(rng, n, cx, cy, r, width, start, end):
    theta = rng.uniform(start, end, n)
    rad = r + rng.normal(0, width, n)
    return np.stack([cx + rad * np.cos(theta), cy + rad * np.sin(theta)], -1)


def make_d1(n: int = 10_000, seed: int = 0, noise_frac: float = 0.04) -> np.ndarray:
    """D1 analogue: different shapes, some clusters surrounded by others."""
    rng = np.random.default_rng(seed)
    n_noise = int(n * noise_frac)
    n_sig = n - n_noise
    w = np.array([0.22, 0.10, 0.18, 0.14, 0.14, 0.12, 0.10])
    counts = np.maximum((w / w.sum() * n_sig).astype(int), 1)
    counts[0] += n_sig - counts.sum()
    parts = [
        _ring(rng, counts[0], 0.30, 0.65, 0.16, 0.012),       # ring ...
        _blob(rng, counts[1], 0.30, 0.65, 0.025),             # ... surrounding a blob
        _moon(rng, counts[2], 0.72, 0.72, 0.13, 0.012, 0.25, np.pi - 0.25),
        _moon(rng, counts[3], 0.78, 0.56, 0.13, 0.012, np.pi + 0.25, 2 * np.pi - 0.25),
        _blob(rng, counts[4], 0.22, 0.22, 0.07, 0.03, 0.6),   # tilted ellipse
        _blob(rng, counts[5], 0.62, 0.22, 0.03),
        _blob(rng, counts[6], 0.84, 0.30, 0.025),
    ]
    noise = rng.uniform(0, 1, (n_noise, 2))
    pts = np.concatenate(parts + [noise])
    return np.clip(pts, 0.0, 1.0).astype(np.float32)


def make_d2(n: int = 30_000, seed: int = 1, noise_frac: float = 0.04) -> np.ndarray:
    """D2 analogue: 2 small circles, 1 big circle, 2 linked ovals."""
    rng = np.random.default_rng(seed)
    n_noise = int(n * noise_frac)
    n_sig = n - n_noise
    w = np.array([0.30, 0.12, 0.12, 0.23, 0.23])
    counts = np.maximum((w / w.sum() * n_sig).astype(int), 1)
    counts[0] += n_sig - counts.sum()
    big = _ring(rng, counts[0], 0.32, 0.68, 0.20, 0.02)
    c1 = _ring(rng, counts[1], 0.75, 0.80, 0.07, 0.015)
    c2 = _ring(rng, counts[2], 0.85, 0.55, 0.07, 0.015)
    ov1 = _blob(rng, counts[3], 0.40, 0.25, 0.10, 0.035, 0.5)
    ov2 = _blob(rng, counts[4], 0.58, 0.20, 0.10, 0.035, -0.5)  # linked: overlaps ov1
    noise = rng.uniform(0, 1, (n_noise, 2))
    pts = np.concatenate([big, c1, c2, ov1, ov2, noise])
    return np.clip(pts, 0.0, 1.0).astype(np.float32)


def make_clustered(n: int, k: int = 8, seed: int = 0,
                   spread: float = 0.02) -> np.ndarray:
    """k Gaussian blobs at uniform-random centres — the benchmark layout
    where most tile pairs are prunable (block-sparse phase 1)."""
    rng = np.random.default_rng(seed)
    centers = rng.uniform(0.1, 0.9, (k, 2))
    pts = centers[rng.integers(0, k, n)] + rng.normal(0, spread, (n, 2))
    return pts.astype(np.float32)


def make_worm(n: int, seed: int = 1, waves: int = 3, amp: float = 0.2,
              width: float = 0.004) -> np.ndarray:
    """Long thin noisy sine curve: core-graph diameter ~ curve length/ε —
    the worst case for plain label sweeping (pointer-doubling benchmark)."""
    rng = np.random.default_rng(seed)
    t = np.linspace(0, 1, n)
    pts = np.stack([t, 0.5 + amp * np.sin(2 * waves * np.pi * t)], -1)
    return (pts + rng.normal(0, width, (n, 2))).astype(np.float32)


def _disc(rng, n, cx, cy, a, b=None, rot=0.0):
    """Uniform-density filled ellipse — no Gaussian tails, so cluster
    extents are sharp and DBSCAN boundaries are seed-stable."""
    b = a if b is None else b
    t = rng.uniform(0, 2 * np.pi, n)
    r = np.sqrt(rng.uniform(0, 1, n))
    pts = np.stack([a * r * np.cos(t), b * r * np.sin(t)], -1)
    c, s = np.cos(rot), np.sin(rot)
    return pts @ np.array([[c, -s], [s, c]]).T + [cx, cy]


def morton_sorted(pts: np.ndarray) -> np.ndarray:
    """Reorder points by 2-D Morton (Z-order) code so *contiguous index
    blocks are spatially compact* — the order block-partitioned shards
    (and ``ddc_host``'s default split) see from a spatial partitioner.
    Without it, a high shard count hands every shard a sparse subsample
    of each shape and local density collapses below ``min_pts``."""
    from repro.core import partitioner

    code = np.asarray(partitioner.morton_code(pts))
    return pts[np.argsort(code, kind="stable")]


def make_rings(n: int = 2048, seed: int = 2) -> np.ndarray:
    """Rings scenario (phase-2 benchmark): a ring *surrounding* a disc —
    the non-convexity case where a convex-hull contour would wrongly
    merge the pair — plus two separate rings.  Morton-ordered."""
    rng = np.random.default_rng(seed)
    w = np.array([0.34, 0.12, 0.27, 0.27])
    c = (w / w.sum() * n).astype(int)
    c[0] += n - c.sum()
    parts = [
        _ring(rng, c[0], 0.30, 0.64, 0.095, 0.004),
        _disc(rng, c[1], 0.30, 0.64, 0.010),
        _ring(rng, c[2], 0.74, 0.78, 0.050, 0.004),
        _ring(rng, c[3], 0.72, 0.20, 0.050, 0.004),
    ]
    return morton_sorted(np.clip(np.concatenate(parts), 0, 1).astype(np.float32))


def make_linked_ovals(n: int = 2048, seed: int = 3) -> np.ndarray:
    """Linked-ovals scenario (phase-2 benchmark): two overlapping tilted
    ovals that must merge into one global cluster across any partition
    cut, plus a separate small oval.  Morton-ordered."""
    rng = np.random.default_rng(seed)
    w = np.array([0.4, 0.4, 0.2])
    c = (w / w.sum() * n).astype(int)
    c[0] += n - c.sum()
    parts = [
        _disc(rng, c[0], 0.38, 0.56, 0.14, 0.05, 0.5),
        _disc(rng, c[1], 0.56, 0.50, 0.14, 0.05, -0.5),   # linked: overlaps
        _disc(rng, c[2], 0.82, 0.16, 0.07, 0.03, 0.2),
    ]
    return morton_sorted(np.clip(np.concatenate(parts), 0, 1).astype(np.float32))


def make_noise_heavy(n: int = 2048, seed: int = 4,
                     noise_frac: float = 0.3) -> np.ndarray:
    """Noise-heavy scenario (phase-2 benchmark): five compact uniform
    discs under 30 % background noise — exercises noise rejection, empty
    merge slots, and (at high shard counts) fully-noise shards.
    Morton-ordered."""
    rng = np.random.default_rng(seed)
    n_noise = int(n * noise_frac)
    n_sig = n - n_noise
    centers = np.array([[0.2, 0.2], [0.2, 0.8], [0.8, 0.2], [0.8, 0.8], [0.5, 0.5]])
    per = n_sig // 5
    parts = [
        _disc(rng, per + (n_sig - 5 * per if i == 0 else 0), cx, cy, 0.055)
        for i, (cx, cy) in enumerate(centers)
    ]
    noise = rng.uniform(0, 1, (n_noise, 2))
    return morton_sorted(
        np.clip(np.concatenate(parts + [noise]), 0, 1).astype(np.float32))


# Phase-2 benchmark/test layout registry: generator + the DDC parameters
# (eps, min_pts, grid, max_verts, max_clusters) tuned so every local AND
# merged contour fits the vertex budget at 2–32 shards and inter-cluster
# gaps clear both merge predicates with margin (DESIGN.md §7 sizing
# rule).  benchmarks/phase2.py and tests/_phase2_script.py consume this
# single table so the benchmark and the equivalence suite can never
# drift onto different configurations.
PHASE2_LAYOUTS = {
    "rings": dict(make=make_rings, eps=0.008, min_pts=5,
                  grid=64, max_verts=80, max_clusters=8),
    "linked_ovals": dict(make=make_linked_ovals, eps=0.012, min_pts=5,
                         grid=48, max_verts=88, max_clusters=8),
    # Worm: the *merged* contour must hold the whole curve's boundary
    # (the tree schedule resolves non-leader slots against it), so the
    # raster is coarse enough that the global outline fits max_verts.
    "worm": dict(make=lambda n, seed=1: morton_sorted(
                     make_worm(n, seed=seed, waves=1, amp=0.1)),
                 eps=0.012, min_pts=5, grid=32, max_verts=96,
                 max_clusters=8),
    "noise_heavy": dict(make=make_noise_heavy, eps=0.012, min_pts=8,
                        grid=48, max_verts=64, max_clusters=8),
}


def shard_capacity(n: int, shards: int) -> int:
    """Ring slots per shard so a block partition of ``n`` points fits
    exactly: the largest ``np.array_split`` part, i.e. ceil(n/shards).
    The one sizing rule shared by the stream backend, the serve
    benchmarks/launchers, and the equivalence tests."""
    return max(-(-n // shards), 1)


def stream_batches(pts: np.ndarray, shards: int, batch: int,
                   order: str = "round_robin", seed: int | None = None):
    """Deterministic ingest schedule for the streaming serve engine.

    Block-partitions ``pts`` into ``shards`` contiguous parts (the same
    ``np.array_split`` ``ddc_host`` uses, so streaming≡batch equivalence
    compares identical per-shard memberships), slices each part into
    ``batch``-point chunks, and returns a list of (shard, chunk) pairs:

    * ``round_robin`` — interleave shards chunk-by-chunk (steady traffic
      touching every shard in turn);
    * ``sequential`` — all of shard 0's chunks, then shard 1's, …;
    * ``shuffled`` — a ``seed``-deterministic permutation of the chunks
      (the hypothesis equivalence suite draws ``seed``).

    Any order yields the same final per-shard point sets, which is
    exactly the property the streaming≡batch suite exercises.
    """
    parts = np.array_split(np.arange(len(pts)), shards)
    per_shard = [
        [(s, pts[idx[o:o + batch]]) for o in range(0, len(idx), batch)]
        for s, idx in enumerate(parts)
    ]
    if order == "sequential":
        return [c for chunks in per_shard for c in chunks]
    rounds = max((len(c) for c in per_shard), default=0)
    interleaved = [chunks[r] for r in range(rounds)
                   for chunks in per_shard if r < len(chunks)]
    if order == "round_robin":
        return interleaved
    if order == "shuffled":
        rng = np.random.default_rng(seed)
        return [interleaved[i] for i in rng.permutation(len(interleaved))]
    raise ValueError(order)


# --------------------------------------------------------------------------
# Trajectory stream generators (cluster tracking, serve/tracking.py).
#
# Each generator produces a deterministic sequence of per-step point
# frames plus the ground-truth per-step centre and velocity field of
# every moving group.  Frames are Morton-ordered so a block partition
# hands each shard a spatially compact subset (same reasoning as
# ``morton_sorted`` above), which keeps per-shard density above
# ``min_pts`` at 8 shards.  One frame == one refresh generation.
# --------------------------------------------------------------------------


class Trajectory(NamedTuple):
    """A seeded moving-cluster stream.

    ``frames[t]`` is the (n_t, 2) float32 point cloud ingested at step
    ``t``; ``centers[t, b]`` / ``velocities[t, b]`` are the true centre
    and per-step displacement of group ``b`` at that step (the velocity
    field the tracker's analytics are checked against).
    """

    frames: tuple
    centers: np.ndarray       # (steps, B, 2) float64
    velocities: np.ndarray    # (steps, B, 2) float64


def _frames_from_paths(rng, centers, radii, weights, n_per_step):
    """Render centre paths into per-step Morton-ordered point frames."""
    steps, nb = centers.shape[:2]
    w = np.asarray(weights, np.float64)
    counts = np.maximum((w / w.sum() * n_per_step).astype(int), 1)
    counts[0] += n_per_step - counts.sum()
    frames = []
    for t in range(steps):
        parts = [
            _disc(rng, counts[b], centers[t, b, 0], centers[t, b, 1], radii[b])
            for b in range(nb)
        ]
        frames.append(morton_sorted(
            np.clip(np.concatenate(parts), 0, 1).astype(np.float32)))
    return tuple(frames)


def make_drifting_blobs(steps: int = 24, n_per_step: int = 96,
                        n_blobs: int = 3, seed: int = 0,
                        speed: float = 0.015,
                        radius: float = 0.05) -> Trajectory:
    """``n_blobs`` uniform discs drifting horizontally in separate
    lanes, bouncing off the arena walls — lanes are far apart so the
    groups never interact and a perfect tracker reports only
    continuations after the first generation (the ID-stability
    layout)."""
    rng = np.random.default_rng(seed)
    ys = (np.linspace(0.2, 0.8, n_blobs) if n_blobs > 1
          else np.array([0.5]))
    xs = rng.uniform(0.25, 0.75, n_blobs)
    vx = speed * rng.uniform(0.75, 1.25, n_blobs)
    vx *= np.where(np.arange(n_blobs) % 2 == 0, 1.0, -1.0)
    lo, hi = 0.12, 0.88
    centers = np.zeros((steps, n_blobs, 2))
    velocities = np.zeros((steps, n_blobs, 2))
    for t in range(steps):
        for b in range(n_blobs):
            nxt = xs[b] + vx[b]
            if nxt < lo or nxt > hi:       # bounce off the wall
                vx[b] = -vx[b]
            xs[b] += vx[b]
            centers[t, b] = (xs[b], ys[b])
            velocities[t, b] = (vx[b], 0.0)
    frames = _frames_from_paths(
        rng, centers, [radius] * n_blobs, [1.0] * n_blobs, n_per_step)
    return Trajectory(frames, centers, velocities)


def make_merging_crowds(steps: int = 24, n_per_step: int = 96,
                        seed: int = 1, speed: float = 0.02,
                        radius: float = 0.055) -> Trajectory:
    """Two crowds walking toward each other along one lane: they fuse
    into a single global cluster mid-run (merge event) and separate
    again after crossing (split event).  A stationary bystander group
    checks that unrelated tracks keep their IDs throughout."""
    rng = np.random.default_rng(seed)
    centers = np.zeros((steps, 3, 2))
    velocities = np.zeros((steps, 3, 2))
    for t in range(steps):
        centers[t, 0] = (0.22 + speed * t, 0.5)
        centers[t, 1] = (0.78 - speed * t, 0.5)
        centers[t, 2] = (0.5, 0.88)
        velocities[t, 0] = (speed, 0.0)
        velocities[t, 1] = (-speed, 0.0)
    frames = _frames_from_paths(
        rng, centers, [radius, radius, 0.04], [0.4, 0.4, 0.2], n_per_step)
    return Trajectory(frames, centers, velocities)


def make_convoys(steps: int = 20, n_per_step: int = 96, seed: int = 2,
                 speed: float = 0.02, radius: float = 0.04) -> Trajectory:
    """Two convoys of two vehicles each, moving in opposite lanes with a
    shared per-convoy velocity; in-convoy spacing stays above the merge
    radius — including the trail of window-aged points each vehicle
    drags behind it — so each vehicle keeps its own track while the
    analytics see the convoy's common heading."""
    rng = np.random.default_rng(seed)
    centers = np.zeros((steps, 4, 2))
    velocities = np.zeros((steps, 4, 2))
    for t in range(steps):
        centers[t, 0] = (0.10 + speed * t, 0.30)   # convoy A, eastbound
        centers[t, 1] = (0.36 + speed * t, 0.30)
        centers[t, 2] = (0.90 - speed * t, 0.72)   # convoy B, westbound
        centers[t, 3] = (0.64 - speed * t, 0.72)
        velocities[t, 0] = velocities[t, 1] = (speed, 0.0)
        velocities[t, 2] = velocities[t, 3] = (-speed, 0.0)
    frames = _frames_from_paths(
        rng, centers, [radius] * 4, [1.0] * 4, n_per_step)
    return Trajectory(frames, centers, velocities)


# Trajectory layout registry: generator + DDC parameters + the stream
# shape (steps, points per step, sliding-window length in steps).  Tuned
# like PHASE2_LAYOUTS: contours fit the vertex budget at 2-8 shards,
# inter-group gaps clear the merge radius (eps + 1.5*cell ≈ 0.051), and
# the per-step displacement stays well inside the match gate so
# continuations are unambiguous.  benchmarks/tracking.py and
# tests/test_tracking.py consume this single table.
TRAJECTORY_LAYOUTS = {
    "drifting_blobs": dict(make=make_drifting_blobs, eps=0.02, min_pts=3,
                           grid=48, max_verts=96, max_clusters=8,
                           steps=24, n_per_step=96, window=4),
    "merging_crowds": dict(make=make_merging_crowds, eps=0.02, min_pts=3,
                           grid=48, max_verts=96, max_clusters=8,
                           steps=24, n_per_step=96, window=4),
    "convoys": dict(make=make_convoys, eps=0.02, min_pts=3,
                    grid=48, max_verts=96, max_clusters=8,
                    steps=20, n_per_step=96, window=4),
}


def trajectory_capacity(n_per_step: int, window: int, shards: int) -> int:
    """Ring slots per shard for a windowed trajectory run: the largest
    per-frame block-partition part times the frames live at once (the
    window plus the frame ingested before that step's eviction)."""
    return shard_capacity(n_per_step, shards) * (window + 1)


def make_blobs(
    n: int, k: int, seed: int = 0, spread: float = 0.02, margin: float = 0.12
) -> tuple[np.ndarray, np.ndarray]:
    """Well-separated Gaussian blobs (used by property tests: DDC must
    agree with sequential DBSCAN here).  Returns (points, true_labels)."""
    rng = np.random.default_rng(seed)
    # Centres on a jittered grid so blobs stay >= margin apart.
    g = int(np.ceil(np.sqrt(k)))
    cells = [(i, j) for i in range(g) for j in range(g)][:k]
    centers = (np.array(cells) + 0.5) / g
    centers += rng.uniform(-0.25 / g + margin / 4, 0.25 / g - margin / 4, centers.shape)
    labels = rng.integers(0, k, n)
    pts = centers[labels] + rng.normal(0, spread, (n, 2))
    return np.clip(pts, 0, 1).astype(np.float32), labels.astype(np.int32)

"""Synthetic spatial benchmark datasets.

The paper uses two Chameleon benchmark sets (D1: 10 000 points, nested
shapes; D2: 30 000 points, circles + linked ovals) from
http://cs.uef.fi/sipu/datasets/ — not downloadable in this offline
container, so we synthesise datasets with the same described structure and
sizes (noted in DESIGN.md): shape mixes with clusters surrounded by
other clusters, plus background noise.  All generators are deterministic
in ``seed`` and return float32 (n, 2) in [0, 1]^2.
"""
from __future__ import annotations

import numpy as np


def _ring(rng, n, cx, cy, r, width):
    theta = rng.uniform(0, 2 * np.pi, n)
    rad = r + rng.normal(0, width, n)
    return np.stack([cx + rad * np.cos(theta), cy + rad * np.sin(theta)], -1)


def _blob(rng, n, cx, cy, sx, sy=None, rot=0.0):
    sy = sx if sy is None else sy
    pts = rng.normal(0, 1, (n, 2)) * [sx, sy]
    c, s = np.cos(rot), np.sin(rot)
    pts = pts @ np.array([[c, -s], [s, c]]).T
    return pts + [cx, cy]


def _moon(rng, n, cx, cy, r, width, start, end):
    theta = rng.uniform(start, end, n)
    rad = r + rng.normal(0, width, n)
    return np.stack([cx + rad * np.cos(theta), cy + rad * np.sin(theta)], -1)


def make_d1(n: int = 10_000, seed: int = 0, noise_frac: float = 0.04) -> np.ndarray:
    """D1 analogue: different shapes, some clusters surrounded by others."""
    rng = np.random.default_rng(seed)
    n_noise = int(n * noise_frac)
    n_sig = n - n_noise
    w = np.array([0.22, 0.10, 0.18, 0.14, 0.14, 0.12, 0.10])
    counts = np.maximum((w / w.sum() * n_sig).astype(int), 1)
    counts[0] += n_sig - counts.sum()
    parts = [
        _ring(rng, counts[0], 0.30, 0.65, 0.16, 0.012),       # ring ...
        _blob(rng, counts[1], 0.30, 0.65, 0.025),             # ... surrounding a blob
        _moon(rng, counts[2], 0.72, 0.72, 0.13, 0.012, 0.25, np.pi - 0.25),
        _moon(rng, counts[3], 0.78, 0.56, 0.13, 0.012, np.pi + 0.25, 2 * np.pi - 0.25),
        _blob(rng, counts[4], 0.22, 0.22, 0.07, 0.03, 0.6),   # tilted ellipse
        _blob(rng, counts[5], 0.62, 0.22, 0.03),
        _blob(rng, counts[6], 0.84, 0.30, 0.025),
    ]
    noise = rng.uniform(0, 1, (n_noise, 2))
    pts = np.concatenate(parts + [noise])
    return np.clip(pts, 0.0, 1.0).astype(np.float32)


def make_d2(n: int = 30_000, seed: int = 1, noise_frac: float = 0.04) -> np.ndarray:
    """D2 analogue: 2 small circles, 1 big circle, 2 linked ovals."""
    rng = np.random.default_rng(seed)
    n_noise = int(n * noise_frac)
    n_sig = n - n_noise
    w = np.array([0.30, 0.12, 0.12, 0.23, 0.23])
    counts = np.maximum((w / w.sum() * n_sig).astype(int), 1)
    counts[0] += n_sig - counts.sum()
    big = _ring(rng, counts[0], 0.32, 0.68, 0.20, 0.02)
    c1 = _ring(rng, counts[1], 0.75, 0.80, 0.07, 0.015)
    c2 = _ring(rng, counts[2], 0.85, 0.55, 0.07, 0.015)
    ov1 = _blob(rng, counts[3], 0.40, 0.25, 0.10, 0.035, 0.5)
    ov2 = _blob(rng, counts[4], 0.58, 0.20, 0.10, 0.035, -0.5)  # linked: overlaps ov1
    noise = rng.uniform(0, 1, (n_noise, 2))
    pts = np.concatenate([big, c1, c2, ov1, ov2, noise])
    return np.clip(pts, 0.0, 1.0).astype(np.float32)


def make_clustered(n: int, k: int = 8, seed: int = 0,
                   spread: float = 0.02) -> np.ndarray:
    """k Gaussian blobs at uniform-random centres — the benchmark layout
    where most tile pairs are prunable (block-sparse phase 1)."""
    rng = np.random.default_rng(seed)
    centers = rng.uniform(0.1, 0.9, (k, 2))
    pts = centers[rng.integers(0, k, n)] + rng.normal(0, spread, (n, 2))
    return pts.astype(np.float32)


def make_worm(n: int, seed: int = 1, waves: int = 3, amp: float = 0.2,
              width: float = 0.004) -> np.ndarray:
    """Long thin noisy sine curve: core-graph diameter ~ curve length/ε —
    the worst case for plain label sweeping (pointer-doubling benchmark)."""
    rng = np.random.default_rng(seed)
    t = np.linspace(0, 1, n)
    pts = np.stack([t, 0.5 + amp * np.sin(2 * waves * np.pi * t)], -1)
    return (pts + rng.normal(0, width, (n, 2))).astype(np.float32)


def make_blobs(
    n: int, k: int, seed: int = 0, spread: float = 0.02, margin: float = 0.12
) -> tuple[np.ndarray, np.ndarray]:
    """Well-separated Gaussian blobs (used by property tests: DDC must
    agree with sequential DBSCAN here).  Returns (points, true_labels)."""
    rng = np.random.default_rng(seed)
    # Centres on a jittered grid so blobs stay >= margin apart.
    g = int(np.ceil(np.sqrt(k)))
    cells = [(i, j) for i in range(g) for j in range(g)][:k]
    centers = (np.array(cells) + 0.5) / g
    centers += rng.uniform(-0.25 / g + margin / 4, 0.25 / g - margin / 4, centers.shape)
    labels = rng.integers(0, k, n)
    pts = centers[labels] + rng.normal(0, spread, (n, 2))
    return np.clip(pts, 0, 1).astype(np.float32), labels.astype(np.int32)

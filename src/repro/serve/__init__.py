"""Serving substrates: the LM prefill/decode engine (engine.py) and the
streaming DDC cluster service (cluster_service.py).

The cluster-service re-export is lazy (PEP 562) so importing the LM
engine does not drag in the whole clustering stack, and vice versa.
"""

_CLUSTER_EXPORTS = ("ClusterService", "StreamConfig")


def __getattr__(name):
    if name in _CLUSTER_EXPORTS:
        from repro.serve import cluster_service
        return getattr(cluster_service, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

"""Serving substrates: the LM prefill/decode engine (engine.py) and the
streaming DDC cluster services (cluster_service.py: host-mirror control
plane + host-driven data plane; dist_service.py: the same control plane
over a device-resident shard_map data plane).  faults.py / journal.py
are the failure model riding both (DESIGN.md §11): seeded fault
injection, the delta validation gate, and the write-ahead recovery log.
query_tier.py is the high-QPS read path riding on top (DESIGN.md §12):
immutable versioned snapshots published at refresh, coalesced batched
queries with pow2 shape bucketing, and the QueryResult/ServiceStats
API contract.  hierarchy.py is the tree-of-aggregators (DESIGN.md §13)
both engines swap in for the flat aggregator when ``agg_degree`` is set.
tracking.py is the cluster tracking subsystem (DESIGN.md §14): stable
track IDs, lifecycle events, and motion analytics folded over the
refresh generations of either engine.

The cluster-service re-exports are lazy (PEP 562) so importing the LM
engine does not drag in the whole clustering stack, and vice versa.
"""

_CLUSTER_EXPORTS = ("ClusterService", "ShardControlPlane", "StreamConfig")
_DIST_EXPORTS = ("DistClusterService",)
_HIERARCHY_EXPORTS = ("AggregatorTree",)
_QUERY_TIER_EXPORTS = ("QueryResult", "QueryTier", "QueueFull", "Snapshot",
                       "ServiceStats", "ServiceCounters", "ServiceGauges",
                       "route_snapshot")
_FAULT_EXPORTS = ("FAULT_KINDS", "FaultEvent", "FaultPlan", "FaultError",
                  "DeltaDropped", "LaneKilled", "DeltaValidationError",
                  "RecoveryError")
_JOURNAL_EXPORTS = ("Journal",)
_TRACKING_EXPORTS = ("ClusterTracker", "TrackSnapshot", "TrackView",
                     "TrackEvent")


def __getattr__(name):
    if name in _CLUSTER_EXPORTS:
        from repro.serve import cluster_service
        return getattr(cluster_service, name)
    if name in _DIST_EXPORTS:
        from repro.serve import dist_service
        return getattr(dist_service, name)
    if name in _HIERARCHY_EXPORTS:
        from repro.serve import hierarchy
        return getattr(hierarchy, name)
    if name in _QUERY_TIER_EXPORTS:
        from repro.serve import query_tier
        return getattr(query_tier, name)
    if name in _FAULT_EXPORTS:
        from repro.serve import faults
        return getattr(faults, name)
    if name in _JOURNAL_EXPORTS:
        from repro.serve import journal
        return getattr(journal, name)
    if name in _TRACKING_EXPORTS:
        from repro.serve import tracking
        return getattr(tracking, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

"""Seeded, deterministic fault injection for the serve stack.

The serve engines (PRs 3-5) exchange one fixed-size ClusterSet per dirty
shard per refresh.  This module models everything that can go wrong on
that exchange — and at the snapshot boundary — as a reproducible
``FaultPlan``: a seeded schedule of :class:`FaultEvent` s keyed on each
shard's *delivery ordinal* (how many deltas that shard has attempted to
deliver so far), so a chaos run replays bit-for-bit regardless of how
refreshes are numbered or interleaved.

Injectable fault kinds (``FAULT_KINDS``):

* ``drop``    — the delta never arrives; ``attempts`` consecutive
  deliveries are lost, so ``attempts <= max_retries`` is healed by the
  per-refresh retry loop and anything more quarantines the shard.
* ``delay``   — a one-attempt transient drop (always healed by retry).
* ``dup``     — a late duplicate of an already-merged delta shows up;
  the epoch fence must discard it (exactly-once merge).
* ``corrupt`` — slot metadata mangled out of range (vertex counts /
  sizes); the validation gate must reject it before the pair-d2 cache
  is touched.
* ``poison``  — NaN/inf contour vertices; likewise gated.
* ``kill``    — the lane dies mid-refresh: its device buffers are lost
  and the shard must be quarantined until journal-replay recovery.

Plus ``torn_snapshot``: the next ``DDC.save`` is truncated mid-write
(the byte-torn file must fail ``DDC.load`` with ``SnapshotError``).

This module is deliberately jax-free (numpy only): the fault seam and
the validation gate run on host-side payload copies in the control
plane, never inside jitted code.
"""
from __future__ import annotations

import dataclasses

import numpy as np

FAULT_KINDS = ("drop", "delay", "dup", "corrupt", "poison", "kill")


class FaultError(RuntimeError):
    """Base class for injected transport faults."""


class DeltaDropped(FaultError):
    """The shard's delta never reached the aggregator this attempt."""


class LaneKilled(FaultError):
    """The shard's device lane died mid-refresh; its buffers are lost."""


class DeltaValidationError(ValueError):
    """An incoming delta failed the aggregator's validation gate."""


class RecoveryError(RuntimeError):
    """Journal replay diverged from the authoritative host mirrors."""


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.

    ``delivery`` is the 0-based ordinal of the shard's delta deliveries
    at which the event fires; ``None`` means "the shard's next
    delivery, whenever that is" (handy for benches that arm a fault at
    steady state).  ``attempts`` only matters for ``drop``: how many
    consecutive delivery attempts of that delta are lost.
    """
    kind: str
    shard: int
    delivery: int | None = None
    attempts: int = 1

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")
        if self.attempts < 1:
            raise ValueError("attempts must be >= 1")


class FaultPlan:
    """A seeded, deterministic schedule of faults.

    The plan is consulted once per (shard, delivery attempt) by the
    control plane's delta-exchange seam; per-shard delivery counters
    live here so the same plan object must not be shared between
    services.  Corruption payloads are drawn from a private
    ``default_rng(seed)`` so two runs with equal plans mangle
    identically.
    """

    def __init__(self, events: tuple = (), torn_snapshot: bool = False,
                 seed: int = 0):
        self.events = tuple(events)
        for ev in self.events:
            if not isinstance(ev, FaultEvent):
                raise TypeError(f"expected FaultEvent, got {type(ev)}")
        self.torn_snapshot = bool(torn_snapshot)
        self.seed = int(seed)
        self._rng = np.random.default_rng(self.seed)
        self._deliveries: dict = {}   # shard -> deliveries attempted
        self._consumed: set = set()   # event indices that can't refire
        self._torn_used = False

    @classmethod
    def random(cls, seed: int, shards: int, n_faults: int = 3,
               horizon: int = 2, kinds=FAULT_KINDS,
               max_drop_attempts: int = 4,
               torn_snapshot: bool = False) -> "FaultPlan":
        """Draw a reproducible plan: ``n_faults`` events on distinct
        (shard, delivery) cells within the first ``horizon`` deliveries
        of each shard."""
        rng = np.random.default_rng(seed)
        cells = [(s, d) for s in range(shards) for d in range(horizon)]
        picks = rng.choice(len(cells), size=min(n_faults, len(cells)),
                           replace=False)
        events = []
        for p in picks:
            shard, delivery = cells[int(p)]
            kind = str(rng.choice(list(kinds)))
            attempts = int(rng.integers(1, max_drop_attempts + 1)) \
                if kind == "drop" else 1
            events.append(FaultEvent(kind=kind, shard=shard,
                                     delivery=delivery, attempts=attempts))
        return cls(events=tuple(events), torn_snapshot=torn_snapshot,
                   seed=seed)

    def on_delta(self, shard: int, attempt: int) -> FaultEvent | None:
        """The delta-exchange seam: called once per delivery attempt of
        ``shard``'s current delta.  ``attempt`` 0 is the first send of a
        new delta (it advances the shard's delivery ordinal); higher
        attempts are the refresh loop's retries of the same delta."""
        if attempt == 0:
            self._deliveries[shard] = self._deliveries.get(shard, -1) + 1
        ordinal = self._deliveries.get(shard, 0)
        for i, ev in enumerate(self.events):
            if i in self._consumed or ev.shard != shard:
                continue
            if ev.delivery is not None and ev.delivery != ordinal:
                continue
            if ev.kind == "drop":
                if attempt < ev.attempts:
                    return ev
                self._consumed.add(i)   # delta finally got through
                continue
            if attempt > 0:
                # one-shot kinds fire on the first attempt only
                continue
            self._consumed.add(i)
            return ev
        return None

    def mangle(self, kind: str, payload: dict) -> dict:
        """Deterministically corrupt a host-side delta payload (dict of
        numpy arrays: contours/counts/sizes/valid/overflow)."""
        out = {k: np.array(v, copy=True) for k, v in payload.items()}
        if kind == "poison":
            flat = out["contours"].reshape(-1)
            i = int(self._rng.integers(0, flat.size))
            j = int(self._rng.integers(0, flat.size))
            flat[i] = np.nan
            flat[j] = np.inf
        elif kind == "corrupt":
            slot = int(self._rng.integers(0, out["counts"].size))
            out["counts"].reshape(-1)[slot] = -7 if self._rng.integers(2) \
                else 7 * (out["contours"].shape[-2] + 1)
            out["sizes"].reshape(-1)[slot] = -5
        else:
            raise ValueError(f"mangle does not apply to kind {kind!r}")
        return out

    def take_torn_snapshot(self) -> bool:
        """One-shot: should the next snapshot write be torn?"""
        if self.torn_snapshot and not self._torn_used:
            self._torn_used = True
            return True
        return False


def validate_delta(payload: dict, cfg) -> None:
    """The aggregator's validation gate: every incoming delta is checked
    BEFORE it can touch the mirror or the cached pair-d2 matrix.  Raises
    :class:`DeltaValidationError` on the first violation."""
    contours = np.asarray(payload["contours"])
    counts = np.asarray(payload["counts"])
    sizes = np.asarray(payload["sizes"])
    if not np.isfinite(contours).all():
        raise DeltaValidationError("non-finite contour vertices")
    if counts.size and (counts.min() < 0 or counts.max() > cfg.max_verts):
        raise DeltaValidationError(
            f"slot vertex counts outside [0, {cfg.max_verts}]")
    if sizes.size and sizes.min() < 0:
        raise DeltaValidationError("negative cluster sizes")


def tear_snapshot(path: str, keep_frac: float = 0.5) -> None:
    """Simulate a torn (partial) snapshot write by byte-truncating the
    state file in place, as a crashed writer would leave it."""
    import os
    target = os.path.join(path, "state.npz")
    size = os.path.getsize(target)
    with open(target, "r+b") as f:
        f.truncate(max(1, int(size * keep_frac)))

"""High-QPS query tier: snapshot-versioned reads over coalesced batches.

The paper's architectural claim — results "are not affected by the types
of communications" — only buys a *serving* story if reads stop riding
the ingest/refresh path.  This module is that decoupling (DESIGN.md
§12): after every refresh the engine publishes an immutable, versioned
``Snapshot`` (global ClusterSet view + per-shard read buffers + routing
bboxes + the quarantine set, stamped with the refresh epoch that
produced it), and the ``QueryTier`` answers every read from the last
published snapshot while the next delta refresh runs.  Ingest and query
become independent pipelines that meet only at the snapshot swap.

Three mechanisms:

* **Snapshot publish/swap** — a snapshot is cut atomically at the end of
  a successful refresh, from one consistent engine state (buffers,
  labels, bboxes, quarantine all observed at the same instant).  Its
  arrays are fresh copies, never aliases of the engine's donated device
  buffers, so a query racing the next refresh can never observe a torn
  state: it sees version V in full or V+1 in full, nothing in between.
  Versions are monotonic; a query answered from snapshot V is
  bit-identical to a synchronous query against a service frozen at V
  (tests/_query_tier_script.py proves this per layout × shard count ×
  engine).
* **Coalescing + pow2 bucketing** — concurrent requests whose ε-dilated
  bbox scan sets overlap are folded into ONE batched kernel launch over
  the union scan set (exact: a shard outside a request's own scan set
  provably holds no point within ε of its queries, so it can neither
  produce a hit nor steal an argmin tie from one — the same argument
  that makes routing exact).  Query widths and scan-set widths are both
  padded to powers of two, so the jit cache holds at most
  (#query-buckets × #shard-buckets) entries no matter the traffic mix —
  asserted by tests via ``snapshot_query_cache_entries()``.
* **Bounded queue + deadlines + degraded reads** — ``submit`` refuses
  work past ``queue_depth`` (backpressure, ``QueueFull``); a request
  whose deadline has passed by serve time is still answered from the
  current snapshot (a fast possibly-stale answer beats no answer) and
  counted in ``deadline_misses``.  Quarantine (DESIGN.md §11) carries
  over: shards quarantined *at publish time* are routed around exactly
  like the synchronous path; shards quarantined *after* the snapshot was
  cut still serve their last-good rows — both cases surface as
  ``QueryResult.degraded=True``.

``QueryResult`` replaces the bare ndarray the engines used to return:
labels + the snapshot ``version`` they came from + the ``degraded`` flag
+ the ``scanned_shards`` routing set + per-request ``latency_ms``.  It
duck-types as its own labels array (``__array__``, comparisons,
indexing), so pre-redesign callers keep working unchanged.
``ServiceStats`` is the matching read side: one typed stats contract
(monotonic ``ServiceCounters`` vs point-in-time ``ServiceGauges``)
surfaced identically by all four backends, with dict views preserving
the legacy ``stats()``/``comm_stats()`` keys.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# QueryResult — the structured read-path return value
# ---------------------------------------------------------------------------


class QueryResult:
    """Labels plus the read-path metadata the bare ndarray hid.

    * ``labels`` — (n,) int32 global cluster id per query point (-1 noise);
    * ``version`` — the snapshot version that answered (0: the empty
      service short-circuit, before any snapshot exists);
    * ``degraded`` — True iff a quarantined shard could have mattered:
      either routed around (quarantined at publish) or served stale
      (quarantined after this snapshot was cut);
    * ``scanned_shards`` — the request's own bbox-routed scan set;
    * ``latency_ms`` — submit→answer wall clock for this request.

    Deprecation shim: the object duck-types as ``labels`` (``__array__``,
    comparisons, indexing, attribute forwarding), so callers written
    against the old ``np.ndarray`` return keep working verbatim.
    """

    __slots__ = ("labels", "version", "degraded", "scanned_shards",
                 "latency_ms")

    def __init__(self, labels: np.ndarray, version: int = 0,
                 degraded: bool = False,
                 scanned_shards: Tuple[int, ...] = (),
                 latency_ms: float = 0.0):
        self.labels = np.asarray(labels, np.int32)
        self.version = int(version)
        self.degraded = bool(degraded)
        self.scanned_shards = tuple(int(s) for s in scanned_shards)
        self.latency_ms = float(latency_ms)

    # -- ndarray duck-typing (the legacy-caller shim) -----------------------

    def __array__(self, dtype=None, copy=None):
        out = self.labels if dtype is None else self.labels.astype(dtype)
        return np.array(out) if copy else out

    def __len__(self):
        return len(self.labels)

    def __iter__(self):
        return iter(self.labels)

    def __getitem__(self, idx):
        return self.labels[idx]

    def __eq__(self, other):
        return self.labels == np.asarray(other)

    def __ne__(self, other):
        return self.labels != np.asarray(other)

    def __lt__(self, other):
        return self.labels < np.asarray(other)

    def __le__(self, other):
        return self.labels <= np.asarray(other)

    def __gt__(self, other):
        return self.labels > np.asarray(other)

    def __ge__(self, other):
        return self.labels >= np.asarray(other)

    # Defining __eq__ normally sets __hash__ = None (unhashable) — but the
    # elementwise comparisons above are an ndarray shim, not value equality,
    # so identity hashing is the right contract: callers may dedupe results
    # in a set / key a dict on them (each submit() is a distinct result).
    __hash__ = object.__hash__

    def __getattr__(self, name):
        # Fallback for ndarray attributes/methods (shape, tolist, all, …).
        return getattr(object.__getattribute__(self, "labels"), name)

    def __repr__(self):
        return (f"QueryResult(n={len(self.labels)}, version={self.version}, "
                f"degraded={self.degraded}, "
                f"scanned_shards={self.scanned_shards}, "
                f"latency_ms={self.latency_ms:.3f})")


# ---------------------------------------------------------------------------
# Snapshot — the immutable published read view
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Snapshot:
    """One consistent, immutable read view of a serve engine.

    Cut atomically at the end of a refresh (or a restore): every field
    below was observed from the same engine state, and the arrays are
    copies — the engine's donated ring buffers are never aliased — so
    holding a Snapshot across later ingests/refreshes is always safe.
    """

    version: int                    # monotonic publish counter (1-based)
    epoch: int                      # engine refresh count that produced it
    published_at: float             # time.monotonic() at publish
    eps: float
    pts: jax.Array                  # (K, cap, 2) f32, device-resident
    mask: jax.Array                 # (K, cap) bool live mask
    glabels: jax.Array              # (K, cap) int32 global labels
    bboxes: Tuple[Optional[tuple], ...]   # per-shard live bbox (None: empty)
    quarantined: frozenset          # shards quarantined at publish time
    n_live: int
    n_clusters: int

    @property
    def shards(self) -> int:
        return len(self.bboxes)

    def age(self) -> float:
        return time.monotonic() - self.published_at


# The one bbox-dilation constant shared by every routing path (the control
# plane's synchronous ``_route``, the dist lanes' scan flags derived from it,
# and ``route_snapshot`` below).  The 1e-6 relative slack absorbs the f32
# round-trip of points through the ring buffers: a query exactly eps away
# from a stored point must still scan that shard.  Duplicating the literal
# per call-site is how the snapshot and sync paths drift apart — never
# inline it again.
ROUTE_EPS_DILATION = 1.0 + 1e-6


def routing_eps(eps: float) -> float:
    """The dilated routing radius used by every bbox scan test."""
    return float(eps) * ROUTE_EPS_DILATION


def bbox_route(bboxes, q: np.ndarray, eps: float) -> np.ndarray:
    """(K,) bool scan flags: which shards' live bboxes could hold a point
    within ``eps`` of ANY query in ``q``.  One float64 point-to-box
    distance test against the ε-dilated radius — the single shared
    implementation behind the sync control-plane route and the snapshot
    route, so a boundary query can never be routed differently by path.

    ``bboxes`` is a per-shard sequence of (x0, y0, x1, y1) or None (no
    live rows → never scanned).
    """
    q64 = np.asarray(q, np.float64).reshape(-1, 2)
    e = routing_eps(eps)
    scan = np.zeros((len(bboxes),), bool)
    for s, box in enumerate(bboxes):
        if box is None:
            continue
        x0, y0, x1, y1 = box
        dx = np.maximum(np.maximum(x0 - q64[:, 0], 0.0), q64[:, 0] - x1)
        dy = np.maximum(np.maximum(y0 - q64[:, 1], 0.0), q64[:, 1] - y1)
        scan[s] = bool(np.any(dx * dx + dy * dy <= e * e))
    return scan


def route_snapshot(snap: Snapshot, q: np.ndarray,
                   quarantined_now=frozenset()) -> Tuple[np.ndarray, bool]:
    """(scan (K,) bool, degraded): the snapshot edition of the control
    plane's ``_route`` — literally the same ``bbox_route`` call (one
    float64 test, one ``ROUTE_EPS_DILATION``), so routing (and therefore
    labels) match the synchronous path bit-for-bit on the same state.

    ``degraded`` is raised when a quarantined shard could have mattered
    for THIS request: one quarantined at publish time (its rows were
    excluded from the snapshot's routing, like the sync path), or one
    quarantined *since* (its last-good rows are still in the snapshot
    and will be served stale).
    """
    k = snap.shards
    scan = bbox_route(snap.bboxes, q, snap.eps)
    degraded = False
    if snap.quarantined:
        qmask = np.zeros((k,), bool)
        qmask[list(snap.quarantined)] = True
        degraded = bool((scan & qmask).any())
        scan &= ~qmask
    stale_only = set(quarantined_now) - set(snap.quarantined)
    if stale_only:
        degraded = degraded or bool(scan[sorted(stale_only)].any())
    return scan, degraded


# ---------------------------------------------------------------------------
# The batched snapshot query kernel (one compilation per pow2 bucket pair)
# ---------------------------------------------------------------------------


@jax.jit
def _snapshot_query(q, pts, mask, glabels, eps):
    """Nearest clustered live point within eps, else -1 — the same flat
    argmin as the engines' synchronous kernels (``_query_labels`` /
    the dist per-lane fold), so snapshot reads are bit-identical to a
    synchronous query against the same state.  ``q`` (Qb, 2) is a
    pow2-bucketed batch; ``pts``/``mask``/``glabels`` carry a pow2
    scanned-shard axis (padded rows masked inert).  Padded query rows
    compute junk that the host slices off.
    """
    flat = pts.reshape(-1, 2)
    ok = (mask & (glabels >= 0)).reshape(-1)
    d2 = jnp.sum((q[:, None, :] - flat[None, :, :]) ** 2, axis=-1)
    d2 = jnp.where(ok[None, :], d2, jnp.float32(1e30))
    j = jnp.argmin(d2, axis=1)
    hit = d2[jnp.arange(q.shape[0]), j] <= eps * eps
    return jnp.where(hit, glabels.reshape(-1)[j], -1)


def snapshot_query_cache_entries() -> int:
    """Process-wide compiled-entry count of the snapshot query kernel —
    the number tests bound by the pow2 bucket count."""
    return _snapshot_query._cache_size()


def clear_snapshot_query_cache() -> None:
    _snapshot_query._clear_cache()


def pow2_bucket(n: int, lo: int, hi: int) -> int:
    """The pow2 width ``n`` rows pad to, clamped to [lo, hi]."""
    n = max(int(n), 1)
    return max(lo, min(1 << (n - 1).bit_length(), hi))


# ---------------------------------------------------------------------------
# Typed service statistics — counters vs gauges, one contract, 4 backends
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ServiceCounters:
    """Monotonic counters: only ever increase over a service's lifetime
    (and survive snapshot/restore).  Rates are meaningful; levels are
    history."""

    refreshes: int = 0              # refresh() invocations that did work
    delta_refreshes: int = 0        # …that took the delta-merge path
    snapshots_published: int = 0    # read views cut (== snapshot_version)
    refits: int = 0                 # batch-backend full-pipeline reruns
    query_chunks: int = 0           # sync-path routed chunks
    query_shards_scanned: int = 0   # sync-path shard scans
    queries_served: int = 0         # tier requests answered
    query_launches: int = 0         # coalesced batched kernel launches
    coalesced_requests: int = 0     # requests that shared a launch
    query_rows: int = 0             # query points pushed through launches
    deadline_misses: int = 0        # requests answered past their deadline
    degraded_queries: int = 0       # answers that routed around quarantine
    retries: int = 0                # delta re-deliveries
    quarantine_events: int = 0      # shards ever quarantined
    fenced_deltas: int = 0          # duplicates the epoch fence dropped
    journal_entries: int = 0        # write-ahead journal records


@dataclasses.dataclass(frozen=True)
class ServiceGauges:
    """Point-in-time gauges: the state of the service *now*.  May move in
    either direction; comparing across time measures change, not work."""

    shards: int = 0
    capacity: int = 0
    n_live: int = 0
    n_clusters: int = 0
    snapshot_version: int = 0       # last published version (0: none yet)
    snapshot_epoch: int = 0         # refresh count behind that version
    quarantined_now: Tuple[int, ...] = ()
    queue_pending: int = 0          # tier requests awaiting a drain
    jit_cache_entries: int = 0      # snapshot-query kernel compilations
    # Window age (TTL/sliding-window deployments): the oldest and newest
    # live ingest timestamps, None when no point is live (distinguishing
    # an empty service from a genuine t=0 stamp).
    oldest_ts: Optional[float] = None
    newest_ts: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class ServiceStats:
    """The one typed stats contract every backend surfaces
    (``Backend.service_stats()`` / ``DDC.stats()``): monotonic
    ``counters``, point-in-time ``gauges``, and the exact ``comm``
    wire accounting.  ``as_dict()``/``comm_dict()`` are the legacy
    views ``stats()``/``comm_stats()`` now derive from, so the dicts
    and the typed object can never drift."""

    backend: str
    counters: ServiceCounters
    gauges: ServiceGauges
    comm: Dict[str, int] = dataclasses.field(default_factory=dict)

    def as_dict(self, nest_comm: bool = True) -> dict:
        """The engine-``stats()``-shaped flat dict (legacy keys kept:
        ``quarantined_shards`` is the quarantine_events counter,
        ``query_shards_possible`` the chunk-count × shard bound)."""
        c, g = self.counters, self.gauges
        out = {
            "shards": g.shards,
            "capacity": g.capacity,
            "n_live": g.n_live,
            "refreshes": c.refreshes,
            "delta_refreshes": c.delta_refreshes,
            "n_clusters": g.n_clusters,
            "retries": c.retries,
            "quarantined_shards": c.quarantine_events,
            "quarantined_now": list(g.quarantined_now),
            "fenced_deltas": c.fenced_deltas,
            "degraded_queries": c.degraded_queries,
            "journal_entries": c.journal_entries,
            "query_chunks": c.query_chunks,
            "query_shards_scanned": c.query_shards_scanned,
            "query_shards_possible": c.query_chunks * g.shards,
            "snapshots_published": c.snapshots_published,
            "snapshot_version": g.snapshot_version,
            "snapshot_epoch": g.snapshot_epoch,
            "queries_served": c.queries_served,
            "query_launches": c.query_launches,
            "coalesced_requests": c.coalesced_requests,
            "query_rows": c.query_rows,
            "deadline_misses": c.deadline_misses,
            "queue_pending": g.queue_pending,
            "jit_cache_entries": g.jit_cache_entries,
            "oldest_ts": g.oldest_ts,
            "newest_ts": g.newest_ts,
            "refits": c.refits,
        }
        if nest_comm and self.comm:
            out["comm"] = dict(self.comm)
        return out

    def comm_dict(self) -> dict:
        """The backend-``comm_stats()``-shaped flat dict: backend tag +
        service stats + the meter snapshot flattened alongside."""
        return {"backend": self.backend} | self.as_dict(nest_comm=False) \
            | dict(self.comm)


# ---------------------------------------------------------------------------
# The query tier
# ---------------------------------------------------------------------------


class QueueFull(RuntimeError):
    """The bounded request queue refused a submit (backpressure)."""


@dataclasses.dataclass
class PendingQuery:
    """One enqueued request; ``result`` is filled by the next drain."""

    points: np.ndarray
    deadline: Optional[float]       # absolute time.monotonic() cutoff
    submitted: float
    result: Optional[QueryResult] = None


class QueryTier:
    """Pipelined read loop over a snapshot source (DESIGN.md §12).

    ``source`` is any object with ``snapshot()`` (last published view or
    None), ``read_snapshot()`` (freshness-seeking: fold pending writes,
    then return the published view), and optionally ``quarantined``
    (shard→reason of CURRENTLY quarantined shards) — both serve engines
    and the batch backends' snapshot adapters qualify.

    Freshness policy (``max_staleness`` seconds):

    * ``None`` (default) — always fresh: every drain goes through
      ``read_snapshot()``, folding pending writes first.  This is the
      legacy read semantics, and what the facade uses by default.
    * a float — serve the published snapshot as long as it is at most
      that old; only refresh when the bound is exceeded (or no snapshot
      exists yet).  ``float('inf')``: never refresh — the pure
      decoupled read path.
    """

    def __init__(self, source, *, max_queries: int = 256,
                 queue_depth: int = 64, bucket_min: int = 16,
                 max_staleness: Optional[float] = None):
        if queue_depth < 1:
            raise ValueError(f"queue_depth must be >= 1, got {queue_depth}")
        if bucket_min < 1:
            raise ValueError(f"bucket_min must be >= 1, got {bucket_min}")
        self.source = source
        self.max_queries = int(max_queries)
        self.queue_depth = int(queue_depth)
        self.bucket_min = min(int(bucket_min), self.max_queries)
        self.max_staleness = max_staleness
        self._pending: List[PendingQuery] = []
        self._gather_cache: dict = {}
        self._gather_version = 0
        # Monotonic tier counters (folded into ServiceStats).
        self.queries_served = 0
        self.query_launches = 0
        self.coalesced_requests = 0
        self.query_rows = 0
        self.deadline_misses = 0
        self.degraded_queries = 0
        self.last_version = 0

    # -- submission ---------------------------------------------------------

    def submit(self, points: np.ndarray,
               deadline: Optional[float] = None) -> PendingQuery:
        """Enqueue one request; raises ``QueueFull`` past ``queue_depth``.
        ``deadline`` is an absolute ``time.monotonic()`` cutoff; a
        request served after it is counted in ``deadline_misses`` (and
        still answered — from the snapshot, a stale answer beats none)."""
        if len(self._pending) >= self.queue_depth:
            raise QueueFull(
                f"query queue full ({self.queue_depth} pending); drain() "
                f"before submitting more")
        req = PendingQuery(
            points=np.asarray(points, np.float32).reshape(-1, 2),
            deadline=deadline, submitted=time.monotonic())
        self._pending.append(req)
        return req

    def query(self, points: np.ndarray,
              deadline: Optional[float] = None) -> QueryResult:
        """Synchronous convenience: submit + drain one request."""
        req = self.submit(points, deadline=deadline)
        self.drain()
        return req.result

    @property
    def pending(self) -> int:
        return len(self._pending)

    # -- snapshot resolution ------------------------------------------------

    def _resolve_snapshot(self) -> Optional[Snapshot]:
        snap = self.source.snapshot()
        if snap is None:
            return self.source.read_snapshot()
        if self.max_staleness is None:
            return self.source.read_snapshot()
        if snap.age() > self.max_staleness:
            return self.source.read_snapshot()
        return snap

    # -- the drain: route, coalesce, bucket, launch, split ------------------

    def drain(self) -> List[QueryResult]:
        """Answer every pending request from one resolved snapshot.
        Requests whose ε-dilated scan sets overlap share a kernel
        launch; all shapes are pow2-bucketed.  Returns results in
        submission order (also filled into each ``PendingQuery``)."""
        reqs, self._pending = self._pending, []
        if not reqs:
            return []
        snap = self._resolve_snapshot()
        now = time.monotonic()
        quarantined_now = frozenset(
            dict(getattr(self.source, "quarantined", {}) or {}))

        if snap is None:
            # Empty service, never refreshed: the all-noise short-circuit
            # (same as the engines' sync path), version 0.
            for req in reqs:
                req.result = QueryResult(
                    np.full((len(req.points),), -1, np.int32), version=0,
                    latency_ms=(now - req.submitted) * 1e3)
            self._finish(reqs, now)
            return [r.result for r in reqs]

        if snap.version != self._gather_version:
            self._gather_cache.clear()
            self._gather_version = snap.version

        routes = [route_snapshot(snap, req.points, quarantined_now)
                  for req in reqs]
        groups = self._coalesce([scan for scan, _ in routes])
        for group in groups:
            self._launch_group(snap, [reqs[i] for i in group],
                               [routes[i] for i in group])
        now = time.monotonic()
        for req, (scan, degraded) in zip(reqs, routes):
            req.result.latency_ms = (now - req.submitted) * 1e3
            if degraded:
                self.degraded_queries += 1
        self.last_version = snap.version
        self._finish(reqs, now)
        return [r.result for r in reqs]

    def _finish(self, reqs: List[PendingQuery], now: float) -> None:
        self.queries_served += len(reqs)
        for req in reqs:
            if req.deadline is not None and now > req.deadline:
                self.deadline_misses += 1

    def _coalesce(self, scans: List[np.ndarray]) -> List[List[int]]:
        """Group request indices whose scan sets overlap (transitively):
        each group becomes one batched launch over the union scan set.
        Requests with empty scan sets stay singleton (they short-circuit
        to noise without a kernel)."""
        parent = list(range(len(scans)))

        def find(i):
            while parent[i] != i:
                parent[i] = parent[parent[i]]
                i = parent[i]
            return i

        for i in range(len(scans)):
            if not scans[i].any():
                continue
            for j in range(i + 1, len(scans)):
                if (scans[i] & scans[j]).any():
                    ri, rj = find(i), find(j)
                    if ri != rj:
                        parent[rj] = ri
        groups: dict = {}
        for i in range(len(scans)):
            groups.setdefault(find(i), []).append(i)
        return list(groups.values())

    def _launch_group(self, snap: Snapshot, reqs: List[PendingQuery],
                      routes: List[Tuple[np.ndarray, bool]]) -> None:
        union = np.zeros((snap.shards,), bool)
        for scan, _ in routes:
            union |= scan
        sel = np.nonzero(union)[0]
        if len(sel) == 0:
            for req, (scan, degraded) in zip(reqs, routes):
                req.result = QueryResult(
                    np.full((len(req.points),), -1, np.int32),
                    version=snap.version, degraded=degraded)
            return
        rows = np.concatenate([req.points for req in reqs])
        labels = np.empty((len(rows),), np.int32)
        pts, mask, glab = self._gather(snap, sel)
        qmax = self.max_queries
        for off in range(0, len(rows), qmax):
            chunk = rows[off:off + qmax]
            nq = len(chunk)
            width = pow2_bucket(nq, self.bucket_min, qmax)
            if nq < width:
                chunk = np.pad(chunk, ((0, width - nq), (0, 0)))
            out = _snapshot_query(jnp.asarray(chunk), pts, mask, glab,
                                  snap.eps)
            labels[off:off + nq] = np.asarray(out)[:nq]
            self.query_launches += 1
            self.query_rows += width
        if len(reqs) > 1:
            self.coalesced_requests += len(reqs)
        base = 0
        for req, (scan, degraded) in zip(reqs, routes):
            n = len(req.points)
            req.result = QueryResult(
                labels[base:base + n], version=snap.version,
                degraded=degraded,
                scanned_shards=tuple(np.nonzero(scan)[0].tolist()))
            base += n

    def _gather(self, snap: Snapshot, sel: np.ndarray):
        """Stack the scanned shards' snapshot rows, padded to a pow2
        shard width (padded rows point at shard 0 with a zeroed mask —
        inert, exactly like the sync path's ``_scan_stack``).  Cached
        per (snapshot version, scan set), bounded."""
        key = tuple(int(s) for s in sel)
        hit = self._gather_cache.get(key)
        if hit is None:
            spad = 1 << max(0, (len(sel) - 1).bit_length())
            pad = np.concatenate([sel, np.zeros((spad - len(sel),), np.int64)])
            valid = np.arange(spad) < len(sel)
            rows = jnp.asarray(pad)
            pts = jnp.take(snap.pts, rows, axis=0)
            mask = jnp.take(snap.mask, rows, axis=0) \
                & jnp.asarray(valid)[:, None]
            glab = jnp.take(snap.glabels, rows, axis=0)
            if len(self._gather_cache) > 16:
                self._gather_cache.clear()
            hit = (pts, mask, glab)
            self._gather_cache[key] = hit
        return hit

    # -- stats --------------------------------------------------------------

    def counters(self) -> dict:
        return {
            "queries_served": self.queries_served,
            "query_launches": self.query_launches,
            "coalesced_requests": self.coalesced_requests,
            "query_rows": self.query_rows,
            "deadline_misses": self.deadline_misses,
            "degraded_queries": self.degraded_queries,
        }

    def cache_bound(self, shards: int) -> int:
        """Worst-case compiled-entry count for this tier's traffic: one
        entry per (pow2 query bucket, pow2 scanned-shard width) pair."""
        qb = 0
        w = self.bucket_min
        while True:
            qb += 1
            if w >= self.max_queries:
                break
            w = min(w * 2, self.max_queries)
        sb = max(1, shards - 1).bit_length() + 1
        return qb * sb

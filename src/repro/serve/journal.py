"""Bounded write-ahead ingest/evict journal for the serve engines.

The control plane (DESIGN.md §10) already mirrors every ring-buffer
decision on the host — slot choice, live mask, TTL stamps, seq numbers —
so a write-ahead log costs almost nothing: we record each ingest chunk
(slots + points + stamps) and each kill mask *as they are applied to the
mirrors*, per shard, on top of a base snapshot of the mirrors.  Replay
is then a pure host-side fold: base copy + entries, in order, lands
bit-exactly on the current mirrors — which is exactly the state a lost
device lane needs re-uploaded to rejoin after quarantine.

The journal is bounded: once a shard accumulates more than
``limit`` entries it is compacted (base := current mirrors, entries
cleared), so memory stays O(shards · capacity) regardless of stream
length.  ``entries_total`` is a monotonic counter surfaced in
``stats()`` so journal pressure is observable.

numpy-only by design: replay happens on the host, never inside jit.
"""
from __future__ import annotations

import numpy as np


class Journal:
    def __init__(self, shards: int, capacity: int, limit: int = 1024):
        self.shards = int(shards)
        self.capacity = int(capacity)
        self.limit = max(1, int(limit))
        self._base = [self._empty_base(self.capacity)
                      for _ in range(self.shards)]
        self._entries: list = [[] for _ in range(self.shards)]
        self.entries_total = 0      # monotonic, survives compaction
        self.compactions = 0

    @staticmethod
    def _empty_base(cap: int) -> dict:
        # Matches the control plane's freshly-built mirrors bit-for-bit.
        return {
            "pts": np.zeros((cap, 2), np.float32),
            "live": np.zeros((cap,), bool),
            "ts": np.full((cap,), -np.inf, np.float64),
            "seq": np.full((cap,), -1, np.int64),
        }

    def entry_count(self, shard: int) -> int:
        return len(self._entries[shard])

    def record_ingest(self, shard: int, slots: np.ndarray, pts: np.ndarray,
                      ts: np.ndarray, seqs: np.ndarray) -> None:
        """Log one ingest chunk: ring slots written, the points, and the
        authoritative ts/seq stamps (seq-stamped ordering)."""
        self._entries[shard].append((
            "ingest",
            np.asarray(slots, np.int64).copy(),
            np.asarray(pts, np.float32).copy(),
            np.asarray(ts, np.float64).copy(),
            np.asarray(seqs, np.int64).copy(),
        ))
        self.entries_total += 1

    def record_kill(self, shard: int, kill: np.ndarray) -> None:
        """Log one eviction: the slots whose liveness was cleared."""
        self._entries[shard].append(
            ("kill", np.nonzero(np.asarray(kill, bool))[0].copy()))
        self.entries_total += 1

    def needs_compaction(self, shard: int) -> bool:
        return len(self._entries[shard]) > self.limit

    def compact(self, shard: int, pts, live, ts, seq) -> None:
        """Re-base the shard's log on the current mirrors (the caller's
        arrays ARE the replay target, so this is always safe)."""
        self._base[shard] = {
            "pts": np.asarray(pts, np.float32).copy(),
            "live": np.asarray(live, bool).copy(),
            "ts": np.asarray(ts, np.float64).copy(),
            "seq": np.asarray(seq, np.int64).copy(),
        }
        self._entries[shard] = []
        self.compactions += 1

    def replay(self, shard: int):
        """Fold base + entries into the shard's ring-buffer state.
        Returns ``(pts, live, ts, seq)`` host arrays."""
        base = self._base[shard]
        pts = base["pts"].copy()
        live = base["live"].copy()
        ts = base["ts"].copy()
        seq = base["seq"].copy()
        for entry in self._entries[shard]:
            if entry[0] == "ingest":
                _, slots, chunk, cts, cseq = entry
                pts[slots] = chunk
                live[slots] = True
                ts[slots] = cts
                seq[slots] = cseq
            else:   # kill
                live[entry[1]] = False
        return pts, live, ts, seq

"""Streaming DDC serve engine: incremental ingest, delta-merge, queries.

The paper's two-phase split (local clustering, then contour-only
aggregation) is what makes an *online* clustering service cheap: when new
points land on one shard, only that shard's local clusters change, and
the global view is repaired by re-merging just the touched contours — no
bulk data exchange.  This module is that serving path, split into two
halves (DESIGN.md §10):

* **Control plane** (``ShardControlPlane``) — the host-mirror half every
  engine shares: ring slot choice, liveness/ts/seq mirrors, eviction
  victim selection, dirty-shard tracking, per-shard live-point bbox
  mirrors (query routing), and shard-range validation.  Everything the
  control plane decides is a pure function of the call sequence, so no
  device sync ever sits on the write path.
* **Data plane** — where the buffers live and kernels run.  This module's
  ``ClusterService`` keeps them host-driven on the default device (one
  process, K logical shards).  ``serve/dist_service.py`` pins each
  shard's buffers to its own mesh device and runs the same control plane
  over a ``shard_map`` data plane.

Engine behaviour (shared by both data planes):

* **Ingest buffers** — every shard owns a static-shape ring buffer
  ((capacity, 2) points + live mask), donated to the jitted append kernel
  so updates are in-place on device.  Appending past capacity evicts the
  oldest points (ring overwrite); ``evict_oldest`` (by ingest sequence)
  and ``evict_older_than`` (TTL: by the per-point ingest timestamps
  mirrored on the host) are the explicit eviction APIs — liveness holes
  are legal, the live mirror is authoritative.  The append kernel is a
  single static-shape scatter; the *slots* it writes are chosen on the
  host mirrors (dead slots in ring order first, then the oldest live
  points once the buffer is genuinely full).
* **Dirty-shard phase 1** — ``refresh`` re-runs ``ddc.local_phase`` only
  on shards whose buffers changed since the last refresh; an emptied
  shard short-circuits to the cached ``ddc.empty_clusterset`` without
  touching the device.
* **Delta-merge phase 2** — the engine caches the per-shard ClusterSets
  *and* the (K·C, K·C) slot×slot contour-distance matrix behind
  ``ddc.merge_many``.  A delta refresh recomputes only the dirty shards'
  rows/columns and re-closes the transitive closure (``ddc.merge_delta``).
  This is **exact**, not approximate: the matrix is a pure per-slot-pair
  function of the per-shard contours, so patching dirty rows/columns
  reproduces the from-scratch matrix bit-for-bit, and everything
  downstream (components, ranking, contour rebuild) is a deterministic
  function of (batch, matrix).  In particular, evictions that *split* a
  global cluster are handled correctly — the closure is always recomputed
  over per-shard contours, never over the (unsplittable) merged global
  contour.  DESIGN.md §8.
* **Queries** — ``query`` maps read-traffic points to global cluster ids:
  nearest clustered live point within ``eps`` (DBSCAN's border rule
  applied to the frozen clustering), else noise.  Query chunks are
  *routed*: only shards whose ε-dilated live bbox could contain a
  neighbour of some chunk point are scanned (the control plane mirrors
  each shard's bbox), and the scanned-shard counters surface in
  ``stats()``/``comm_stats()``.  Routing is exact — a skipped shard holds
  no point within ε of any query, so it could never supply a label.
* **Snapshot/restore** — ``state_dict``/``from_state`` serialise the
  full engine state (ring buffers, host mirrors, per-shard ClusterSets,
  pair-d2 cache); the global set/maps/labels are recomputed on restore
  from the saved inputs, so a restarted server resumes bit-identically
  without a re-cluster (DESIGN.md §9).

Communication model (``CommMeter``): shards and the aggregator are
distinct nodes.  A full re-merge ships all K ClusterSets up
(K·B bytes, B = ``DDCConfig.buffer_bytes()``); a delta refresh ships only
the dirty ones (|dirty|·B).  Both ship each shard its (C,) slot-map row
back down (K·C·4 bytes).  Steady-state single-shard ingest therefore
moves B + K·C·4 per refresh vs K·B + K·C·4 — the measurable
minimal-communication claim (benchmarks/serve.py).  For this host-driven
engine the model is metered; the ``dist`` data plane realises the same
byte counts as real device-boundary transfers.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ddc
from repro.serve import faults as faults_mod
from repro.serve import hierarchy
from repro.serve import journal as journal_mod
from repro.serve import query_tier as qt
from repro.serve import tracking as tracking_mod


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Static configuration of the streaming engine."""

    shards: int                     # K logical shards
    capacity: int                   # per-shard point-buffer slots
    max_batch: int = 256            # static ingest width (host pads)
    max_queries: int = 256          # static query width (host pads)
    merge_mode: str = "delta"       # "delta" | "full"
    max_retries: int = 2            # delta re-deliveries per refresh
    retry_backoff: float = 0.0      # seconds; doubles per retry round
    journal_limit: int = 1024       # per-shard WAL entries before compaction
    agg_degree: Optional[int] = None  # None: flat aggregator; >=2: tree fan-in
    track: bool = False             # cluster tracking fold (DESIGN.md §14)
    track_history: int = 16         # per-track motion-history ring length
    match_min_overlap: float = 0.0  # tighten the match gate, in [0, 1)
    ddc: ddc.DDCConfig = dataclasses.field(default_factory=ddc.DDCConfig)


# ---------------------------------------------------------------------------
# Jitted state-update kernels (static shapes; buffers donated)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _append(pts_buf, mask_buf, batch, idx, nb):
    """Ring-buffer append: scatter the ``nb`` valid rows of ``batch``
    into slots ``idx`` (bmax,) and mark them live, in place.

    The *choice* of slots happens on the host mirrors (``_write_slots``):
    dead slots in ring order first, then — only when the buffer is
    genuinely full — the oldest live points.  The kernel itself is a
    plain static-shape scatter, so one compilation serves the contiguous
    case, the wraparound case, and rings with TTL holes alike.
    """
    cap = pts_buf.shape[0]
    bmax = batch.shape[0]
    wvalid = jnp.arange(bmax) < nb
    safe = jnp.where(wvalid, idx, cap)               # invalid rows drop
    pts_buf = pts_buf.at[safe].set(batch, mode="drop")
    mask_buf = mask_buf.at[safe].set(True, mode="drop")
    return pts_buf, mask_buf


@functools.partial(jax.jit, donate_argnums=(0,))
def _kill_mask(mask_buf, kill):
    """Clear the live bit of every slot marked in ``kill`` (cap,) bool.
    One kernel serves every eviction flavour — oldest-n, TTL, clear —
    because the *choice* of victims is made on the host mirrors (ingest
    order and timestamps are a pure function of the call sequence, no
    device sync needed)."""
    return mask_buf & ~kill


@functools.partial(jax.jit, donate_argnums=(0,))
def _set_row(stack, row, i):
    """stack[i] <- row for every leaf of a stacked pytree (in place)."""
    return jax.tree.map(
        lambda s, x: jax.lax.dynamic_update_slice(
            s, x[None], (i,) + (0,) * x.ndim),
        stack, row)


@jax.jit
def _global_labels(dense, mask, maps):
    """(K, cap) dense local labels + (K, C) slot maps -> global labels."""
    def one(d, m, mp):
        return jnp.where(m & (d >= 0), mp[jnp.clip(d, 0)], -1)
    return jax.vmap(one)(dense, mask, maps)


@jax.jit
def _query_labels(q, qn, pts, mask, glabels, eps):
    """Nearest clustered live point within eps, else -1.  q: (Qmax, 2);
    ``pts``/``mask``/``glabels`` carry a leading scanned-shard axis (any
    width: the router stacks only candidate shards, padded rows masked)."""
    flat = pts.reshape(-1, 2)
    ok = (mask & (glabels >= 0)).reshape(-1)
    d2 = jnp.sum((q[:, None, :] - flat[None, :, :]) ** 2, axis=-1)
    d2 = jnp.where(ok[None, :], d2, jnp.float32(1e30))
    j = jnp.argmin(d2, axis=1)
    hit = d2[jnp.arange(q.shape[0]), j] <= eps * eps
    lab = jnp.where(hit, glabels.reshape(-1)[j], -1)
    return jnp.where(jnp.arange(q.shape[0]) < qn, lab, -1)


def _cs_to_host(cs: ddc.ClusterSet) -> dict:
    """One shard's delta as the host-side wire payload the validation
    gate (and the fault seam) sees: plain numpy views of the leaves."""
    return {
        "contours": np.asarray(cs.contours),
        "counts": np.asarray(cs.counts),
        "sizes": np.asarray(cs.sizes),
        "valid": np.asarray(cs.valid),
        "overflow": np.asarray(cs.overflow),
    }


def _cs_from_host(payload: dict) -> ddc.ClusterSet:
    """Rebuild the device ClusterSet from the wire payload.  The
    host round-trip is bit-exact (no dtype changes), so staging the
    gated payload — not the pre-seam device value — costs nothing."""
    return ddc.ClusterSet(
        contours=jnp.asarray(payload["contours"], jnp.float32),
        counts=jnp.asarray(payload["counts"], jnp.int32),
        sizes=jnp.asarray(payload["sizes"], jnp.int32),
        valid=jnp.asarray(payload["valid"], bool),
        overflow=jnp.asarray(payload["overflow"], bool),
    )


# ---------------------------------------------------------------------------
# Control plane — the host-mirror half every data plane shares
# ---------------------------------------------------------------------------


class ShardControlPlane:
    """Host mirrors + write/evict/routing policy over K logical shards.

    Subclasses supply the data plane: ``_append_chunk`` (write one padded
    chunk into a shard's device buffer), ``_kill_device`` (clear live
    bits on device), ``_read_view`` (donation-safe copies for snapshot
    publish), and ``_invalidate_reads``.  Everything else
    — slot choice, eviction victim selection, TTL stamps, bbox mirrors,
    dirty tracking, shard-range validation, snapshot publish/swap — is
    shared host logic that never syncs with the device on the write path.
    """

    flavor = "base"                 # backend tag ("stream" / "dist")

    def __init__(self, scfg: StreamConfig, meter: ddc.CommMeter | None = None,
                 faults: faults_mod.FaultPlan | None = None):
        if scfg.merge_mode not in ("delta", "full"):
            raise ValueError(scfg.merge_mode)
        if scfg.capacity < scfg.max_batch:
            raise ValueError(
                f"capacity {scfg.capacity} < max_batch {scfg.max_batch}: an "
                f"append chunk could overwrite itself in the ring scatter")
        self.scfg = scfg
        self.cfg = scfg.ddc
        self.meter = meter
        self.faults = faults
        k, cap = scfg.shards, scfg.capacity
        # Host mirrors of the ring state (known exactly from the call
        # sequence — no device sync on the write path).  ``_live`` is the
        # authoritative liveness mirror (TTL eviction punches holes, so
        # head/count alone no longer describe the live set); ``_ts`` and
        # ``_seq`` stamp each slot with its ingest timestamp and global
        # ingest sequence number for TTL / oldest-first eviction.
        # ``_hpts`` mirrors the coordinates the control plane itself
        # wrote (ingest sees every point on the host), which is what
        # keeps the per-shard bbox exact across evictions without ever
        # reading the device buffers back.
        self._head = [0] * k
        self._count = [0] * k
        self._live = [np.zeros((cap,), bool) for _ in range(k)]
        self._ts = [np.full((cap,), -np.inf) for _ in range(k)]
        self._seq = [np.full((cap,), -1, np.int64) for _ in range(k)]
        self._hpts = [np.zeros((cap, 2), np.float32) for _ in range(k)]
        self._bbox: List[Optional[tuple]] = [None] * k
        self._next_seq = 0
        self._dirty = set(range(k))
        # Aggregator mirror: the control plane caches every shard's last
        # exchanged ClusterSet (stacked), the slot-distance matrix, and
        # the merged global state — the state a delta refresh patches.
        empty = ddc.empty_clusterset(self.cfg)
        self._local: List[ddc.ClusterSet] = [empty] * k
        self._batch: ddc.ClusterSet = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (k,) + x.shape), empty)
        self._pair_d2: Optional[jax.Array] = None
        self._global: Optional[ddc.ClusterSet] = None
        self._maps: Optional[jax.Array] = None
        # Hierarchical aggregation (DESIGN.md §13): with ``agg_degree``
        # set, the flat (K·C)² cache above stays None and the tree owns
        # one small per-node cache per D children instead.
        self._hier: Optional[hierarchy.AggregatorTree] = None
        if scfg.agg_degree is not None:
            self._hier = hierarchy.AggregatorTree(
                k, scfg.agg_degree, self.cfg, meter=meter)
        self.refreshes = 0
        self.delta_refreshes = 0
        self.query_chunks = 0
        self.query_shards_scanned = 0
        # Failure model (DESIGN.md §11): a bounded write-ahead journal of
        # every ingest/evict decision (riding the host mirrors), a
        # quarantine set of shards whose deltas failed the validation
        # gate or whose lane died, and per-shard epochs fencing duplicate
        # deliveries so the merge is exactly-once.
        self._journal = journal_mod.Journal(k, cap, limit=scfg.journal_limit)
        self._quarantined: dict = {}    # shard -> reason
        self._epoch = [0] * k           # delta generation per shard
        self._merged_epoch = [-1] * k   # last epoch folded into the merge
        self.retries = 0                # delta re-deliveries (monotonic)
        self.quarantine_events = 0      # shards ever quarantined (monotonic)
        self.fenced_deltas = 0          # duplicates the epoch fence dropped
        self.degraded_queries = 0       # queries routed around quarantine
        self.last_query_degraded = False
        self._route_degraded = False
        # Snapshot publish/swap (DESIGN.md §12): the last published read
        # view and its monotonic version counter.  Cut eagerly at the end
        # of every refresh (and on restore), NEVER invalidated by
        # ingest/evict — a held snapshot is stale but consistent.
        self._snapshot: Optional[qt.Snapshot] = None
        self._snapshot_version = 0
        # Cluster tracking (DESIGN.md §14): a pure fold over the merged
        # generations, observed at refresh (post-gate only, so faulted
        # and fault-free runs fold identical inputs).
        self._tracker: Optional[tracking_mod.ClusterTracker] = None
        self._track_snapshot: Optional[tracking_mod.TrackSnapshot] = None
        if scfg.track:
            self._tracker = tracking_mod.ClusterTracker(
                self.cfg, history=scfg.track_history,
                min_overlap=scfg.match_min_overlap)

    # -- data-plane hooks ---------------------------------------------------

    def _append_chunk(self, shard: int, chunk: np.ndarray,
                      idx: np.ndarray, nb: int) -> None:
        raise NotImplementedError

    def _kill_device(self, shard: int, kill: np.ndarray) -> None:
        raise NotImplementedError

    def _restore_lane(self, shard: int, pts: np.ndarray,
                      live: np.ndarray) -> None:
        """Overwrite one shard's device buffers wholesale (the recovery
        upload: journal-replayed points + live mask)."""
        raise NotImplementedError

    def _lose_lane(self, shard: int) -> None:
        """Model a dead lane: its device buffers are gone (zeroed), only
        the host mirrors + journal survive."""
        cap = self.scfg.capacity
        self._restore_lane(shard, np.zeros((cap, 2), np.float32),
                           np.zeros((cap,), bool))
        self._invalidate_reads()

    def _invalidate_reads(self) -> None:
        """Called whenever a write/evict changes the live point set."""

    # -- write path ---------------------------------------------------------

    def _check_shard(self, shard: int) -> int:
        if not 0 <= shard < self.scfg.shards:
            raise ValueError(
                f"shard {shard} out of range [0, {self.scfg.shards}) for "
                f"this {self.scfg.shards}-shard service")
        return shard

    def ingest(self, shard: int, points: np.ndarray,
               t: float | np.ndarray | None = None) -> None:
        """Append ``points`` (n, 2) to ``shard``'s buffer, evicting the
        oldest live points if the buffer would overflow.

        ``t`` stamps the batch for TTL eviction (``evict_older_than``):
        a scalar (whole batch) or an (n,) array (per point).  Default:
        the global ingest sequence number, so count-based and time-based
        eviction coincide when the caller never supplies timestamps.
        """
        self._check_shard(shard)
        cap, bmax = self.scfg.capacity, self.scfg.max_batch
        pts = np.asarray(points, np.float32).reshape(-1, 2)
        n = len(pts)
        if t is None:
            ts = np.arange(self._next_seq, self._next_seq + n, dtype=np.float64)
        else:
            ts = np.broadcast_to(np.asarray(t, np.float64), (n,))
        for off in range(0, n, bmax):
            chunk = pts[off:off + bmax]
            nb = len(chunk)
            idx = self._write_slots(shard, nb)
            pad_idx = idx
            if nb < bmax:
                chunk = np.pad(chunk, ((0, bmax - nb), (0, 0)))
                pad_idx = np.pad(idx, (0, bmax - nb))
            seqs = np.arange(self._next_seq + off, self._next_seq + off + nb)
            # Write-ahead: journal the decision before the device write,
            # so a lane lost mid-append is still recoverable.
            self._journal.record_ingest(shard, idx, chunk[:nb],
                                        ts[off:off + nb], seqs)
            if shard not in self._quarantined:
                self._append_chunk(shard, chunk, pad_idx, nb)
            self._live[shard][idx] = True
            self._hpts[shard][idx] = chunk[:nb]
            self._ts[shard][idx] = ts[off:off + nb]
            self._seq[shard][idx] = seqs
            self._head[shard] = int(idx[-1] + 1) % cap
            self._count[shard] = int(self._live[shard].sum())
        if self._journal.needs_compaction(shard):
            self._journal.compact(shard, self._hpts[shard],
                                  self._live[shard], self._ts[shard],
                                  self._seq[shard])
        self._next_seq += n
        if n and shard not in self._quarantined:
            self._dirty.add(shard)
        if n:
            self._bbox[shard] = None
            self._invalidate_reads()

    def _write_slots(self, shard: int, nb: int) -> np.ndarray:
        """Pick the ``nb`` slots the next append chunk writes: dead slots
        in ring order from the head first (so TTL holes are refilled
        before anything live is touched), then — only when the buffer is
        genuinely full — the oldest live points by ingest sequence.  In a
        hole-free ring this reproduces the classic ring-buffer layout
        exactly: the window [head, head+nb) while there is room, the
        oldest window once it wraps."""
        cap = self.scfg.capacity
        live = self._live[shard]
        order = (self._head[shard] + np.arange(cap)) % cap
        dead = order[~live[order]]
        take = dead[:nb]
        if len(take) < nb:
            live_idx = np.nonzero(live)[0]
            oldest = live_idx[np.argsort(self._seq[shard][live_idx],
                                         kind="stable")]
            take = np.concatenate([take, oldest[:nb - len(take)]])
        return take.astype(np.int64)

    def _apply_kill(self, shard: int, kill: np.ndarray) -> int:
        """Clear the live bits marked in ``kill`` (cap,) bool on device
        and in the host mirrors.  Returns the number evicted."""
        self._check_shard(shard)
        n = int(kill.sum())
        if n == 0:
            return 0
        self._journal.record_kill(shard, kill)
        if shard not in self._quarantined:
            self._kill_device(shard, kill)
            self._dirty.add(shard)
        self._live[shard][kill] = False
        self._count[shard] = int(self._live[shard].sum())
        if self._journal.needs_compaction(shard):
            self._journal.compact(shard, self._hpts[shard],
                                  self._live[shard], self._ts[shard],
                                  self._seq[shard])
        self._bbox[shard] = None
        self._invalidate_reads()
        return n

    def evict_oldest(self, shard: int, n: int) -> int:
        """Evict the ``n`` oldest live points from ``shard`` (by ingest
        sequence).  Returns the number actually evicted."""
        self._check_shard(shard)
        live_idx = np.nonzero(self._live[shard])[0]
        if n <= 0 or len(live_idx) == 0:
            return 0
        order = np.argsort(self._seq[shard][live_idx], kind="stable")
        kill = np.zeros((self.scfg.capacity,), bool)
        kill[live_idx[order[:n]]] = True
        return self._apply_kill(shard, kill)

    def evict_older_than(self, shard: int, t: float) -> int:
        """TTL / windowed eviction: evict every live point on ``shard``
        whose ingest timestamp is < ``t``.  Returns the eviction count.
        The ring layout is untouched (holes are legal: liveness is a
        mask, and the append wrap overwrites dead slots for free)."""
        self._check_shard(shard)
        return self._apply_kill(
            shard, self._live[shard] & (self._ts[shard] < t))

    def clear(self, shard: int) -> int:
        """Evict every live point from ``shard``."""
        self._check_shard(shard)
        return self._apply_kill(shard, self._live[shard].copy())

    def window_ts(self) -> Tuple[Optional[float], Optional[float]]:
        """(oldest, newest) live ingest timestamps across all shards,
        from the host timestamp mirrors — the observable window age for
        TTL/sliding-window deployments.  (None, None) when no point is
        live, distinguishing "empty" from a genuine t=0 stamp."""
        lo: Optional[float] = None
        hi: Optional[float] = None
        for s in range(self.scfg.shards):
            live = self._live[s]
            if not live.any():
                continue
            ts = self._ts[s][live]
            tmin, tmax = float(ts.min()), float(ts.max())
            lo = tmin if lo is None else min(lo, tmin)
            hi = tmax if hi is None else max(hi, tmax)
        return lo, hi

    # -- query routing ------------------------------------------------------

    def shard_bbox(self, shard: int) -> Optional[tuple]:
        """(x0, y0, x1, y1) over ``shard``'s live points, or None when
        the shard is empty.  Maintained from the host coordinate mirror
        — updated lazily after any ingest/evict invalidated it — so
        routing never touches the device buffers."""
        self._check_shard(shard)
        box = self._bbox[shard]
        if box is None:
            live = self._live[shard]
            if not live.any():
                box = ()
            else:
                p = self._hpts[shard][live]
                box = (float(p[:, 0].min()), float(p[:, 1].min()),
                       float(p[:, 0].max()), float(p[:, 1].max()))
            self._bbox[shard] = box
        return box or None

    def _route(self, q: np.ndarray) -> np.ndarray:
        """(K,) bool: shards whose ε-dilated live bbox could contain a
        neighbour of ANY row of ``q`` — every other shard provably holds
        no point within ε of any query, so skipping it cannot change a
        single label (exactness).  The shared ``query_tier.bbox_route``
        test (one ``ROUTE_EPS_DILATION`` margin absorbing f32 rounding in
        the distance kernel) is what the snapshot path runs too, so the
        two paths can never route a boundary query differently; counters
        feed ``stats()``.
        """
        k = self.scfg.shards
        scan = qt.bbox_route(
            tuple(self.shard_bbox(s) for s in range(k)), q, self.cfg.eps)
        # Quarantined shards are routed around: the answer is degraded
        # (their points can't label a query until recovery), flagged via
        # ``_route_degraded`` — but healthy shards keep serving.  The
        # bbox test above ran on the *logical* mirrors, so the flag is
        # raised exactly when a quarantined shard could have mattered.
        self._route_degraded = False
        if self._quarantined:
            qmask = np.zeros((k,), bool)
            qmask[list(self._quarantined)] = True
            self._route_degraded = bool((scan & qmask).any())
            scan &= ~qmask
        self.query_chunks += 1
        self.query_shards_scanned += int(scan.sum())
        return scan

    # -- aggregator (delta merge + metering) --------------------------------

    def _merge_and_meter(self, dirty: list, mode: str,
                         up_bytes: int | None = None) -> None:
        """Fold the aggregator mirror into the global state and account
        the up-leg of the exchange: a delta refresh ships |dirty|
        ClusterSets, a full re-merge ships all K.  With ``up_bytes=None``
        (the host-driven engine) the counters are the static model; the
        ``dist`` data plane passes the bytes it MEASURED on its actual
        device→aggregator fetches, so the model-vs-real equality the
        bench asserts is an observation, not a restatement (DESIGN.md
        §10).  Callers meter the map-rows down-leg via
        ``_meter_maps_down`` once the maps exist."""
        cfg = self.cfg
        k, c = self.scfg.shards, cfg.max_clusters
        bbytes = cfg.buffer_bytes()
        exclude = self._exclude_mask()
        if self._hier is not None:
            # Hierarchical aggregation (DESIGN.md §13): shard payloads go
            # to their leaf aggregators; the tree meters its own internal
            # summary/map edges and folds, so only the shard→leaf up-leg
            # is accounted here (model or measured, same as flat).  The
            # flat (K·C)² cache stays None by construction.
            delta = mode == "delta" and self._hier.ready
            self._global, self._maps = self._hier.refresh(
                self._batch, dirty if delta else None, exclude)
            if self.meter is not None:
                if up_bytes is not None:
                    self.meter.add_collective(1, up_bytes)
                else:
                    self.meter.add_collective(
                        len(dirty) if delta else k, bbytes)
            if delta:
                self.delta_refreshes += 1
            return
        if mode == "delta" and self._pair_d2 is not None:
            self._global, self._maps, self._pair_d2 = ddc.merge_delta(
                self._batch, self._pair_d2, dirty, cfg, exclude)
            if self.meter is not None:
                if up_bytes is None:
                    self.meter.add_collective(len(dirty), bbytes)
                else:
                    self.meter.add_collective(1, up_bytes)
            self.delta_refreshes += 1
        else:
            # Full rebuild goes through the same difference-form build
            # (not the Pallas kernel): the cached matrix must stay
            # bit-compatible with the delta patches on every backend —
            # see ddc.contour_pair_d2_exact.
            self._global, self._maps, self._pair_d2 = ddc.merge_delta(
                self._batch, None, None, cfg, exclude)
            if self.meter is not None:
                self.meter.add_collective(
                    *((k, bbytes) if up_bytes is None else (1, up_bytes)))
        if self.meter is not None:
            self.meter.add_merge(k, c)

    def _meter_maps_down(self, nbytes: int | None = None) -> None:
        """Account the down-leg: each shard's (C,) slot-map row.  The
        model counts K·C·4; the dist engine passes the measured size of
        the maps array it actually pushes."""
        if self.meter is not None:
            if nbytes is None:
                self.meter.add_collective(
                    self.scfg.shards, self.cfg.max_clusters * 4)
            else:
                self.meter.add_collective(1, nbytes)

    # -- delta exchange: fault seam, validation gate, retries, fencing ------

    def _exclude_mask(self):
        """(K,) bool quarantine mask for ``merge_delta``/``merge_from_d2``
        (None when every shard is healthy — the identical fast path)."""
        if not self._quarantined:
            return None
        mask = np.zeros((self.scfg.shards,), bool)
        mask[list(self._quarantined)] = True
        return jnp.asarray(mask)

    def _quarantine(self, shard: int, reason: str) -> None:
        """Fence ``shard`` out of merges and query routing.  Its cached
        pair-d2 rows and aggregator mirror stay untouched, so rejoining
        is one ordinary delta patch — that is the bit-exact-recovery
        guarantee."""
        if shard not in self._quarantined:
            self._quarantined[shard] = reason
            self.quarantine_events += 1
        self._dirty.discard(shard)
        self._invalidate_reads()

    @property
    def quarantined(self) -> dict:
        """shard -> reason for every currently quarantined shard."""
        return dict(self._quarantined)

    def _fault_delta(self, shard: int, attempt: int,
                     payload: dict) -> Tuple[dict, bool]:
        """The fault-injection seam on the delta-exchange path.  Consults
        the plan once per delivery attempt; returns the (possibly
        mangled) payload plus a duplicate-delivery flag, or raises
        ``DeltaDropped`` / ``LaneKilled``."""
        if self.faults is None:
            return payload, False
        ev = self.faults.on_delta(shard, attempt)
        if ev is None:
            return payload, False
        if ev.kind in ("drop", "delay"):
            raise faults_mod.DeltaDropped(
                f"shard {shard} delta lost (attempt {attempt})")
        if ev.kind == "kill":
            raise faults_mod.LaneKilled(f"shard {shard} lane died")
        if ev.kind == "dup":
            return payload, True
        return self.faults.mangle(ev.kind, payload), False

    def _gate_and_stage(self, shard: int, payload: dict, epoch: int,
                        cs=None) -> bool:
        """Epoch fence + validation gate in front of the aggregator
        mirror.  A duplicate (epoch already merged) is discarded —
        exactly-once; a corrupt payload raises ``DeltaValidationError``
        BEFORE any mirror or cached pair-d2 state is touched.  ``cs`` is
        the producer's canonical device ClusterSet for this payload, if
        it still has one (dropped when the wire copy was mangled); it
        preserves object identity for the cached empty-shard ClusterSet.
        Returns True iff the delta was staged."""
        if epoch <= self._merged_epoch[shard]:
            self.fenced_deltas += 1
            return False
        faults_mod.validate_delta(payload, self.cfg)
        if cs is None:
            cs = _cs_from_host(payload)
        self._local[shard] = cs
        self._batch = _set_row(self._batch, cs, shard)
        self._merged_epoch[shard] = epoch
        return True

    def _exchange_deltas(self, dirty: list, produce) -> list:
        """Drive one refresh's delta exchange: per-shard delivery with
        retry/backoff (``max_retries``/``retry_backoff``), the fault
        seam, the validation gate, and epoch fencing.  ``produce(shard,
        attempt)`` yields ``(payload, cs)`` — the shard's host-side wire
        payload plus its canonical device ClusterSet when the producer
        has one (re-invoked on retry: the lane re-sends).  Shards whose
        deltas cannot be delivered or fail the gate are quarantined; the
        rest are staged into the aggregator mirror.  Returns the staged
        shard list."""
        staged: list = []
        pending = list(dirty)
        for i in pending:
            self._epoch[i] += 1      # one delta generation per refresh
        attempt = 0
        while pending:
            if attempt > 0:
                self.retries += len(pending)
                if self.scfg.retry_backoff > 0:
                    time.sleep(self.scfg.retry_backoff * 2 ** (attempt - 1))
            still: list = []
            for i in pending:
                epoch = self._epoch[i]
                try:
                    sent, cs = produce(i, attempt)
                    payload, dup = self._fault_delta(i, attempt, sent)
                    if payload is not sent:
                        cs = None    # mangled in flight: trust the wire
                    if self._gate_and_stage(i, payload, epoch, cs):
                        staged.append(i)
                    if dup:
                        # late duplicate of the delta just merged: the
                        # fence must discard it (exactly-once)
                        self._gate_and_stage(i, payload, epoch, cs)
                except faults_mod.DeltaDropped:
                    still.append(i)
                except faults_mod.LaneKilled:
                    self._lose_lane(i)
                    self._quarantine(i, "lane killed mid-refresh")
                except faults_mod.DeltaValidationError as e:
                    self._quarantine(i, f"delta rejected: {e}")
            if still and attempt >= self.scfg.max_retries:
                for i in still:
                    self._quarantine(
                        i, f"delta dropped ({attempt + 1} attempts)")
                break
            pending = still
            attempt += 1
        return staged

    # -- recovery ------------------------------------------------------------

    def recover(self, shard: int) -> bool:
        """Rejoin a quarantined shard: replay the write-ahead journal
        into the ring-buffer state the lane should hold, upload it, and
        mark the shard dirty so the next refresh re-runs phase 1 and
        patches its pair-d2 rows.  Post-recovery state is bit-exact vs
        an uninterrupted run (DESIGN.md §11).  Returns True if the shard
        was quarantined (and is now rejoined)."""
        self._check_shard(shard)
        if shard not in self._quarantined:
            return False
        pts, live, ts, seq = self._journal.replay(shard)
        # The journal rides the host mirrors: replay must land exactly
        # on them, or the log itself is damaged.
        if not (np.array_equal(pts, self._hpts[shard])
                and np.array_equal(live, self._live[shard])
                and np.array_equal(ts, self._ts[shard])
                and np.array_equal(seq, self._seq[shard])):
            raise faults_mod.RecoveryError(
                f"journal replay for shard {shard} diverged from the "
                f"host mirrors; refusing to rejoin")
        self._restore_lane(shard, pts, live)
        del self._quarantined[shard]
        self._dirty.add(shard)
        self._bbox[shard] = None
        self._invalidate_reads()
        return True

    def recover_all(self) -> list:
        """Rejoin every quarantined shard; returns the recovered list."""
        return [s for s in sorted(self._quarantined) if self.recover(s)]

    def refresh(self, mode: str | None = None, force: bool = False,
                track: bool | None = None):
        raise NotImplementedError

    # -- cluster tracking (DESIGN.md §14) -----------------------------------

    @property
    def tracker(self) -> Optional[tracking_mod.ClusterTracker]:
        return self._tracker

    def track_snapshot(self) -> Optional[tracking_mod.TrackSnapshot]:
        """The ``TrackSnapshot`` cut alongside the last published read
        view — same version, so labels+tracks reads are consistent.
        None before the first refresh or with tracking disabled."""
        return self._track_snapshot

    def _track_update(self, track: bool | None) -> None:
        """Fold the freshly merged generation into the tracker.

        ``track=None`` (the default) folds iff tracking is enabled and
        no shard is quarantined: the tracker observes only *post-gate*
        complete generations, so a faulted run and its fault-free twin
        fold identical inputs and their histories stay bit-identical
        (the §11 chaos contract extended to tracking).  ``track=False``
        skips the fold for this refresh; ``track=True`` forces it."""
        if self._tracker is None or self._global is None:
            return
        if track is None:
            track = not self._quarantined
        if not track:
            return
        self._tracker.update(self._batch, self._maps, self._global)

    # -- snapshot publish/swap (DESIGN.md §12) ------------------------------

    def _read_view(self):
        """Data-plane hook for snapshot publish: (pts (K, cap, 2), mask
        (K, cap), glabels (K, cap)) device arrays that are safe to hold
        indefinitely — copies of (never aliases into) the donated ring
        buffers."""
        raise NotImplementedError

    def _publish_snapshot(self) -> "qt.Snapshot":
        """Cut and swap in a new immutable read view of the CURRENT
        engine state.  Called at the end of every refresh (and restore),
        so every published version corresponds to one consistent
        (buffers, labels, bboxes, quarantine) observation — a concurrent
        reader sees version V in full or V+1 in full, never a mix."""
        pts, mask, glab = self._read_view()
        k = self.scfg.shards
        self._snapshot_version += 1
        self._snapshot = qt.Snapshot(
            version=self._snapshot_version,
            epoch=self.refreshes,
            published_at=time.monotonic(),
            eps=float(self.cfg.eps),
            pts=pts, mask=mask, glabels=glab,
            bboxes=tuple(self.shard_bbox(s) for s in range(k)),
            quarantined=frozenset(self._quarantined),
            n_live=self.n_live(),
            n_clusters=int(np.asarray(self._global.valid).sum())
            if self._global is not None else 0,
        )
        if self._tracker is not None:
            # Same version as the labels snapshot above: a reader pairing
            # the two sees one consistent generation.
            self._track_snapshot = self._tracker.snapshot(
                version=self._snapshot_version, epoch=self.refreshes)
        return self._snapshot

    def snapshot(self) -> Optional["qt.Snapshot"]:
        """The last published read view (None before the first refresh)."""
        return self._snapshot

    def read_snapshot(self) -> Optional["qt.Snapshot"]:
        """Freshness-seeking read view: fold pending writes (refresh if
        dirty), then return the published snapshot.  None only for the
        empty-service short-circuit (nothing ingested, nothing merged)."""
        if self._global is None and self.n_live() == 0:
            return None
        if self._dirty or self._global is None:
            self.refresh()
        if self._snapshot is None:
            self._publish_snapshot()
        return self._snapshot

    # -- unified read path (both data planes) -------------------------------

    def _query_sync(self, q: np.ndarray):
        """Engine hook: label ``q`` against the current refreshed state.
        Returns (labels (n,) int32, degraded, scanned-shard set)."""
        raise NotImplementedError

    def query(self, points: np.ndarray, return_stale: bool = False,
              legacy: bool = False):
        """Global cluster id for each query point: the label of the
        nearest clustered live point within ``eps`` (DBSCAN's border
        rule against the frozen clustering), else -1.

        Returns a ``QueryResult`` — labels plus the snapshot ``version``
        that answered, the ``degraded`` flag (a quarantined shard could
        have mattered), the routed ``scanned_shards``, and latency.  The
        result duck-types as its labels array, and ``legacy=True`` returns
        the bare ndarray outright (deprecation shim for pre-redesign
        callers); ``return_stale=True`` keeps the old ``(labels, stale)``
        tuple shape with a ``QueryResult`` in the first slot.

        Each chunk is routed to the shards whose ε-dilated bbox could
        contain a neighbour (``_route``); a chunk that reaches no shard
        short-circuits to noise without running a kernel, and a service
        with no live points and no global state yet short-circuits
        entirely (version 0).  Quarantined shards are routed around, so
        healthy shards keep answering during a fault — surfaced via
        ``QueryResult.degraded`` (and the legacy ``last_query_degraded``
        flag + ``degraded_queries`` counter).
        """
        t0 = time.monotonic()
        q = np.asarray(points, np.float32).reshape(-1, 2)
        self.last_query_degraded = False
        if self._global is None and self.n_live() == 0:
            res = qt.QueryResult(
                np.full((len(q),), -1, np.int32), version=0,
                latency_ms=(time.monotonic() - t0) * 1e3)
            return self._query_return(res, return_stale, legacy)
        if self._dirty or self._global is None:
            self.refresh()
        out, degraded, scanned = self._query_sync(q)
        self.last_query_degraded = degraded
        if degraded:
            self.degraded_queries += 1
        res = qt.QueryResult(
            out, version=self._snapshot_version, degraded=degraded,
            scanned_shards=tuple(sorted(scanned)),
            latency_ms=(time.monotonic() - t0) * 1e3)
        return self._query_return(res, return_stale, legacy)

    @staticmethod
    def _query_return(res: "qt.QueryResult", return_stale: bool,
                      legacy: bool):
        out = res.labels if legacy else res
        return (out, res.degraded) if return_stale else out

    def service_stats(self, tier: "qt.QueryTier | None" = None
                      ) -> "qt.ServiceStats":
        """The typed stats contract (DESIGN.md §12): monotonic counters,
        point-in-time gauges, and the comm meter snapshot.  ``tier``
        folds a ``QueryTier``'s serving counters in; the legacy
        ``stats()`` dict is derived from this via ``as_dict()``."""
        tc = tier.counters() if tier is not None else {}
        counters = qt.ServiceCounters(
            refreshes=self.refreshes,
            delta_refreshes=self.delta_refreshes,
            snapshots_published=self._snapshot_version,
            query_chunks=self.query_chunks,
            query_shards_scanned=self.query_shards_scanned,
            queries_served=tc.get("queries_served", 0),
            query_launches=tc.get("query_launches", 0),
            coalesced_requests=tc.get("coalesced_requests", 0),
            query_rows=tc.get("query_rows", 0),
            deadline_misses=tc.get("deadline_misses", 0),
            degraded_queries=self.degraded_queries
            + tc.get("degraded_queries", 0),
            retries=self.retries,
            quarantine_events=self.quarantine_events,
            fenced_deltas=self.fenced_deltas,
            journal_entries=self._journal.entries_total,
        )
        oldest_ts, newest_ts = self.window_ts()
        gauges = qt.ServiceGauges(
            shards=self.scfg.shards,
            capacity=self.scfg.capacity,
            n_live=self.n_live(),
            oldest_ts=oldest_ts,
            newest_ts=newest_ts,
            n_clusters=int(np.asarray(self._global.valid).sum())
            if self._global is not None else 0,
            snapshot_version=self._snapshot_version,
            snapshot_epoch=self._snapshot.epoch
            if self._snapshot is not None else 0,
            quarantined_now=tuple(sorted(self._quarantined)),
            queue_pending=tier.pending if tier is not None else 0,
            jit_cache_entries=qt.snapshot_query_cache_entries(),
        )
        comm = self.meter.snapshot() if self.meter is not None else {}
        return qt.ServiceStats(backend=self.flavor, counters=counters,
                               gauges=gauges, comm=comm)

    def remerge_full(self):
        """Recompute the global state from scratch (the baseline the
        delta path is measured against).  Exactness contract: the result
        is bit-identical to the incrementally maintained state."""
        return self.refresh(mode="full", force=True)

    # -- snapshot helpers (shared by both data planes) ----------------------

    def _mirror_arrays(self) -> dict:
        """The control-plane mirrors + aggregator ClusterSet cache, as
        the numpy dict both engines' ``state_dict`` builds on."""
        arrays = {
            "live": np.stack(self._live),
            "ts": np.stack(self._ts),
            "seq": np.stack(self._seq),
            # The authoritative host point mirror.  Healthy lanes hold
            # the same bits on device, but a quarantined lane's device
            # buffer is zeroed — the mirror (not "pts") is what journal
            # replay must land on, so it is serialised in its own right.
            "hpts": np.stack(self._hpts),
            "batch_contours": np.asarray(self._batch.contours),
            "batch_counts": np.asarray(self._batch.counts),
            "batch_sizes": np.asarray(self._batch.sizes),
            "batch_valid": np.asarray(self._batch.valid),
            "batch_overflow": np.asarray(self._batch.overflow),
        }
        if self._pair_d2 is not None:
            arrays["pair_d2"] = np.asarray(self._pair_d2)
        if self._tracker is not None:
            arrays.update(self._tracker.state_arrays())
        return arrays

    def _mirror_manifest(self) -> dict:
        return {
            "shards": self.scfg.shards,
            "capacity": self.scfg.capacity,
            "max_batch": self.scfg.max_batch,
            "max_queries": self.scfg.max_queries,
            "merge_mode": self.scfg.merge_mode,
            "agg_degree": self.scfg.agg_degree,
            "head": list(self._head),
            "count": list(self._count),
            "dirty": sorted(self._dirty),
            "next_seq": self._next_seq,
            "refreshes": self.refreshes,
            "delta_refreshes": self.delta_refreshes,
            "query_chunks": self.query_chunks,
            "query_shards_scanned": self.query_shards_scanned,
            "has_global": self._global is not None,
            "max_retries": self.scfg.max_retries,
            "retry_backoff": self.scfg.retry_backoff,
            "journal_limit": self.scfg.journal_limit,
            "epoch": list(self._epoch),
            "merged_epoch": list(self._merged_epoch),
            "quarantined": [[s, r] for s, r in
                            sorted(self._quarantined.items())],
            "retries": self.retries,
            "quarantine_events": self.quarantine_events,
            "fenced_deltas": self.fenced_deltas,
            "degraded_queries": self.degraded_queries,
            "journal_entries": self._journal.entries_total,
            "snapshot_version": self._snapshot_version,
            "track": self.scfg.track,
            "track_history": self.scfg.track_history,
            "match_min_overlap": self.scfg.match_min_overlap,
            "tracker": self._tracker.state_manifest()
            if self._tracker is not None else None,
        }

    def _restore_mirrors(self, arrays: dict, manifest: dict) -> None:
        """Rebuild every host mirror — including the coordinate mirror
        backing the bbox router — from ``state_dict`` output."""
        k = self.scfg.shards
        self._live = [np.asarray(arrays["live"][i], bool) for i in range(k)]
        self._ts = [np.asarray(arrays["ts"][i], np.float64) for i in range(k)]
        self._seq = [np.asarray(arrays["seq"][i], np.int64) for i in range(k)]
        hpts = arrays.get("hpts", arrays["pts"])   # pre-§11 fallback
        self._hpts = [np.asarray(hpts[i], np.float32).copy()
                      for i in range(k)]
        self._bbox = [None] * k
        self._head = [int(h) for h in manifest["head"]]
        self._count = [int(c) for c in manifest["count"]]
        self._next_seq = int(manifest["next_seq"])
        self._dirty = set(int(s) for s in manifest["dirty"])
        self.refreshes = int(manifest["refreshes"])
        self.delta_refreshes = int(manifest["delta_refreshes"])
        self.query_chunks = int(manifest.get("query_chunks", 0))
        self.query_shards_scanned = int(
            manifest.get("query_shards_scanned", 0))
        # Failure-model mirrors (absent in pre-§11 snapshots -> healthy
        # defaults).  The journal is not serialised: its base is re-set
        # to the restored mirrors, so a restored service can still
        # quarantine-and-recover from this point on.
        self._epoch = [int(e) for e in manifest.get("epoch", [0] * k)]
        self._merged_epoch = [int(e) for e in
                              manifest.get("merged_epoch", [-1] * k)]
        self._quarantined = {int(s): str(r)
                             for s, r in manifest.get("quarantined", [])}
        self.retries = int(manifest.get("retries", 0))
        self.quarantine_events = int(manifest.get("quarantine_events", 0))
        self.fenced_deltas = int(manifest.get("fenced_deltas", 0))
        self.degraded_queries = int(manifest.get("degraded_queries", 0))
        # Version monotonicity survives save/load: the restore publish
        # continues from the saved counter, never rewinds it.
        self._snapshot_version = int(manifest.get("snapshot_version", 0))
        self._journal.entries_total = int(manifest.get("journal_entries", 0))
        for s in range(k):
            self._journal.compact(s, self._hpts[s], self._live[s],
                                  self._ts[s], self._seq[s])
        self._journal.compactions = 0
        # Tracker state (absent in pre-§14 snapshots -> fresh tracker).
        if self._tracker is not None and manifest.get("tracker") is not None:
            self._tracker.load_state(arrays, manifest["tracker"])

    def _restore_batch(self, arrays: dict) -> None:
        """Rebuild the aggregator ClusterSet mirror (and the per-shard
        views) from ``state_dict`` output."""
        k = self.scfg.shards
        self._batch = ddc.ClusterSet(
            contours=jnp.asarray(arrays["batch_contours"], jnp.float32),
            counts=jnp.asarray(arrays["batch_counts"], jnp.int32),
            sizes=jnp.asarray(arrays["batch_sizes"], jnp.int32),
            valid=jnp.asarray(arrays["batch_valid"], bool),
            overflow=jnp.asarray(arrays["batch_overflow"], bool),
        )
        self._local = [jax.tree.map(lambda x, i=i: x[i], self._batch)
                       for i in range(k)]

    def _restore_global(self, arrays: dict, manifest: dict) -> bool:
        """Recompute global set + slot maps after ``_restore_batch``.

        Flat mode replays the saved pair-d2 cache through
        ``merge_from_d2``; hierarchical mode rebuilds every node cache
        from scratch over the restored batch — bit-identical to the
        pre-save tree by the per-node DESIGN §8 argument (delta-patched ≡
        from-scratch), so nothing tree-shaped needs serialising.  Returns
        False when the saved engine had no global state yet (callers skip
        the label rebuild + publish)."""
        if not manifest.get("has_global"):
            return False
        if self._hier is not None:
            self._global, self._maps = self._hier.refresh(
                self._batch, None, self._exclude_mask())
            return True
        if "pair_d2" not in arrays:
            return False
        self._pair_d2 = jnp.asarray(arrays["pair_d2"], jnp.float32)
        self._global, self._maps = ddc.merge_from_d2(
            self._batch, self._pair_d2, self.cfg, self._exclude_mask())
        return True

    # -- introspection ------------------------------------------------------

    def n_live(self) -> int:
        return sum(self._count)

    def _live_buffers(self):
        """Data-plane hook for ``live()``: fetch (pts (K, cap, 2),
        mask (K, cap), glabels (K, cap)) as numpy arrays."""
        raise NotImplementedError

    def live(self) -> Tuple[np.ndarray, list, np.ndarray]:
        """Materialise the live state for host-side checks.

        Returns (points (L, 2), parts, labels (L,)): ``parts[s]`` indexes
        the rows of ``points`` held by shard ``s`` — exactly the explicit
        partition ``ddc.ddc_host`` accepts, so streaming≡batch
        equivalence is checked on identical per-shard memberships.
        """
        if self._dirty or self._global is None:
            self.refresh()
        pts, mask, glab = self._live_buffers()
        pts_rows, parts, labels = [], [], []
        base = 0
        for s in range(self.scfg.shards):
            msk = mask[s]
            pts_rows.append(pts[s][msk])
            labels.append(glab[s][msk])
            parts.append(np.arange(base, base + int(msk.sum())))
            base += int(msk.sum())
        return (np.concatenate(pts_rows) if base else np.zeros((0, 2), np.float32),
                parts,
                np.concatenate(labels) if base else np.zeros((0,), np.int32))

    def local_set(self, shard: int) -> ddc.ClusterSet:
        self._check_shard(shard)
        return self._local[shard]

    @property
    def pair_d2(self) -> Optional[jax.Array]:
        """Snapshot (copy) of the cached slot-distance matrix.  The live
        buffer is donated to the next delta refresh, so handing out a
        reference would leave callers holding a deleted array."""
        return None if self._pair_d2 is None else jnp.array(self._pair_d2)

    @property
    def hierarchy(self) -> Optional[hierarchy.AggregatorTree]:
        """The aggregator tree (None in flat mode).  In hierarchical mode
        ``pair_d2`` is None by construction — the per-node caches are the
        cache, reachable here for tests and the chaos sweep."""
        return self._hier

    @property
    def global_set(self) -> Optional[ddc.ClusterSet]:
        return self._global

    def routing_stats(self) -> dict:
        return {
            "query_chunks": self.query_chunks,
            "query_shards_scanned": self.query_shards_scanned,
            "query_shards_possible": self.query_chunks * self.scfg.shards,
        }

    def stats(self) -> dict:
        """Legacy dict view, now DERIVED from the typed ``ServiceStats``
        (``service_stats().as_dict()``) so the two can never drift."""
        return self.service_stats().as_dict()


# ---------------------------------------------------------------------------
# The host-driven service
# ---------------------------------------------------------------------------


class ClusterService(ShardControlPlane):
    """Host-driven streaming DDC engine over K logical shards.

    Write path: ``ingest(shard, points)`` appends into the shard's ring
    buffer (evicting the oldest on overflow) and marks it dirty;
    ``refresh()`` re-clusters dirty shards and delta-merges them into the
    cached global state.  Read path: ``query(points)`` returns global
    cluster ids against the last refreshed state (auto-refreshing if
    writes are pending), scanning only bbox-routed candidate shards.
    All device state is static-shape, so every kernel compiles once per
    (StreamConfig) and is reused for the lifetime of the service.
    """

    flavor = "stream"

    def __init__(self, scfg: StreamConfig, meter: ddc.CommMeter | None = None,
                 faults: faults_mod.FaultPlan | None = None):
        super().__init__(scfg, meter, faults=faults)
        k, cap = scfg.shards, scfg.capacity
        self._pts: List[jax.Array] = [
            jnp.zeros((cap, 2), jnp.float32) for _ in range(k)]
        self._mask: List[jax.Array] = [jnp.zeros((cap,), bool) for _ in range(k)]
        self._dense = jnp.full((k, cap), -1, jnp.int32)
        self._glabels = jnp.full((k, cap), -1, jnp.int32)
        self._stack_cache: dict = {}

    # -- data plane ---------------------------------------------------------

    def _append_chunk(self, shard, chunk, idx, nb) -> None:
        self._pts[shard], self._mask[shard] = _append(
            self._pts[shard], self._mask[shard],
            jnp.asarray(chunk), jnp.asarray(idx), nb)

    def _kill_device(self, shard, kill) -> None:
        self._mask[shard] = _kill_mask(self._mask[shard], jnp.asarray(kill))

    def _restore_lane(self, shard, pts, live) -> None:
        self._pts[shard] = jnp.asarray(pts, jnp.float32)
        self._mask[shard] = jnp.asarray(live, bool)

    def _invalidate_reads(self) -> None:
        self._stack_cache.clear()

    # -- refresh (phase 1 on dirty shards + delta/full merge) --------------

    def refresh(self, mode: str | None = None, force: bool = False,
                track: bool | None = None):
        """Re-cluster dirty shards and fold them into the global state.

        ``mode`` overrides the configured merge mode for this call;
        ``force`` recomputes even with no dirty shards (the full-remerge
        baseline the benchmark times); ``track`` is the per-call
        tracking override (``_track_update``).  Returns the global
        ClusterSet.
        """
        mode = mode or self.scfg.merge_mode
        cfg = self.cfg
        dirty = sorted(self._dirty - self._quarantined.keys())
        if not dirty and self._global is not None and not force:
            return self._global

        def produce(i, attempt):
            if self._count[i] == 0:
                # Emptied shard: the cached all-invalid ClusterSet, no
                # phase-1 work (extends the PR 2 empty-shard fix).
                cs = ddc.empty_clusterset(cfg)
                dense = jnp.full((self.scfg.capacity,), -1, jnp.int32)
            else:
                dense, cs = ddc.local_phase(self._pts[i], self._mask[i], cfg)
            self._dense = _set_row(self._dense, dense, i)
            return _cs_to_host(cs), cs

        staged = self._exchange_deltas(dirty, produce)
        self._merge_and_meter(staged, mode)
        self._meter_maps_down()
        self._glabels = _global_labels(
            self._dense, jnp.stack(self._mask), self._maps)
        self._dirty -= set(staged)
        self._track_update(track)
        self.refreshes += 1
        self._publish_snapshot()
        return self._global

    # -- read path ---------------------------------------------------------

    def _read_view(self):
        # jnp.stack materialises fresh device arrays (copies), so the
        # snapshot survives the donated in-place ring updates; _glabels
        # is never donated, holding the reference is safe.
        return jnp.stack(self._pts), jnp.stack(self._mask), self._glabels

    def _query_sync(self, q: np.ndarray):
        qmax = self.scfg.max_queries
        degraded = False
        scanned: set = set()
        out = np.empty((len(q),), np.int32)
        for off in range(0, len(q), qmax):
            chunk = q[off:off + qmax]
            nq = len(chunk)
            scan = self._route(chunk)
            degraded |= self._route_degraded
            sel = np.nonzero(scan)[0]
            scanned.update(int(s) for s in sel)
            if len(sel) == 0:
                out[off:off + nq] = -1
                continue
            pts, mask, rows = self._scan_stack(sel)
            glab = jnp.take(self._glabels, rows, axis=0)
            if nq < qmax:
                chunk = np.pad(chunk, ((0, qmax - nq), (0, 0)))
            lab = _query_labels(jnp.asarray(chunk), nq, pts, mask, glab,
                                self.cfg.eps)
            out[off:off + nq] = np.asarray(lab)[:nq]
        return out, degraded, scanned

    def _scan_stack(self, sel: np.ndarray):
        """Stack the scanned shards' buffers, padded to a power-of-two
        width so the query kernel compiles at most log2(K)+1 times.
        Padded rows point at shard 0 with a zeroed mask (inert).  Cached
        per scan set; any ingest/evict invalidates (the buffers are
        replaced by donation)."""
        key = tuple(int(s) for s in sel)
        hit = self._stack_cache.get(key)
        if hit is None:
            spad = 1 << max(0, (len(sel) - 1).bit_length())
            pad = np.concatenate(
                [sel, np.zeros((spad - len(sel),), np.int64)])
            valid = np.arange(spad) < len(sel)
            pts = jnp.stack([self._pts[s] for s in pad])
            mask = jnp.stack([self._mask[s] for s in pad]) \
                & jnp.asarray(valid)[:, None]
            if len(self._stack_cache) > 16:
                self._stack_cache.clear()
            hit = (pts, mask, jnp.asarray(pad))
            self._stack_cache[key] = hit
        return hit

    # -- introspection -----------------------------------------------------

    def _live_buffers(self):
        return (np.stack([np.asarray(p) for p in self._pts]),
                np.stack([np.asarray(m) for m in self._mask]),
                np.asarray(self._glabels))

    # -- snapshot / restore -------------------------------------------------

    def state_dict(self) -> Tuple[dict, dict]:
        """Serialise the FULL engine state as (arrays, manifest).

        Everything downstream of (ring buffers, dense labels, per-shard
        ClusterSets, pair-d2 cache) is a deterministic jitted function of
        those inputs, so the global set / slot maps / global labels are
        *recomputed* on restore (``merge_from_d2`` + ``_global_labels``)
        rather than stored — bit-identical by the DESIGN.md §8 argument,
        and the snapshot stays minimal.  The bbox mirrors rebuild from
        the saved buffers (live slots only), so they are not stored.
        """
        arrays = {
            "pts": np.stack([np.asarray(p) for p in self._pts]),
            "mask": np.stack([np.asarray(m) for m in self._mask]),
            "dense": np.asarray(self._dense),
        } | self._mirror_arrays()
        return arrays, self._mirror_manifest()

    @classmethod
    def from_state(cls, scfg: StreamConfig, arrays: dict, manifest: dict,
                   meter: ddc.CommMeter | None = None,
                   faults: faults_mod.FaultPlan | None = None
                   ) -> "ClusterService":
        """Rebuild a service from ``state_dict`` output.  The restored
        engine resumes bit-identically: same labels, same cached pair-d2
        matrix, same delta/full behaviour on the next refresh — no
        re-cluster of the live points."""
        svc = cls(scfg, meter=meter, faults=faults)
        k = scfg.shards
        svc._pts = [jnp.asarray(arrays["pts"][i], jnp.float32)
                    for i in range(k)]
        svc._mask = [jnp.asarray(arrays["mask"][i], bool) for i in range(k)]
        svc._dense = jnp.asarray(arrays["dense"], jnp.int32)
        svc._restore_mirrors(arrays, manifest)
        svc._restore_batch(arrays)
        if svc._restore_global(arrays, manifest):
            svc._glabels = _global_labels(
                svc._dense, jnp.stack(svc._mask), svc._maps)
            # Restore ends with an eager publish, like refresh does: the
            # version counter continues past the saved one (monotonic).
            svc._publish_snapshot()
        return svc

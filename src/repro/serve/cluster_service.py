"""Streaming DDC serve engine: incremental ingest, delta-merge, queries.

The paper's two-phase split (local clustering, then contour-only
aggregation) is what makes an *online* clustering service cheap: when new
points land on one shard, only that shard's local clusters change, and
the global view is repaired by re-merging just the touched contours — no
bulk data exchange.  This module is that serving path:

* **Ingest buffers** — every shard owns a static-shape ring buffer
  ((capacity, 2) points + live mask), donated to the jitted append kernel
  so updates are in-place on device.  Appending past capacity evicts the
  oldest points (ring overwrite); ``evict_oldest`` is the explicit
  eviction API.  The append kernel branches under ``lax.cond`` between a
  contiguous fast path (no wraparound: one ``dynamic_update_slice``) and
  the general wrap/evict scatter.
* **Dirty-shard phase 1** — ``refresh`` re-runs ``ddc.local_phase`` only
  on shards whose buffers changed since the last refresh; an emptied
  shard short-circuits to the cached ``ddc.empty_clusterset`` without
  touching the device.
* **Delta-merge phase 2** — the engine caches the per-shard ClusterSets
  *and* the (K·C, K·C) slot×slot contour-distance matrix behind
  ``ddc.merge_many``.  A delta refresh recomputes only the dirty shards'
  rows/columns (``ddc.update_pair_d2``) and re-closes the transitive
  closure (``ddc.merge_from_d2``).  This is **exact**, not approximate:
  the matrix is a pure per-slot-pair function of the per-shard contours,
  so patching dirty rows/columns reproduces the from-scratch matrix
  bit-for-bit, and everything downstream (components, ranking, contour
  rebuild) is a deterministic function of (batch, matrix).  In
  particular, evictions that *split* a global cluster are handled
  correctly — the closure is always recomputed over per-shard contours,
  never over the (unsplittable) merged global contour.  DESIGN.md §8.
* **Queries** — ``query`` maps read-traffic points to global cluster ids:
  nearest clustered live point within ``eps`` (DBSCAN's border rule
  applied to the frozen clustering), else noise.

Communication model (``CommMeter``): shards and the aggregator are
distinct nodes.  A full re-merge ships all K ClusterSets up
(K·B bytes, B = ``DDCConfig.buffer_bytes()``); a delta refresh ships only
the dirty ones (|dirty|·B).  Both ship each shard its (C,) slot-map row
back down (K·C·4 bytes).  Steady-state single-shard ingest therefore
moves B + K·C·4 per refresh vs K·B + K·C·4 — the measurable
minimal-communication claim (benchmarks/serve.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ddc


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Static configuration of the streaming engine."""

    shards: int                     # K logical shards
    capacity: int                   # per-shard point-buffer slots
    max_batch: int = 256            # static ingest width (host pads)
    max_queries: int = 256          # static query width (host pads)
    merge_mode: str = "delta"       # "delta" | "full"
    ddc: ddc.DDCConfig = dataclasses.field(default_factory=ddc.DDCConfig)


# ---------------------------------------------------------------------------
# Jitted state-update kernels (static shapes; buffers donated)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _append(pts_buf, mask_buf, head, count, batch, nb):
    """Ring-buffer append of ``nb`` valid rows of ``batch``.

    ``lax.cond`` picks between the contiguous fast path (the batch window
    fits before the buffer end and nothing live is overwritten: one
    dynamic_update_slice) and the general wraparound path (modulo
    scatter), which is also the eviction path — slots wrapped onto are
    the oldest live points and are overwritten in place.
    """
    cap = pts_buf.shape[0]
    bmax = batch.shape[0]
    wvalid = jnp.arange(bmax) < nb

    def fast(bufs):
        pts, msk = bufs
        wpts = jax.lax.dynamic_slice(pts, (head, 0), (bmax, 2))
        wmsk = jax.lax.dynamic_slice(msk, (head,), (bmax,))
        pts = jax.lax.dynamic_update_slice(
            pts, jnp.where(wvalid[:, None], batch, wpts), (head, 0))
        msk = jax.lax.dynamic_update_slice(msk, wmsk | wvalid, (head,))
        return pts, msk

    def wrap_evict(bufs):
        pts, msk = bufs
        idx = (head + jnp.arange(bmax)) % cap
        safe = jnp.where(wvalid, idx, cap)           # invalid rows drop
        pts = pts.at[safe].set(batch, mode="drop")
        msk = msk.at[safe].set(True, mode="drop")
        return pts, msk

    fits = (head + bmax <= cap) & (count + nb <= cap)
    pts_buf, mask_buf = jax.lax.cond(fits, fast, wrap_evict,
                                     (pts_buf, mask_buf))
    return pts_buf, mask_buf


@functools.partial(jax.jit, donate_argnums=(0,))
def _kill_oldest(mask_buf, tail, n):
    """Clear the live bit of the ``n`` oldest slots (ring order)."""
    cap = mask_buf.shape[0]
    idx = (tail + jnp.arange(cap)) % cap
    safe = jnp.where(jnp.arange(cap) < n, idx, cap)
    return mask_buf.at[safe].set(False, mode="drop")


@functools.partial(jax.jit, donate_argnums=(0,))
def _set_row(stack, row, i):
    """stack[i] <- row for every leaf of a stacked pytree (in place)."""
    return jax.tree.map(
        lambda s, x: jax.lax.dynamic_update_slice(
            s, x[None], (i,) + (0,) * x.ndim),
        stack, row)


@jax.jit
def _global_labels(dense, mask, maps):
    """(K, cap) dense local labels + (K, C) slot maps -> global labels."""
    def one(d, m, mp):
        return jnp.where(m & (d >= 0), mp[jnp.clip(d, 0)], -1)
    return jax.vmap(one)(dense, mask, maps)


@jax.jit
def _query_labels(q, qn, pts, mask, glabels, eps):
    """Nearest clustered live point within eps, else -1.  q: (Qmax, 2)."""
    flat = pts.reshape(-1, 2)
    ok = (mask & (glabels >= 0)).reshape(-1)
    d2 = jnp.sum((q[:, None, :] - flat[None, :, :]) ** 2, axis=-1)
    d2 = jnp.where(ok[None, :], d2, jnp.float32(1e30))
    j = jnp.argmin(d2, axis=1)
    hit = d2[jnp.arange(q.shape[0]), j] <= eps * eps
    lab = jnp.where(hit, glabels.reshape(-1)[j], -1)
    return jnp.where(jnp.arange(q.shape[0]) < qn, lab, -1)


# ---------------------------------------------------------------------------
# The service
# ---------------------------------------------------------------------------


class ClusterService:
    """Host-driven streaming DDC engine over K logical shards.

    Write path: ``ingest(shard, points)`` appends into the shard's ring
    buffer (evicting the oldest on overflow) and marks it dirty;
    ``refresh()`` re-clusters dirty shards and delta-merges them into the
    cached global state.  Read path: ``query(points)`` returns global
    cluster ids against the last refreshed state (auto-refreshing if
    writes are pending).  All device state is static-shape, so every
    kernel compiles once per (StreamConfig) and is reused for the
    lifetime of the service.
    """

    def __init__(self, scfg: StreamConfig, meter: ddc.CommMeter | None = None):
        if scfg.merge_mode not in ("delta", "full"):
            raise ValueError(scfg.merge_mode)
        if scfg.capacity < scfg.max_batch:
            raise ValueError(
                f"capacity {scfg.capacity} < max_batch {scfg.max_batch}: an "
                f"append chunk could overwrite itself in the ring scatter")
        self.scfg = scfg
        self.cfg = scfg.ddc
        self.meter = meter
        k, cap = scfg.shards, scfg.capacity
        self._pts: List[jax.Array] = [
            jnp.zeros((cap, 2), jnp.float32) for _ in range(k)]
        self._mask: List[jax.Array] = [jnp.zeros((cap,), bool) for _ in range(k)]
        # Host mirrors of the ring state (known exactly from the call
        # sequence — no device sync on the write path).
        self._head = [0] * k
        self._count = [0] * k
        self._dirty = set(range(k))
        empty = ddc.empty_clusterset(self.cfg)
        self._local: List[ddc.ClusterSet] = [empty] * k
        self._batch: ddc.ClusterSet = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (k,) + x.shape), empty)
        self._dense = jnp.full((k, cap), -1, jnp.int32)
        self._pair_d2: Optional[jax.Array] = None
        self._global: Optional[ddc.ClusterSet] = None
        self._maps: Optional[jax.Array] = None
        self._glabels = jnp.full((k, cap), -1, jnp.int32)
        self._stacked: Optional[Tuple[jax.Array, jax.Array]] = None
        self.refreshes = 0
        self.delta_refreshes = 0

    # -- write path --------------------------------------------------------

    def ingest(self, shard: int, points: np.ndarray) -> None:
        """Append ``points`` (n, 2) to ``shard``'s buffer, evicting the
        oldest live points if the buffer would overflow."""
        cap, bmax = self.scfg.capacity, self.scfg.max_batch
        pts = np.asarray(points, np.float32).reshape(-1, 2)
        for off in range(0, len(pts), bmax):
            chunk = pts[off:off + bmax]
            nb = len(chunk)
            if nb < bmax:
                chunk = np.pad(chunk, ((0, bmax - nb), (0, 0)))
            self._pts[shard], self._mask[shard] = _append(
                self._pts[shard], self._mask[shard],
                self._head[shard], self._count[shard], jnp.asarray(chunk), nb)
            self._head[shard] = (self._head[shard] + nb) % cap
            self._count[shard] = min(self._count[shard] + nb, cap)
        if len(pts):
            self._dirty.add(shard)
            self._stacked = None

    def evict_oldest(self, shard: int, n: int) -> int:
        """Evict the ``n`` oldest live points from ``shard``.  Returns the
        number actually evicted."""
        n = min(n, self._count[shard])
        if n == 0:
            return 0
        cap = self.scfg.capacity
        tail = (self._head[shard] - self._count[shard]) % cap
        self._mask[shard] = _kill_oldest(self._mask[shard], tail, n)
        self._count[shard] -= n
        self._dirty.add(shard)
        self._stacked = None
        return n

    def clear(self, shard: int) -> int:
        """Evict every live point from ``shard``."""
        return self.evict_oldest(shard, self._count[shard])

    # -- refresh (phase 1 on dirty shards + delta/full merge) --------------

    def refresh(self, mode: str | None = None, force: bool = False):
        """Re-cluster dirty shards and fold them into the global state.

        ``mode`` overrides the configured merge mode for this call;
        ``force`` recomputes even with no dirty shards (the full-remerge
        baseline the benchmark times).  Returns the global ClusterSet.
        """
        mode = mode or self.scfg.merge_mode
        cfg = self.cfg
        k, c = self.scfg.shards, cfg.max_clusters
        dirty = sorted(self._dirty)
        if not dirty and self._global is not None and not force:
            return self._global

        for i in dirty:
            if self._count[i] == 0:
                # Emptied shard: the cached all-invalid ClusterSet, no
                # phase-1 work (extends the PR 2 empty-shard fix).
                cs = ddc.empty_clusterset(cfg)
                dense = jnp.full((self.scfg.capacity,), -1, jnp.int32)
            else:
                dense, cs = ddc.local_phase(self._pts[i], self._mask[i], cfg)
            self._local[i] = cs
            self._batch = _set_row(self._batch, cs, i)
            self._dense = _set_row(self._dense, dense, i)

        bbytes = cfg.buffer_bytes()
        if mode == "delta" and self._pair_d2 is not None:
            for i in dirty:
                self._pair_d2 = ddc.update_pair_d2(
                    self._pair_d2, self._batch, i, cfg)
            if self.meter is not None:
                self.meter.add_collective(len(dirty), bbytes)
            self.delta_refreshes += 1
        else:
            # Difference-form build (not the Pallas kernel): the cached
            # matrix must stay bit-compatible with the delta patches on
            # every backend — see ddc.contour_pair_d2_exact.
            self._pair_d2 = ddc.contour_pair_d2_exact(self._batch, cfg)
            if self.meter is not None:
                self.meter.add_collective(k, bbytes)
        if self.meter is not None:
            self.meter.add_merge(k, c)
            self.meter.add_collective(k, c * 4)   # per-shard map rows down
        self._global, self._maps = ddc.merge_from_d2(
            self._batch, self._pair_d2, cfg)
        self._glabels = _global_labels(
            self._dense, jnp.stack(self._mask), self._maps)
        self._dirty.clear()
        self.refreshes += 1
        return self._global

    def remerge_full(self):
        """Recompute the global state from scratch (the baseline the
        delta path is measured against).  Exactness contract: the result
        is bit-identical to the incrementally maintained state."""
        return self.refresh(mode="full", force=True)

    # -- read path ---------------------------------------------------------

    def query(self, points: np.ndarray) -> np.ndarray:
        """Global cluster id for each query point: the label of the
        nearest clustered live point within ``eps`` (DBSCAN's border
        rule against the frozen clustering), else -1."""
        if self._dirty or self._global is None:
            self.refresh()
        qmax = self.scfg.max_queries
        q = np.asarray(points, np.float32).reshape(-1, 2)
        out = np.empty((len(q),), np.int32)
        if self._stacked is None:     # invalidated by ingest/evict
            self._stacked = (jnp.stack(self._pts), jnp.stack(self._mask))
        pts, mask = self._stacked
        for off in range(0, len(q), qmax):
            chunk = q[off:off + qmax]
            nq = len(chunk)
            if nq < qmax:
                chunk = np.pad(chunk, ((0, qmax - nq), (0, 0)))
            lab = _query_labels(jnp.asarray(chunk), nq, pts, mask,
                                self._glabels, self.cfg.eps)
            out[off:off + nq] = np.asarray(lab)[:nq]
        return out

    # -- introspection -----------------------------------------------------

    def local_set(self, shard: int) -> ddc.ClusterSet:
        return self._local[shard]

    @property
    def pair_d2(self) -> Optional[jax.Array]:
        """Snapshot (copy) of the cached slot-distance matrix.  The live
        buffer is donated to the next delta refresh, so handing out a
        reference would leave callers holding a deleted array."""
        return None if self._pair_d2 is None else jnp.array(self._pair_d2)

    @property
    def global_set(self) -> Optional[ddc.ClusterSet]:
        return self._global

    def n_live(self) -> int:
        return sum(self._count)

    def live(self) -> Tuple[np.ndarray, List[np.ndarray], np.ndarray]:
        """Materialise the live state for host-side checks.

        Returns (points (L, 2), parts, labels (L,)): ``parts[s]`` indexes
        the rows of ``points`` held by shard ``s`` — exactly the explicit
        partition ``ddc.ddc_host`` accepts, so streaming≡batch
        equivalence is checked on identical per-shard memberships.
        """
        if self._dirty or self._global is None:
            self.refresh()
        pts_rows, parts, labels = [], [], []
        base = 0
        for s in range(self.scfg.shards):
            msk = np.asarray(self._mask[s])
            live = np.asarray(self._pts[s])[msk]
            labs = np.asarray(self._glabels[s])[msk]
            pts_rows.append(live)
            labels.append(labs)
            parts.append(np.arange(base, base + len(live)))
            base += len(live)
        return (np.concatenate(pts_rows) if base else np.zeros((0, 2), np.float32),
                parts,
                np.concatenate(labels) if base else np.zeros((0,), np.int32))

    def stats(self) -> dict:
        out = {
            "shards": self.scfg.shards,
            "capacity": self.scfg.capacity,
            "n_live": self.n_live(),
            "refreshes": self.refreshes,
            "delta_refreshes": self.delta_refreshes,
            "n_clusters": int(np.asarray(self._global.valid).sum())
            if self._global is not None else 0,
        }
        if self.meter is not None:
            out["comm"] = self.meter.snapshot()
        return out

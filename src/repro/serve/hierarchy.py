"""Hierarchical tree-of-aggregators (DESIGN.md §13).

The flat serve engines funnel every delta into ONE aggregator that owns
the global ClusterSet and the full (K·C)² pair-d2 cache — the scaling
ceiling past a few dozen shards (ROADMAP item 2; the paper's aggregation
phase promises the opposite: "does not involve the exchange of large
amounts of data").  `AggregatorTree` replaces it with a D-ary tree of
small aggregators layered over the SAME core primitives:

- every node owns a stacked (D, C, …) ClusterSet of its children's
  summaries, a (D·C)² pair-d2 cache over only those slots, and the
  folded C-slot summary it exports upward;
- a node refresh IS `ddc.merge_delta` with node-local dirty child
  positions and a node-local exclude mask — patch the dirty rows of the
  node cache (`update_pair_d2_many`), refold (`merge_from_d2`);
- a dirty shard patches its leaf node and propagates up the ancestor
  path only; propagation stops the moment a node's exported summary is
  bit-identical to what the parent already holds (absorption);
- the root publishes the global set, and per-shard slot maps are
  composed down the path (`x → parent_map[x]` per level, the
  `merge_tree` idiom), then canonically relabeled so per-shard
  ``glabels`` stay bit-identical to the flat aggregator.

Exactness argument (why labels match the flat path bit-for-bit):

1. Per node, the delta-patched cache equals a from-scratch
   `contour_pair_d2_exact` of its batch (DESIGN §8 — same difference
   form, IEEE-symmetric mirror), so each fold is independent of patch
   history; `cache_exact()` asserts this.
2. The flat fold labels a component by rank (member-count, descending)
   with ties broken by the component's minimum flat slot index (the
   min-label closure + stable argsort in `merge_from_d2`).  Component
   member sets survive re-aggregation (the `merge_tree ≡ merge_sync`
   equivalence the phase-2 suite asserts per layout), member counts are
   exact integer sums in any association order, and the minimum flat
   slot of a component is order-free — so re-ranking the ROOT's slots by
   (size desc, min composed flat slot asc) reproduces the flat
   aggregator's slot ids exactly.  That canonical relabel is the last
   step of every refresh.

Failure model (§11) composition: the engine's quarantine mask is applied
at the LEAF fold only — an excluded shard's slots are treated invalid at
its leaf node, the leaf's summary no longer carries them, and every
ancestor refold is automatically quarantine-free.  The shard's cached
rows in its leaf stay intact, so rejoin is one ordinary row patch, same
as the flat engine.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ddc

_BIG = np.iinfo(np.int32).max


def _cs_equal(a: ddc.ClusterSet, b: ddc.ClusterSet) -> bool:
    """Bitwise equality of two ClusterSets (host compare)."""
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(a, b))


@dataclasses.dataclass
class _Node:
    """One aggregator in the tree.

    ``children`` are shard ids at level 0 (the leaf-node level) and
    previous-level node positions above it; the stacked ``batch`` is
    padded with empty ClusterSets when a node has fewer than D children,
    so every fold in the tree shares one (D, cfg) jit compilation.
    """

    children: List[int]
    batch: ddc.ClusterSet
    pair_d2: Optional[jax.Array] = None
    summary: Optional[ddc.ClusterSet] = None
    maps: Optional[jax.Array] = None          # (D, C) child slot → summary slot
    to_root: Optional[np.ndarray] = None      # (C,) summary slot → root slot


class AggregatorTree:
    """A D-ary tree of delta-cached aggregators over K shards.

    Host-driven like the flat control plane: `refresh(batch, dirty,
    exclude)` takes the engine's (K, C, …) aggregator mirror, the list of
    freshly staged shard ids (None = full rebuild of every node cache
    from scratch), and the quarantine mask, and returns the
    ``(global ClusterSet, (K, C) slot maps)`` pair in exactly the flat
    aggregator's contract — callers cannot tell the topologies apart
    except through the comm meter.
    """

    def __init__(self, shards: int, degree: int, cfg: ddc.DDCConfig,
                 meter: Optional[ddc.CommMeter] = None):
        if degree < 2:
            raise ValueError(f"agg_degree must be >= 2, got {degree}")
        if shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        self.shards = int(shards)
        self.degree = int(degree)
        self.cfg = cfg
        self.meter = meter
        self.levels: List[List[_Node]] = []
        members = list(range(self.shards))
        while True:
            level = [
                _Node(children=members[i:i + self.degree],
                      batch=self._empty_batch())
                for i in range(0, len(members), self.degree)
            ]
            self.levels.append(level)
            if len(level) == 1:
                break
            members = list(range(len(level)))
        self._last_exclude: Optional[np.ndarray] = None
        self._global: Optional[ddc.ClusterSet] = None
        self._maps: Optional[jax.Array] = None
        self._prev_m: Optional[np.ndarray] = None
        self.last_stats: dict = {}

    # -- topology ----------------------------------------------------------

    @property
    def depth(self) -> int:
        return len(self.levels)

    @property
    def n_nodes(self) -> int:
        return sum(len(level) for level in self.levels)

    @property
    def internal_edges(self) -> int:
        """Node→node edges (excludes the K shard→leaf edges)."""
        return self.n_nodes - 1

    @property
    def ready(self) -> bool:
        return self.levels[-1][0].summary is not None

    def _empty_batch(self) -> ddc.ClusterSet:
        empty = ddc.empty_clusterset(self.cfg)
        return jax.tree.map(
            lambda x: jnp.stack([x] * self.degree), empty)

    # -- introspection (tests, chaos sweep) --------------------------------

    def cache_arrays(self) -> List[np.ndarray]:
        """Every built node cache, level order — the hierarchical
        counterpart of the flat engine's ``pair_d2`` property."""
        return [np.asarray(node.pair_d2)
                for level in self.levels for node in level
                if node.pair_d2 is not None]

    def cache_exact(self) -> bool:
        """True iff every node's delta-patched cache is bit-identical to
        a from-scratch rebuild over its current batch — the per-node
        DESIGN §8 invariant the whole exactness argument rests on."""
        for level in self.levels:
            for node in level:
                if node.pair_d2 is None:
                    continue
                scratch = ddc.contour_pair_d2_exact(node.batch, self.cfg)
                if not np.array_equal(np.asarray(node.pair_d2),
                                      np.asarray(scratch)):
                    return False
        return True

    # -- refresh -----------------------------------------------------------

    def refresh(self, batch: ddc.ClusterSet, dirty=None, exclude=None
                ) -> Tuple[ddc.ClusterSet, jax.Array]:
        """Fold the engine mirror through the tree.

        ``batch``: the (K, C, …) stacked per-shard ClusterSets (leaf
        payloads are gathered from it row-by-row, so only dirty shards'
        rows are ever read on the delta path).  ``dirty``: staged shard
        ids, or None to rebuild every node cache from scratch.
        ``exclude``: optional (K,) bool quarantine mask, honored at the
        leaf fold (see module docstring).
        """
        cfg, d = self.cfg, self.degree
        c = cfg.max_clusters
        exclude_np = (None if exclude is None
                      else np.asarray(exclude, bool).copy())
        full = dirty is None or not self.ready
        stats = {"folds": 0, "absorbed": 0, "up_shard_payloads": 0,
                 "internal_up_edges": 0, "down_internal_edges": 0,
                 "down_shard_rows": 0, "bottleneck_bytes": 0}
        load: dict = {}
        bbytes = cfg.buffer_bytes()

        # Which leaf nodes must act, and which member slots changed.
        pending: dict = {}
        if full:
            for ni, node in enumerate(self.levels[0]):
                pending[ni] = set(range(len(node.children)))
            stats["up_shard_payloads"] = self.shards
        else:
            for s in dirty:
                pending.setdefault(int(s) // d, set()).add(int(s) % d)
            stats["up_shard_payloads"] = len(set(int(s) for s in dirty))
            # A quarantine flip without a staged delta still forces the
            # affected leaf to refold (no cache patch — rows are intact).
            prev = self._last_exclude
            for ni, node in enumerate(self.levels[0]):
                for s in node.children:
                    was = bool(prev[s]) if prev is not None else False
                    now = (bool(exclude_np[s])
                           if exclude_np is not None else False)
                    if was != now:
                        pending.setdefault(ni, set())
        self._last_exclude = exclude_np

        any_changed = False
        for li, level in enumerate(self.levels):
            next_pending: dict = {}
            for ni in sorted(pending):
                node = level[ni]
                positions = sorted(pending[ni])
                if positions:
                    if li == 0:
                        src = [node.children[j] for j in positions]
                        rows = jax.tree.map(
                            lambda x: x[jnp.asarray(src)], batch)
                        load[(li, ni)] = load.get((li, ni), 0) \
                            + len(src) * bbytes
                    else:
                        kids = [self.levels[li - 1][node.children[j]].summary
                                for j in positions]
                        rows = jax.tree.map(
                            lambda *xs: jnp.stack(xs), *kids)
                    idx = jnp.asarray(positions, jnp.int32)
                    node.batch = jax.tree.map(
                        lambda b, r: b.at[idx].set(r), node.batch, rows)
                excl = None
                if li == 0 and exclude_np is not None:
                    bits = np.zeros((d,), bool)
                    for j, s in enumerate(node.children):
                        bits[j] = exclude_np[s]
                    if bits.any():
                        excl = jnp.asarray(bits)
                use_cache = not full and node.pair_d2 is not None
                prev_summary, prev_maps = node.summary, node.maps
                node.summary, node.maps, node.pair_d2 = ddc.merge_delta(
                    node.batch,
                    node.pair_d2 if use_cache else None,
                    positions if use_cache else None,
                    cfg, excl)
                stats["folds"] += 1
                if self.meter is not None:
                    self.meter.add_merge(d, c)
                summary_changed = (prev_summary is None
                                   or not _cs_equal(prev_summary,
                                                    node.summary))
                maps_changed = (prev_maps is None
                                or not np.array_equal(
                                    np.asarray(prev_maps),
                                    np.asarray(node.maps)))
                any_changed = any_changed or summary_changed or maps_changed
                if summary_changed and li + 1 < len(self.levels):
                    next_pending.setdefault(ni // d, set()).add(ni % d)
                    stats["internal_up_edges"] += 1
                    load[(li, ni)] = load.get((li, ni), 0) + bbytes
                    load[(li + 1, ni // d)] = \
                        load.get((li + 1, ni // d), 0) + bbytes
                    if self.meter is not None:
                        self.meter.add_collective(1, bbytes)
                elif not summary_changed:
                    stats["absorbed"] += 1
            pending = next_pending
            if not pending and li + 1 < len(self.levels):
                break

        if any_changed or self._maps is None:
            self._compose_down(stats, load)
        stats["bottleneck_bytes"] = max(load.values(), default=0)
        self.last_stats = stats
        return self._global, self._maps

    # -- down pass: map composition + canonical relabel --------------------

    def _compose_down(self, stats: dict, load: dict) -> None:
        cfg, d, k = self.cfg, self.degree, self.shards
        c = cfg.max_clusters
        root = self.levels[-1][0]
        root.to_root = np.arange(c, dtype=np.int64)
        for li in range(len(self.levels) - 1, 0, -1):
            for ni, parent in enumerate(self.levels[li]):
                pmaps = np.asarray(parent.maps, np.int64)
                for j, child_pos in enumerate(parent.children):
                    child = self.levels[li - 1][child_pos]
                    m = pmaps[j]
                    child.to_root = np.where(
                        m >= 0, parent.to_root[np.clip(m, 0, c - 1)], -1)
                    stats["down_internal_edges"] += 1
                    load[(li, ni)] = load.get((li, ni), 0) + c * 4
                    load[(li - 1, child_pos)] = \
                        load.get((li - 1, child_pos), 0) + c * 4
                    if self.meter is not None:
                        self.meter.add_collective(1, c * 4)
        m0 = np.full((k, c), -1, np.int64)
        for ni, node in enumerate(self.levels[0]):
            nmaps = np.asarray(node.maps, np.int64)
            for j, s in enumerate(node.children):
                m = nmaps[j]
                m0[s] = np.where(
                    m >= 0, node.to_root[np.clip(m, 0, c - 1)], -1)

        # Canonical relabel: reproduce the flat aggregator's slot ids —
        # rank root components by member count (desc), ties by the
        # minimum composed flat slot index (the flat closure's min-label
        # root, see module docstring).
        sizes = np.asarray(root.summary.sizes, np.int64)
        valid = np.asarray(root.summary.valid, bool)
        rank = np.where(valid, sizes, -1)
        flat0 = m0.reshape(-1)
        first = np.full((c,), _BIG, np.int64)
        sel = flat0 >= 0
        np.minimum.at(first, flat0[sel], np.nonzero(sel)[0])
        perm = np.lexsort((first, -rank))
        relabel = np.full((c,), -1, np.int64)
        for pos, r in enumerate(perm):
            if rank[r] > 0:
                relabel[r] = pos
        m_final = np.where(
            m0 >= 0, relabel[np.clip(m0, 0, c - 1)], -1).astype(np.int32)
        if self._prev_m is not None:
            stats["down_shard_rows"] = int(
                (m_final != self._prev_m).any(axis=1).sum())
        else:
            stats["down_shard_rows"] = k
        for ni, node in enumerate(self.levels[0]):
            load[(0, ni)] = load.get((0, ni), 0) + len(node.children) * c * 4
        self._prev_m = m_final

        perm_j = jnp.asarray(perm, jnp.int32)
        keep_j = jnp.asarray(rank[perm] > 0)
        summary = root.summary
        self._global = ddc.ClusterSet(
            contours=summary.contours[perm_j],
            counts=jnp.where(keep_j, summary.counts[perm_j], 0),
            sizes=jnp.where(keep_j, summary.sizes[perm_j], 0),
            valid=keep_j,
            overflow=summary.overflow,
        )
        self._maps = jnp.asarray(m_final)

"""Cluster tracking: stable identity, lifecycle events, and motion
analytics over the streaming serve stack (DESIGN.md §14).

Every refresh of a serve engine produces a fresh global ``ClusterSet``
with no memory of the last one.  ``ClusterTracker`` folds those
refresh-by-refresh generations into persistent *tracks*: each new
global cluster is matched against the previous generation by minimum
squared contour distance (the same ``cross_min_d2`` primitive the
aggregation tree uses, no new geometry), matched clusters keep their
track ID, and the unmatched remainder becomes lifecycle events —
birth, death, merge, split, continuation.  Per track, a bounded history
ring of (generation, centroid, size, spread) samples yields centroid
velocity, heading, spread/divergence rate, and a coarse
moving / stationary / dispersing classification.

Exactness.  The fold is a pure function of the per-generation inputs
``(batch contours, slot->global maps, global sizes)``.  Global cluster
*contours* are deliberately NOT used: the hierarchical aggregator's
root contours are re-extracted level by level and are not bit-identical
to the flat aggregator's, while the per-shard batch contours, the
canonical slot maps, and the global sizes ARE bit-identical across
stream vs dist engines and flat vs tree aggregation.  Matching
therefore runs on the *member-slot view* — global cluster ``g`` is the
set of shard contour slots mapping to it — so the same ingest sequence
yields bit-identical track histories on every engine/topology, and
across snapshot save→load→resume (tracker state rides in the mirror
manifest+npz).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ddc, geometry

EVENT_KINDS = ("birth", "death", "merge", "split", "continuation")
_KIND_CODE = {k: i for i, k in enumerate(EVENT_KINDS)}

# Motion classes (TrackView.motion).
MOTION_NEW = "new"                  # < 2 history samples, nothing to rate
MOTION_MOVING = "moving"
MOTION_STATIONARY = "stationary"
MOTION_DISPERSING = "dispersing"


@jax.jit
def _cross_d2(ca, cnta, va, cb, cntb, vb):
    # One compile per contour shape; identical inputs => identical
    # outputs on CPU, which the bit-exactness guarantees lean on.
    return ddc.cross_min_d2(ca, cnta, va, cb, cntb, vb)


@dataclasses.dataclass(frozen=True)
class TrackEvent:
    """One lifecycle transition at generation ``gen``.

    ``partner`` is the surviving track for a merge and the parent track
    for a split (else -1); ``slot`` is the global cluster slot the
    track occupies after the transition (-1 for a death).
    """

    kind: str
    gen: int
    track: int
    partner: int = -1
    slot: int = -1


@dataclasses.dataclass(frozen=True)
class TrackView:
    """Read-only per-track state + motion analytics at one generation."""

    track_id: int
    alive: bool
    slot: int                 # global cluster slot this generation, -1 if dead
    born_gen: int
    last_gen: int
    size: int                 # member points at last observation
    centroid: Tuple[float, float]
    velocity: Tuple[float, float]   # centroid delta per generation
    speed: float
    heading_deg: float        # atan2 degrees, 0 = +x, CCW positive
    spread: float             # RMS contour-vertex distance to centroid
    divergence: float         # spread delta per generation
    motion: str               # MOTION_* classification
    hits: int                 # history samples currently in the ring


@dataclasses.dataclass(frozen=True)
class TrackSnapshot:
    """The tracking read view published alongside the query tier's
    ``Snapshot`` — same ``version``, so a reader pairing ``labels()``
    with ``tracks()`` sees one consistent generation."""

    version: int
    epoch: int
    generation: int
    next_track_id: int
    births: int
    deaths: int
    merges: int
    splits: int
    continuations: int
    tracks: Tuple[TrackView, ...]       # all tracks ever, by ascending ID
    events: Tuple[TrackEvent, ...]      # bounded recent-event ring

    @property
    def alive(self) -> Tuple[TrackView, ...]:
        return tuple(t for t in self.tracks if t.alive)

    def track(self, track_id: int) -> Optional[TrackView]:
        for t in self.tracks:
            if t.track_id == track_id:
                return t
        return None


@dataclasses.dataclass
class _Track:
    tid: int
    slot: int
    born: int
    last: int
    alive: bool
    # History ring entries: (gen, cx, cy, size, spread), oldest first.
    hist: List[Tuple[float, float, float, float, float]]


class ClusterTracker:
    """Stable-identity fold over refresh generations (DESIGN.md §14).

    ``update`` is called by the serve engines once per *tracked*
    refresh with the post-merge batch contours, slot->global maps, and
    global ClusterSet; everything else is derived read-only state.
    """

    def __init__(self, cfg, history: int = 16, min_overlap: float = 0.0,
                 event_limit: int = 4096):
        if history < 2:
            raise ValueError(f"track history must be >= 2, got {history}")
        if not 0.0 <= min_overlap < 1.0:
            raise ValueError(
                f"match_min_overlap must be in [0, 1), got {min_overlap}")
        self.cfg = cfg
        self.history = int(history)
        self.min_overlap = float(min_overlap)
        self.event_limit = int(event_limit)
        # Motion thresholds scale with eps: a cluster moving less than a
        # quarter-eps per generation reads as stationary.
        self.speed_floor = 0.25 * float(cfg.eps)
        self.div_floor = 0.25 * float(cfg.eps)

        self.generation = 0
        self.next_track_id = 0        # monotone; IDs are never reused
        self.event_counts: Dict[str, int] = {k: 0 for k in EVENT_KINDS}
        self._tracks: Dict[int, _Track] = {}
        self._events: List[TrackEvent] = []
        self._prev: Optional[dict] = None   # last observed generation
        # Timing telemetry — excluded from serialized/compared state.
        self.last_update_ms = 0.0
        self.update_ms_total = 0.0

    # -- the fold ----------------------------------------------------------

    def update(self, batch, maps, global_cs) -> int:
        """Fold one merged generation; returns the new generation."""
        t0 = time.monotonic()
        c = int(self.cfg.max_clusters)
        v = int(self.cfg.max_verts)
        contours = np.asarray(batch.contours, np.float32).reshape(-1, v, 2)
        counts = np.asarray(batch.counts, np.int32).reshape(-1)
        gmap = np.asarray(maps, np.int64).reshape(-1)
        mvalid = gmap >= 0
        gsizes = np.asarray(global_cs.sizes, np.int64).reshape(-1)[:c]
        gvalid = np.asarray(global_cs.valid, bool).reshape(-1)[:c]

        self.generation += 1
        gen = self.generation
        cur_slots = [int(h) for h in np.nonzero(gvalid)[0]]
        feats = _slot_features(contours, counts, gmap, cur_slots)
        slot_track = np.full(c, -1, np.int64)
        prev = self._prev

        if prev is None or not prev["slots"]:
            for h in cur_slots:
                self._observe(self._new_track(gen, h), h, gen, gsizes, feats,
                              slot_track)
                self._emit("birth", gen, slot_track[h], slot=h)
        else:
            dg = self._global_d2(prev, contours, counts, mvalid, gmap,
                                 cur_slots)
            r = float(self.cfg.merge_radius)
            thr = r * r * (1.0 - self.min_overlap)
            # Deterministic target per previous track: nearest current
            # slot within the gate, ties broken toward the lowest slot.
            target = {}
            for p in prev["slots"]:
                best = min(cur_slots, key=lambda h: (dg[p, h], h),
                           default=None)
                target[p] = (best if best is not None
                             and dg[p, best] <= thr else -1)
            for h in cur_slots:
                group = [p for p in prev["slots"] if target[p] == h]
                if group:
                    # Survivor: largest previous cluster, ties toward the
                    # older (lower) track ID; the rest merged into it.
                    surv = max(group, key=lambda p: (
                        prev["gsizes"][p], -prev["slot_track"][p]))
                    tid = int(prev["slot_track"][surv])
                    self._observe(tid, h, gen, gsizes, feats, slot_track)
                    self._emit("continuation", gen, tid, slot=h)
                    for p in group:
                        if p != surv:
                            dead = int(prev["slot_track"][p])
                            self._kill(dead)
                            self._emit("merge", gen, dead, partner=tid,
                                       slot=h)
                else:
                    near = [p for p in prev["slots"] if dg[p, h] <= thr]
                    tid = self._new_track(gen, h)
                    self._observe(tid, h, gen, gsizes, feats, slot_track)
                    if near:
                        # Split: fragment of the closest matched parent.
                        parent = min(near, key=lambda p: (
                            dg[p, h], prev["slot_track"][p]))
                        self._emit("split", gen, tid,
                                   partner=int(prev["slot_track"][parent]),
                                   slot=h)
                    else:
                        self._emit("birth", gen, tid, slot=h)
            for p in prev["slots"]:
                if target[p] == -1:
                    dead = int(prev["slot_track"][p])
                    self._kill(dead)
                    self._emit("death", gen, dead)

        self._prev = dict(contours=contours.copy(), counts=counts.copy(),
                          mvalid=mvalid.copy(), gmap=gmap.copy(),
                          gsizes=gsizes.copy(), slot_track=slot_track,
                          slots=[h for h in cur_slots if slot_track[h] >= 0])
        self.last_update_ms = (time.monotonic() - t0) * 1e3
        self.update_ms_total += self.last_update_ms
        return gen

    def _global_d2(self, prev, contours, counts, mvalid, gmap, cur_slots):
        """Member-slot distance: d2[g, h] = min over (previous members
        of g) x (current members of h) of ``cross_min_d2``."""
        d2 = np.asarray(_cross_d2(
            jnp.asarray(prev["contours"]), jnp.asarray(prev["counts"]),
            jnp.asarray(prev["mvalid"]), jnp.asarray(contours),
            jnp.asarray(counts), jnp.asarray(mvalid)), np.float64)
        c = int(self.cfg.max_clusters)
        dg = np.full((c, c), float(geometry.BIG), np.float64)
        for p in prev["slots"]:
            rows = d2[prev["gmap"] == p]
            for h in cur_slots:
                cols = gmap == h
                if rows.size and cols.any():
                    dg[p, h] = float(rows[:, cols].min())
        return dg

    def _new_track(self, gen: int, slot: int) -> int:
        tid = self.next_track_id
        self.next_track_id += 1
        self._tracks[tid] = _Track(tid=tid, slot=slot, born=gen, last=gen,
                                   alive=True, hist=[])
        return tid

    def _observe(self, tid, slot, gen, gsizes, feats, slot_track) -> None:
        t = self._tracks[tid]
        cx, cy, spread = feats[slot]
        t.slot, t.last, t.alive = int(slot), gen, True
        t.hist.append((float(gen), cx, cy, float(gsizes[slot]), spread))
        if len(t.hist) > self.history:
            del t.hist[: len(t.hist) - self.history]
        slot_track[slot] = tid

    def _kill(self, tid: int) -> None:
        t = self._tracks[tid]
        t.alive, t.slot = False, -1

    def _emit(self, kind, gen, track, partner=-1, slot=-1) -> None:
        self._events.append(TrackEvent(kind, gen, int(track), int(partner),
                                       int(slot)))
        if len(self._events) > self.event_limit:
            del self._events[: len(self._events) - self.event_limit]
        self.event_counts[kind] += 1

    # -- read view ---------------------------------------------------------

    def snapshot(self, version: int = 0, epoch: int = 0) -> TrackSnapshot:
        ec = self.event_counts
        return TrackSnapshot(
            version=version, epoch=epoch, generation=self.generation,
            next_track_id=self.next_track_id, births=ec["birth"],
            deaths=ec["death"], merges=ec["merge"], splits=ec["split"],
            continuations=ec["continuation"],
            tracks=tuple(self._view(self._tracks[tid])
                         for tid in sorted(self._tracks)),
            events=tuple(self._events))

    def _view(self, t: _Track) -> TrackView:
        g1, cx, cy, size, spread = t.hist[-1]
        vx = vy = speed = heading = div = 0.0
        if len(t.hist) >= 2:
            g0, x0, y0, _, sp0 = t.hist[0]
            dt = g1 - g0
            vx, vy = (cx - x0) / dt, (cy - y0) / dt
            speed = float(np.hypot(vx, vy))
            heading = float(np.degrees(np.arctan2(vy, vx)))
            div = (spread - sp0) / dt
            if div > self.div_floor:
                motion = MOTION_DISPERSING
            elif speed > self.speed_floor:
                motion = MOTION_MOVING
            else:
                motion = MOTION_STATIONARY
        else:
            motion = MOTION_NEW
        return TrackView(
            track_id=t.tid, alive=t.alive, slot=t.slot, born_gen=t.born,
            last_gen=t.last, size=int(size), centroid=(cx, cy),
            velocity=(vx, vy), speed=speed, heading_deg=heading,
            spread=spread, divergence=div, motion=motion, hits=len(t.hist))

    # -- snapshot save/restore (manifest + npz, DESIGN.md §14) -------------

    def state_arrays(self) -> Dict[str, np.ndarray]:
        tids = sorted(self._tracks)
        nt, h = len(tids), self.history
        hist = np.zeros((nt, h, 5), np.float64)
        hlen = np.zeros(nt, np.int64)
        meta = np.zeros((nt, 5), np.int64)   # tid, slot, born, last, alive
        for i, tid in enumerate(tids):
            t = self._tracks[tid]
            meta[i] = (t.tid, t.slot, t.born, t.last, int(t.alive))
            hlen[i] = len(t.hist)
            if t.hist:
                hist[i, : len(t.hist)] = np.asarray(t.hist, np.float64)
        events = np.asarray(
            [[_KIND_CODE[e.kind], e.gen, e.track, e.partner, e.slot]
             for e in self._events], np.int64).reshape(-1, 5)
        out = {"trk_meta": meta, "trk_hist": hist, "trk_hlen": hlen,
               "trk_events": events}
        if self._prev is not None:
            p = self._prev
            out |= {"trk_prev_contours": p["contours"],
                    "trk_prev_counts": p["counts"],
                    "trk_prev_mvalid": p["mvalid"],
                    "trk_prev_gmap": p["gmap"],
                    "trk_prev_gsizes": p["gsizes"],
                    "trk_prev_slot_track": p["slot_track"]}
        return out

    def state_manifest(self) -> dict:
        return {"generation": self.generation,
                "next_track_id": self.next_track_id,
                "history": self.history,
                "min_overlap": self.min_overlap,
                "event_limit": self.event_limit,
                "event_counts": dict(self.event_counts),
                "has_prev": self._prev is not None}

    def state_dict(self) -> Tuple[Dict[str, np.ndarray], dict]:
        return self.state_arrays(), self.state_manifest()

    def load_state(self, arrays, manifest: dict) -> None:
        self.generation = int(manifest["generation"])
        self.next_track_id = int(manifest["next_track_id"])
        self.history = int(manifest["history"])
        self.min_overlap = float(manifest["min_overlap"])
        self.event_limit = int(manifest["event_limit"])
        self.event_counts = {k: int(manifest["event_counts"].get(k, 0))
                             for k in EVENT_KINDS}
        meta = np.asarray(arrays["trk_meta"], np.int64).reshape(-1, 5)
        hist = np.asarray(arrays["trk_hist"], np.float64)
        hlen = np.asarray(arrays["trk_hlen"], np.int64)
        self._tracks = {}
        for i in range(len(meta)):
            tid, slot, born, last, alive = (int(x) for x in meta[i])
            self._tracks[tid] = _Track(
                tid=tid, slot=slot, born=born, last=last, alive=bool(alive),
                hist=[tuple(float(x) for x in row)
                      for row in hist[i, : hlen[i]]])
        self._events = [
            TrackEvent(EVENT_KINDS[int(k)], int(g), int(t), int(p), int(s))
            for k, g, t, p, s in
            np.asarray(arrays["trk_events"], np.int64).reshape(-1, 5)]
        if manifest.get("has_prev"):
            slot_track = np.asarray(arrays["trk_prev_slot_track"], np.int64)
            self._prev = dict(
                contours=np.asarray(arrays["trk_prev_contours"], np.float32),
                counts=np.asarray(arrays["trk_prev_counts"], np.int32),
                mvalid=np.asarray(arrays["trk_prev_mvalid"], bool),
                gmap=np.asarray(arrays["trk_prev_gmap"], np.int64),
                gsizes=np.asarray(arrays["trk_prev_gsizes"], np.int64),
                slot_track=slot_track,
                slots=[int(h) for h in np.nonzero(slot_track >= 0)[0]])
        else:
            self._prev = None


def _slot_features(contours, counts, gmap, cur_slots):
    """Pooled centroid + RMS spread per global slot, from the member
    shard contours' valid vertices in ascending flat-slot order (the
    one vertex set that is bit-identical on every engine/topology)."""
    feats = {}
    for h in cur_slots:
        members = np.nonzero(gmap == h)[0]
        verts = [contours[a, : counts[a]].astype(np.float64)
                 for a in members if counts[a] > 0]
        if not verts:
            feats[h] = (0.0, 0.0, 0.0)
            continue
        allv = np.concatenate(verts)
        cx, cy = (float(x) for x in allv.mean(axis=0))
        spread = float(np.sqrt(
            ((allv - (cx, cy)) ** 2).sum(axis=1).mean()))
        feats[h] = (cx, cy, spread)
    return feats


def play(model, frames, window: Optional[int] = None):
    """Drive a stream/dist ``DDC`` model through a trajectory: one
    refresh per frame (so tracker generation == frame step), points
    block-partitioned over shards, ``t=step`` timestamps, and — when
    ``window`` is given — sliding-window eviction of frames older than
    ``window`` steps.  Returns the final ``TrackSnapshot``."""
    k = model.config.shards
    for step, frame in enumerate(frames):
        for shard, part in enumerate(np.array_split(frame, k)):
            if len(part):
                model.partial_fit(shard, part,
                                  t=float(step) * np.ones(len(part)))
        if window is not None and step + 1 > window:
            model.expire(float(step - window + 1))
        model.service.refresh()
    return model.tracks()

"""Device-resident streaming DDC data plane (the ``dist`` backend).

``ClusterService`` drives K *logical* shards from the host: every ring
buffer lives on the default device and the phase-2 exchange is a metered
model.  This module keeps the exact same control plane
(``ShardControlPlane``: slot choice, eviction, TTL stamps, bbox routing,
dirty tracking) but pins each shard's data to its own mesh device and
makes the exchange real (DESIGN.md §10):

* **Pinned buffers** — points/mask/dense/glabels are stacked (K, …)
  arrays sharded ``P("shards", …)`` over a K-device host mesh: shard
  ``i``'s rows live on device ``i`` and never leave it.
* **shard_map ingest / eviction / phase 1** — the ring scatter, the
  kill-mask, and dirty-shard ``local_phase`` all run as per-lane bodies
  inside ``shard_map`` over the mesh axis.  The host mirrors still pick
  the slots/victims (a pure function of the call sequence), so the
  per-lane kernels stay single static-shape scatters; a per-lane
  ``lax.cond`` on the dirty flag means clean lanes do no phase-1 work.
* **Delta-ClusterSet exchange** — the ONLY payload that crosses the mesh
  axis per refresh: each dirty lane's fixed-size ClusterSet (contours +
  counts + sizes + valid + overflow, ``DDCConfig.buffer_bytes()`` each)
  moves device→aggregator, and each lane's (C,) slot-map row moves back
  (K·C·4 bytes total).  The aggregator (the control plane's ClusterSet
  mirror + cached pair-d2 matrix) patches only the dirty rows/columns —
  ``ddc.merge_delta``, the same code path as the host-driven engine, so
  the result is bit-identical to it (and to batch ``ddc_host``).  The
  CommMeter counters are therefore *real* axis-crossing bytes here, not
  a model: |dirty|·B + K·C·4 per delta refresh, K·B + K·C·4 for a full
  re-merge (which genuinely re-ships every lane's ClusterSet).
* **Routed queries** — a query chunk is broadcast only conceptually: each
  lane whose ε-dilated bbox could contain a neighbour (host bbox
  mirrors) computes its local (best-d2, label) per query under a
  per-lane ``lax.cond``; skipped lanes return the identity.  The host
  folds lanes in ascending shard order with a strict ``<`` so ties
  resolve exactly like the flat argmin of the host-driven engine.

Phase-1 numerics are bit-identical between the two data planes (the
per-lane ``local_phase`` is the same XLA program as the per-shard jit),
so labels AND the cached pair-d2 matrix match the ``stream`` engine
bit-for-bit — asserted per layout × shard count by
tests/_dist_backend_script.py.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.core import ddc
from repro.launch import mesh as mesh_mod
from repro.parallel import compress
from repro.serve import faults as faults_mod
from repro.serve.cluster_service import (
    ShardControlPlane, StreamConfig, _cs_from_host, _set_row,
)

AXIS = "shards"


def require_devices(shards: int) -> None:
    """The dist data plane pins one shard per device; fail with the fix
    spelled out instead of an opaque mesh error."""
    ndev = len(jax.devices())
    if ndev < shards:
        raise ValueError(
            f"backend='dist' pins one shard per device but jax sees "
            f"{ndev} device(s) for shards={shards}; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={shards} before jax "
            f"initialises (or lower shards)")


@functools.lru_cache(maxsize=None)
def _data_plane(mesh, cfg: ddc.DDCConfig, cap: int, bmax: int, qmax: int):
    """Build (once per (mesh, config, shapes)) the jitted shard_map
    kernels of the device data plane.  Every body sees its lane's
    (1, …) block; donation keeps ring updates in place on each device.
    """
    s1, s2, s3 = P(AXIS), P(AXIS, None), P(AXIS, None, None)
    cs_spec = ddc.ClusterSet(
        contours=P(AXIS, None, None, None), counts=s2, sizes=s2,
        valid=s2, overflow=s1)
    empty_cs = ddc.empty_clusterset(cfg)

    def smap(f, in_specs, out_specs):
        return compat.shard_map(f, mesh=mesh, in_specs=in_specs,
                                out_specs=out_specs, check_vma=False)

    def lane_append(pts, mask, chunk, idx, nb):
        wvalid = jnp.arange(chunk.shape[1]) < nb[0]
        safe = jnp.where(wvalid, idx[0], cap)        # invalid rows drop
        p = pts[0].at[safe].set(chunk[0], mode="drop")
        m = mask[0].at[safe].set(True, mode="drop")
        return p[None], m[None]

    append = jax.jit(
        smap(lane_append, (s3, s2, s3, s2, s1), (s3, s2)),
        donate_argnums=(0, 1))

    def lane_kill(mask, kill):
        return (mask[0] & ~kill[0])[None]

    kill = jax.jit(smap(lane_kill, (s2, s2), s2), donate_argnums=(0,))

    def lane_restore(pts, mask, npts, nmask, flag):
        # Recovery upload: the flagged lane's buffers are replaced
        # wholesale (journal-replayed state); other lanes untouched.
        p = jnp.where(flag[0], npts[0], pts[0])
        m = jnp.where(flag[0], nmask[0], mask[0])
        return p[None], m[None]

    restore = jax.jit(smap(lane_restore, (s3, s2, s3, s2, s1), (s3, s2)),
                      donate_argnums=(0, 1))

    def lane_refresh(pts, mask, dense, cs, dirty):
        p, m = pts[0], mask[0]
        old = dense[0], jax.tree.map(lambda x: x[0], cs)

        def recompute(_):
            def nonempty(_):
                return ddc.local_phase(p, m, cfg)

            def emptied(_):
                # Emptied shard: the cached all-invalid ClusterSet (the
                # PR 2 empty-shard fix, lane-local edition).
                return jnp.full((cap,), -1, jnp.int32), empty_cs

            return jax.lax.cond(jnp.any(m), nonempty, emptied, None)

        nd, ncs = jax.lax.cond(dirty[0], recompute, lambda _: old, None)
        return nd[None], jax.tree.map(lambda x: x[None], ncs)

    refresh = jax.jit(
        smap(lane_refresh, (s3, s2, s2, cs_spec, s1), (s2, cs_spec)),
        donate_argnums=(2, 3))

    def lane_labels(dense, mask, maps):
        d, m, mp = dense[0], mask[0], maps[0]
        return jnp.where(m & (d >= 0), mp[jnp.clip(d, 0)], -1)[None]

    labels = jax.jit(smap(lane_labels, (s2, s2, s2), s2))

    def lane_query(q, pts, mask, glab, scan):
        def compute(_):
            d2 = jnp.sum((q[:, None, :] - pts[0][None, :, :]) ** 2, axis=-1)
            ok = mask[0] & (glab[0] >= 0)
            d2 = jnp.where(ok[None, :], d2, jnp.float32(1e30))
            j = jnp.argmin(d2, axis=1)
            return d2[jnp.arange(qmax), j], glab[0][j]

        def skipped(_):
            return (jnp.full((qmax,), 1e30, jnp.float32),
                    jnp.full((qmax,), -1, jnp.int32))

        bd, bl = jax.lax.cond(scan[0], compute, skipped, None)
        return bd[None], bl[None]

    query = jax.jit(smap(lane_query, (P(None, None), s3, s2, s2, s1),
                         (s2, s2)))

    return {"append": append, "kill": kill, "restore": restore,
            "refresh": refresh, "labels": labels, "query": query}


class DistClusterService(ShardControlPlane):
    """Streaming DDC engine whose per-shard state is pinned to its own
    mesh device (see module doc).  Same public surface as
    ``ClusterService``; the difference is *where* the data plane runs
    and that the delta-ClusterSet exchange bytes are real transfers.
    """

    flavor = "dist"

    def __init__(self, scfg: StreamConfig, meter: ddc.CommMeter | None = None,
                 faults: faults_mod.FaultPlan | None = None):
        super().__init__(scfg, meter, faults=faults)
        k, cap = scfg.shards, scfg.capacity
        require_devices(k)
        self.mesh = mesh_mod.make_host_mesh(k, axis=AXIS)
        self._fns = _data_plane(self.mesh, self.cfg, cap,
                                scfg.max_batch, scfg.max_queries)
        self._sh1 = NamedSharding(self.mesh, P(AXIS))
        self._sh2 = NamedSharding(self.mesh, P(AXIS, None))
        self._sh3 = NamedSharding(self.mesh, P(AXIS, None, None))
        self._zero_pieces: dict = {}   # (operand, lane) -> zero piece
        self._pts = jax.device_put(np.zeros((k, cap, 2), np.float32), self._sh3)
        self._mask = jax.device_put(np.zeros((k, cap), bool), self._sh2)
        self._dense = jax.device_put(np.full((k, cap), -1, np.int32), self._sh2)
        self._glabels = jax.device_put(
            np.full((k, cap), -1, np.int32), self._sh2)
        # Device-side stacked ClusterSets: lane i's row is its last
        # phase-1 output, resident on device i (clean lanes carry it
        # forward through the per-lane cond without recompute).
        self._batch_dev = jax.tree.map(
            lambda x: jax.device_put(
                np.broadcast_to(np.asarray(x)[None],
                                (k,) + np.asarray(x).shape).copy(),
                NamedSharding(self.mesh,
                              P(AXIS, *([None] * np.asarray(x).ndim)))),
            ddc.empty_clusterset(self.cfg))

    # -- data plane ---------------------------------------------------------

    def _lane_stage(self, name: str, sharding, payload: np.ndarray,
                    shard: int):
        """A (K, …) sharded operand whose lane ``shard`` holds
        ``payload`` and every other lane holds zeros — assembled from
        per-device pieces so ONLY the target lane's payload crosses the
        host→device boundary.  The zero pieces are device-resident and
        cached per (operand, lane); that is safe because none of the
        staged operands are donated by the data-plane kernels."""
        devices = list(self.mesh.devices.flat)
        shape = (len(devices),) + payload.shape
        pieces = []
        for i, dev in enumerate(devices):
            if i == shard:
                pieces.append(jax.device_put(payload[None], dev))
                continue
            key = (name, i)
            zero = self._zero_pieces.get(key)
            if zero is None:
                zero = jax.device_put(
                    np.zeros((1,) + payload.shape, payload.dtype), dev)
                self._zero_pieces[key] = zero
            pieces.append(zero)
        return jax.make_array_from_single_device_arrays(
            shape, sharding, pieces)

    def _append_chunk(self, shard, chunk, idx, nb) -> None:
        self._pts, self._mask = self._fns["append"](
            self._pts, self._mask,
            self._lane_stage("chunk", self._sh3,
                             np.asarray(chunk, np.float32), shard),
            self._lane_stage("idx", self._sh2,
                             np.asarray(idx, np.int32), shard),
            self._lane_stage("nb", self._sh1,
                             np.asarray(nb, np.int32), shard))

    def _kill_device(self, shard, kill) -> None:
        self._mask = self._fns["kill"](
            self._mask,
            self._lane_stage("kill", self._sh2,
                             np.asarray(kill, bool), shard))

    def _restore_lane(self, shard, pts, live) -> None:
        flags = np.zeros((self.scfg.shards,), bool)
        flags[shard] = True
        self._pts, self._mask = self._fns["restore"](
            self._pts, self._mask,
            self._lane_stage("rpts", self._sh3,
                             np.asarray(pts, np.float32), shard),
            self._lane_stage("rmask", self._sh2,
                             np.asarray(live, bool), shard),
            jax.device_put(flags, self._sh1))

    # -- refresh (lane-local phase 1 + delta exchange + merge) --------------

    def refresh(self, mode: str | None = None, force: bool = False,
                track: bool | None = None):
        """Re-cluster dirty lanes on their own devices, exchange ONLY
        their delta ClusterSets across the axis, and re-close the cached
        merge.  Bit-identical to ``ClusterService.refresh`` on the same
        call sequence (and to a from-scratch re-merge), including the
        tracking fold (``track`` as in ``_track_update``)."""
        mode = mode or self.scfg.merge_mode
        k = self.scfg.shards
        dirty = sorted(self._dirty - self._quarantined.keys())
        if not dirty and self._global is not None and not force:
            return self._global

        if dirty:
            flags = np.zeros((k,), bool)
            flags[dirty] = True
            self._dense, self._batch_dev = self._fns["refresh"](
                self._pts, self._mask, self._dense, self._batch_dev,
                jax.device_put(flags, self._sh1))

        # The axis crossing: dirty lanes' ClusterSets move to the
        # aggregator mirror (a delta refresh ships just those in ONE
        # gathered fetch; a full re-merge genuinely re-ships every
        # lane's).  ``up_bytes`` is measured off the fetched arrays
        # themselves, so the meter reports what actually crossed — the
        # bench's dist-vs-stream byte equality is an observation.  Every
        # payload then passes the control plane's delta exchange (fault
        # seam, validation gate, retry, epoch fence) before it may touch
        # the mirror; a retry is a genuine lane re-send, metered too.
        up_bytes = [0]

        def row_payload(rows, j):
            return {"contours": rows.contours[j], "counts": rows.counts[j],
                    "sizes": rows.sizes[j], "valid": rows.valid[j],
                    "overflow": rows.overflow[j]}

        def refetch(i):
            row = jax.device_get(jax.tree.map(
                lambda x: x[i], self._batch_dev))
            up_bytes[0] += compress.pytree_wire_bytes(row)
            return {"contours": row.contours, "counts": row.counts,
                    "sizes": row.sizes, "valid": row.valid,
                    "overflow": row.overflow}

        # The cached aggregation that makes a delta fetch sufficient is
        # the flat pair-d2 matrix OR the built hierarchy (whose per-node
        # caches play the same role, DESIGN §13).
        delta_ready = (self._hier.ready if self._hier is not None
                       else self._pair_d2 is not None)
        if mode == "delta" and delta_ready:
            payloads = {}
            if dirty:
                rows = jax.device_get(jax.tree.map(
                    lambda x: x[jnp.asarray(dirty)], self._batch_dev))
                up_bytes[0] += compress.pytree_wire_bytes(rows)
                payloads = {i: row_payload(rows, j)
                            for j, i in enumerate(dirty)}

            def produce(i, attempt):
                if attempt == 0 and i in payloads:
                    return payloads[i], None
                return refetch(i), None

            staged = self._exchange_deltas(dirty, produce)
        else:
            # All K lanes re-ship anyway: one bulk fetch; the dirty
            # lanes' payloads still pass the gate, the clean lanes'
            # mirror rows are refreshed in place (bit-identical values).
            fetched = jax.device_get(self._batch_dev)
            up_bytes[0] += compress.pytree_wire_bytes(fetched)
            payloads = {i: row_payload(fetched, i) for i in dirty}

            def produce(i, attempt):
                if attempt == 0:
                    return payloads[i], None
                return refetch(i), None

            staged = self._exchange_deltas(dirty, produce)
            if not self._quarantined and set(staged) == set(dirty):
                self._batch = ddc.ClusterSet(
                    *[jnp.asarray(x) for x in fetched])
                self._local = [jax.tree.map(lambda x, i=i: x[i], self._batch)
                               for i in range(k)]
            else:
                for i in range(k):
                    if i in self._quarantined or i in dirty:
                        continue    # dirty rows went through the gate
                    cs = _cs_from_host(row_payload(fetched, i))
                    self._local[i] = cs
                    self._batch = _set_row(self._batch, cs, i)

        self._merge_and_meter(staged, mode, up_bytes=up_bytes[0])
        # Map rows back down, lane-local relabel; again metered from the
        # array actually pushed.
        maps_np = np.asarray(self._maps, np.int32)
        self._meter_maps_down(maps_np.nbytes)
        maps_dev = jax.device_put(maps_np, self._sh2)
        self._glabels = self._fns["labels"](self._dense, self._mask, maps_dev)
        self._dirty -= set(staged)
        self._track_update(track)
        self.refreshes += 1
        self._publish_snapshot()
        return self._global

    # -- read path ----------------------------------------------------------

    def _read_view(self):
        # The pinned buffers are donated by append/kill/restore, so the
        # snapshot must own genuine copies: fetch to host, re-put on the
        # default device (where the snapshot query kernel runs anyway).
        return (jnp.asarray(np.asarray(self._pts)),
                jnp.asarray(np.asarray(self._mask)),
                jnp.asarray(np.asarray(self._glabels)))

    def _query_sync(self, q: np.ndarray):
        """Lane-local (best-d2, label) per bbox-routed shard, folded on
        the host in ascending shard order with a strict ``<`` so ties
        match the host-driven engine's flat argmin."""
        qmax = self.scfg.max_queries
        k = self.scfg.shards
        eps2 = np.float32(self.cfg.eps) * np.float32(self.cfg.eps)
        degraded = False
        scanned: set = set()
        out = np.empty((len(q),), np.int32)
        for off in range(0, len(q), qmax):
            chunk = q[off:off + qmax]
            nq = len(chunk)
            scan = self._route(chunk)
            degraded |= self._route_degraded
            scanned.update(int(s) for s in np.nonzero(scan)[0])
            if not scan.any():
                out[off:off + nq] = -1
                continue
            if nq < qmax:
                chunk = np.pad(chunk, ((0, qmax - nq), (0, 0)))
            bd, bl = self._fns["query"](
                jnp.asarray(chunk), self._pts, self._mask, self._glabels,
                jax.device_put(scan, self._sh1))
            bd, bl = np.asarray(bd), np.asarray(bl)
            best = np.full((qmax,), 1e30, np.float32)
            lab = np.full((qmax,), -1, np.int32)
            for s in range(k):          # ascending + strict <: ties go to
                upd = bd[s] < best      # the lowest (shard, slot), like
                best = np.where(upd, bd[s], best)   # the flat argmin
                lab = np.where(upd, bl[s], lab)
            out[off:off + nq] = np.where(best <= eps2, lab, -1)[:nq]
        return out, degraded, scanned

    # -- introspection -------------------------------------------------------

    def _live_buffers(self):
        return (np.asarray(self._pts), np.asarray(self._mask),
                np.asarray(self._glabels))

    # -- snapshot / restore --------------------------------------------------

    def state_dict(self) -> Tuple[dict, dict]:
        """Same array/manifest layout as ``ClusterService.state_dict``,
        so snapshots are portable between the two data planes."""
        arrays = {
            "pts": np.asarray(self._pts),
            "mask": np.asarray(self._mask),
            "dense": np.asarray(self._dense),
        } | self._mirror_arrays()
        return arrays, self._mirror_manifest()

    @classmethod
    def from_state(cls, scfg: StreamConfig, arrays: dict, manifest: dict,
                   meter: ddc.CommMeter | None = None,
                   faults: faults_mod.FaultPlan | None = None
                   ) -> "DistClusterService":
        svc = cls(scfg, meter=meter, faults=faults)
        svc._pts = jax.device_put(
            np.asarray(arrays["pts"], np.float32), svc._sh3)
        svc._mask = jax.device_put(np.asarray(arrays["mask"], bool), svc._sh2)
        svc._dense = jax.device_put(
            np.asarray(arrays["dense"], np.int32), svc._sh2)
        svc._restore_mirrors(arrays, manifest)
        svc._restore_batch(arrays)
        svc._batch_dev = jax.tree.map(
            lambda x: jax.device_put(
                np.asarray(x),
                NamedSharding(svc.mesh, P(AXIS, *([None] * (x.ndim - 1))))),
            svc._batch)
        if svc._restore_global(arrays, manifest):
            maps_dev = jax.device_put(
                np.asarray(svc._maps, np.int32), svc._sh2)
            svc._glabels = svc._fns["labels"](svc._dense, svc._mask, maps_dev)
            svc._publish_snapshot()
        return svc

"""Serving substrate: prefill + decode step builders, batched requests.

decode_step is the latency path: one token per call against the KV/SSM
cache (sharded per parallel/sharding.py: batch over DP, head-dim /
latent-rank / SSM-heads over 'model').  The cache is donated so decode
is in-place on device.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.parallel import api as par


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int
    window: Any = "cfg"       # "cfg" or explicit int/None (long-context cells)
    param_dtype: str = "bfloat16"


def build_prefill(cfg: ModelConfig, scfg: ServeConfig, pctx: par.ParallelCtx):
    def prefill_fn(params, tokens, prefix=None, frames=None):
        with par.use(pctx):
            return T.prefill(
                cfg, params, tokens, prefix=prefix, frames=frames,
                max_len=scfg.max_len, window=scfg.window,
            )

    return prefill_fn


def build_decode(cfg: ModelConfig, scfg: ServeConfig, pctx: par.ParallelCtx):
    def decode_fn(params, token, cache, pos):
        with par.use(pctx):
            return T.decode_step(cfg, params, token, cache, pos,
                                 window=scfg.window)

    return decode_fn


def greedy_generate(cfg, params, prompt, steps: int, scfg: ServeConfig,
                    pctx: par.ParallelCtx, prefix=None, frames=None,
                    temperature: float = 0.0, key=None):
    """Reference generation loop (host-driven) used by examples/tests."""
    prefill_fn = jax.jit(build_prefill(cfg, scfg, pctx), static_argnames=())
    decode_fn = jax.jit(build_decode(cfg, scfg, pctx))
    logits, cache, pos = prefill_fn(params, prompt, prefix, frames)
    toks = []
    tok = _sample(logits, temperature, key, cfg.vocab)
    toks.append(tok)
    for i in range(steps - 1):
        logits, cache = decode_fn(params, tok[:, None], cache, jnp.asarray(pos + i))
        if key is not None:
            key = jax.random.fold_in(key, i)
        tok = _sample(logits, temperature, key, cfg.vocab)
        toks.append(tok)
    return jnp.stack(toks, axis=1)


def _sample(logits, temperature, key, vocab):
    logits = logits[..., :vocab]
    if temperature <= 0.0 or key is None:
        return jnp.argmax(logits, axis=-1)
    return jax.random.categorical(key, logits / temperature, axis=-1)

"""Parameter / cache / optimizer-state sharding rules.

Maps every param-tree leaf to a PartitionSpec by its path:

  TP  ('model'):  attention projections, MLP in/out, expert dims (EP),
                  vocab (embed & head).
  FSDP ('data'):  with ``fsdp=True``, each leaf additionally shards its
                  largest still-unsharded divisible dim over 'data'
                  (ZeRO-3: params *and* optimizer state; the backward
                  all-gathers re-materialise full params per layer).

Divisibility-aware: a dim that doesn't divide the mesh axis stays
replicated (e.g. 40 heads on a 16-lane model axis) rather than relying
on GSPMD padding.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.parallel.api import ParallelCtx


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, str):
        return mesh.shape.get(axis, 1)
    return int(np.prod([mesh.shape.get(a, 1) for a in axis]))


def _path_strs(path) -> tuple[str, ...]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return tuple(out)


def _trailing_spec(path: tuple[str, ...], ndim_unstacked: int,
                   moe_impl: str = "epsum") -> tuple[Any, ...]:
    """TP spec over the leaf's *unstacked* trailing dims."""
    name = path[-1]
    in_ffn = "ffn" in path
    in_mixer = "mixer" in path or "cross" in path
    shared = "shared" in path

    if name in ("embed", "lm_head"):
        return ("model", None)
    if in_ffn and not shared:
        if name == "router":
            return (None, None)
        if ndim_unstacked == 3:           # MoE expert weights (E, d, f)
            if moe_impl == "a2a":
                # Fully sharded, never gathered: experts over 'data',
                # expert-FFN dim over 'model' — matches the a2a island.
                if name in ("w1", "w3"):
                    return ("data", None, "model")
                return ("data", "model", None)
            return ("model", None, None)  # expert parallel — matches island
        if name in ("w1", "w3"):
            return (None, "model")
        if name == "w2":
            return ("model", None)
    if in_ffn and shared:
        return (None, "model") if name in ("w1", "w3") else ("model", None)
    if in_mixer:
        if name in ("wq", "wk", "wv", "w_uq", "w_uk", "w_uv", "w_in"):
            return (None, "model")
        if name in ("wo", "w_out"):
            return ("model", None)
        # w_dq / w_dkv / conv / a_log / dt_bias / d_skip / norms
        return (None,) * ndim_unstacked
    return (None,) * ndim_unstacked


def spec_for(path: tuple[str, ...], shape: tuple[int, ...], pctx: ParallelCtx) -> P:
    mesh = pctx.mesh
    if mesh is None:
        return P()
    ndim = len(shape)
    # Leaves under "blocks"/"encoder" carry one stacked leading group dim.
    n_stack = 1 if ("blocks" in path or "encoder" in path) else 0
    trailing = _trailing_spec(path, ndim - n_stack, moe_impl=pctx.moe_impl)
    spec_full = [None] * (ndim - len(trailing)) + list(trailing)

    # Drop non-divisible 'model' entries.
    for i, ax in enumerate(spec_full):
        if ax is not None and shape[i] % _axis_size(mesh, ax) != 0:
            spec_full[i] = None

    # FSDP: shard the largest remaining dim over 'data' (and 'pod' if
    # present — fully sharded across all DP lanes).  Axes already used by
    # the TP spec (e.g. a2a expert weights on 'data') are excluded — a
    # PartitionSpec may not repeat a mesh axis.
    if pctx.fsdp:
        used: set = set()
        for ax in spec_full:
            if ax is None:
                continue
            used.update((ax,) if isinstance(ax, str) else ax)
        dp_axes = tuple(a for a in ("pod", "data")
                        if a in mesh.shape and a not in used)
        dp = _axis_size(mesh, dp_axes)
        if dp > 1:
            cand = [
                (shape[i], i)
                for i in range(ndim)
                if spec_full[i] is None and shape[i] % dp == 0 and shape[i] >= dp
            ]
            if cand:
                _, i = max(cand)
                spec_full[i] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    return P(*spec_full)


def param_shardings(params, pctx: ParallelCtx):
    """PyTree of NamedShardings matching ``params``."""
    mesh = pctx.mesh

    def one(path, leaf):
        p = _path_strs(path)
        return NamedSharding(mesh, spec_for(p, leaf.shape, pctx))

    return jax.tree_util.tree_map_with_path(one, params)


def cache_shardings(cfg, cache, pctx: ParallelCtx):
    """KV/SSM cache shardings: batch over DP axes; head_dim (GQA), latent
    rank (MLA) or SSM heads over 'model' — chosen to divide for every
    assigned arch (DESIGN.md §5)."""
    mesh = pctx.mesh
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    bspec = batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None)

    def one(path, leaf):
        p = _path_strs(path)
        name = p[-1]
        if name in ("k", "v", "xk", "xv"):      # (g, B, kv, S, hd)
            spec: tuple[Any, ...] = (None, bspec, None, None, "model")
        elif name == "ckv":                      # (g, B, S, r)
            spec = (None, bspec, None, "model")
        elif name == "kr":                       # (g, B, S, rope)
            spec = (None, bspec, None, None)
        elif name == "ssm":                      # (g, B, h, st, hd)
            spec = (None, bspec, "model", None, None)
        elif name == "conv":                     # (g, B, K-1, C)
            spec = (None, bspec, None, "model")
        else:
            spec = (None, bspec) + (None,) * (leaf.ndim - 2)
        spec = list(spec[: leaf.ndim])
        for i, ax in enumerate(spec):
            if ax is not None and leaf.shape[i] % _axis_size(mesh, ax) != 0:
                spec[i] = None
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, cache)

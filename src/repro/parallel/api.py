"""Parallelism context threaded through the model stack.

Models stay pure functions; sharding is injected via a context (mesh +
logical-axis rules).  ``constrain`` is a no-op outside a mesh so the same
model code runs in single-device smoke tests, the multi-pod dry-run, and
real launches.

Logical activation axes:
  batch  -> (pod, data)   data parallel (pods are an outer DP axis;
                          optionally a PP axis, see pipeline.py)
  seq    -> None          (model axis under sequence parallelism)
  heads / ff / experts / vocab -> model   tensor / expert parallel

Param sharding rules live in sharding.py and use divisibility-aware
helpers so archs whose head counts don't divide the model axis degrade
to replication on that dim instead of uneven GSPMD padding.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Mapping

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


DEFAULT_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq": None,
    "seq_sp": "model",      # sequence-parallel alternative for long context
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "experts": "model",
    "vocab": "model",
    "embed": None,
    "state": None,
}


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    mesh: Mesh | None = None
    rules: Mapping[str, Any] = dataclasses.field(default_factory=lambda: DEFAULT_RULES)
    fsdp: bool = False            # ZeRO-3: shard params/opt-state over 'data'
    seq_parallel: bool = False    # shard long sequences over 'model'
    moe_impl: str = "epsum"       # "epsum" | "a2a" | "local"
    a2a_int8: bool = False        # int8 wire format for the MoE dispatch
    remat: str = "none"           # "none" | "full" | "dots"
    compress_grads: bool = False  # int8 error-feedback all-reduce

    def axis_size(self, logical: str) -> int:
        if self.mesh is None:
            return 1
        axes = self.rules.get(logical)
        if axes is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        size = 1
        for a in axes:
            if a in self.mesh.shape:
                size *= self.mesh.shape[a]
        return size

    def spec(self, *logical: Any) -> P:
        """Map logical axes (or None) to a PartitionSpec under the rules,
        dropping mesh axes that don't exist in the current mesh."""
        parts = []
        for l in logical:
            if l is None:
                parts.append(None)
                continue
            axes = self.rules.get(l, None) if isinstance(l, str) else l
            if axes is None:
                parts.append(None)
            elif isinstance(axes, str):
                parts.append(axes if self._has(axes) else None)
            else:
                kept = tuple(a for a in axes if self._has(a))
                parts.append(kept if kept else None)
        return P(*parts)

    def _has(self, axis: str) -> bool:
        return self.mesh is not None and axis in self.mesh.shape


_STATE = threading.local()


def ctx() -> ParallelCtx:
    return getattr(_STATE, "ctx", None) or ParallelCtx()


@contextlib.contextmanager
def use(pctx: ParallelCtx):
    prev = getattr(_STATE, "ctx", None)
    _STATE.ctx = pctx
    try:
        yield pctx
    finally:
        _STATE.ctx = prev


def constrain(x: jax.Array, *logical: Any) -> jax.Array:
    """with_sharding_constraint under the active mesh; no-op otherwise.

    Dims whose size doesn't divide the assigned mesh axes fall back to
    replication (avoids GSPMD padding surprises).
    """
    c = ctx()
    if c.mesh is None:
        return x
    spec = c.spec(*logical)
    parts = []
    for dim, p in zip(x.shape, tuple(spec) + (None,) * (x.ndim - len(spec))):
        if p is None:
            parts.append(None)
            continue
        axes = (p,) if isinstance(p, str) else p
        size = 1
        for a in axes:
            size *= c.mesh.shape[a]
        parts.append(p if dim % size == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(c.mesh, P(*parts))
    )


def batch_spec() -> P:
    return ctx().spec("batch")

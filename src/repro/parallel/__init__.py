"""Package."""

"""Int8 error-feedback gradient compression (distributed-optimization
trick; opt-in via ParallelCtx.compress_grads).

The DP gradient all-reduce moves full-precision gradients; at 1000+
nodes the cross-pod links are the bottleneck.  This module quantises
each gradient leaf to int8 (per-leaf absmax scaling) before it crosses
the wire and keeps the quantisation residual in an error-feedback
accumulator folded into the next step — the standard 1-bit-Adam / EF21
recipe, which preserves convergence.

In the pjit path XLA owns the all-reduce, so compression is expressed as
quantise→dequantise around the gradient (the wire format is what a
custom shard_map reduction would send; the simulated-compression mode
still exercises the numerics end-to-end).  ``shard_map_all_reduce``
is the explicit-collective variant for mesh runs: reduce-scatter in
int8, dequantise, all-gather.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat


def pytree_wire_bytes(tree) -> int:
    """Static wire footprint of a pytree in bytes: sum over leaves of
    element-count × itemsize.

    This is what one lane puts on the wire when the tree crosses a
    collective (DDC phase 2 threads it through its comm-volume meters).
    Shapes and dtypes are static, so this works identically on concrete
    arrays, tracers, and ``ShapeDtypeStruct``s — call it at trace time.
    """
    total = 0
    for leaf in jax.tree.leaves(tree):
        shape = getattr(leaf, "shape", ())
        dtype = np.dtype(getattr(leaf, "dtype", np.float32))
        total += int(np.prod(shape, dtype=np.int64)) * dtype.itemsize
    return total


def pytree_wire_bytes_int8(tree) -> int:
    """Prospective wire footprint if every float leaf shipped as int8
    with one f32 absmax scale per leading-axis row — a finer-grained
    variant of ``quantize_int8`` (which uses a single scale per array):
    per-row scales are what contour buffers would need, since cluster
    extents differ by orders of magnitude.  Integer/bool leaves are
    unchanged.  The streaming DDC delta path reports this as the
    achievable floor for shipping dirty ClusterSets — metered only, since
    quantised contours would break the bit-exactness contract unless both
    the sender's and the aggregator's predicate see the same rounding.
    """
    total = 0
    for leaf in jax.tree.leaves(tree):
        shape = getattr(leaf, "shape", ())
        dtype = np.dtype(getattr(leaf, "dtype", np.float32))
        n = int(np.prod(shape, dtype=np.int64))
        if np.issubdtype(dtype, np.floating):
            total += n + 4 * (int(shape[0]) if shape else 1)
        else:
            total += n * dtype.itemsize
    return total


def quantize_int8(x: jax.Array):
    absmax = jnp.max(jnp.abs(x)) + 1e-12
    scale = absmax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_decompress(grads):
    """Quantise/dequantise each leaf (wire-format numerics, pjit path)."""
    def one(g):
        q, s = quantize_int8(g.astype(jnp.float32))
        return dequantize_int8(q, s).astype(g.dtype)
    return jax.tree.map(one, grads)


def ef_compress(grads, errors):
    """Error-feedback compression: returns (wire_grads, new_errors)."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s = quantize_int8(g32)
        deq = dequantize_int8(q, s)
        return deq.astype(g.dtype), g32 - deq
    flat = jax.tree.map(one, grads, errors)
    wire = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
    errs = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
    return wire, errs


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def int8_all_to_all(x: jax.Array, axis: str) -> jax.Array:
    """all_to_all with int8 wire format in BOTH directions (per-row absmax
    scales ride along in f32).  The MoE a2a dispatch moves activations —
    int8 token rows halve the dominant collective term (§Perf iteration
    B4; DeepSeek-V3 ships fp8 dispatch on GPUs — int8 is the TPU-friendly
    equivalent).  Rounding error enters the forward like any activation
    quantisation; the backward quantises the incoming cotangent the same
    way.  x: (D, C, d) -> (D, C, d)."""
    return _i8_a2a_fwd(x, axis)[0]


def _quant_rows(x):
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _a2a_both(x, axis):
    q, scale = _quant_rows(x)
    q = jax.lax.all_to_all(q, axis, 0, 0)
    scale = jax.lax.all_to_all(scale, axis, 0, 0)
    return (q.astype(jnp.float32) * scale).astype(x.dtype)


def _i8_a2a_fwd(x, axis):
    return _a2a_both(x, axis), None


def _i8_a2a_bwd(axis, _, dy):
    return (_a2a_both(dy, axis),)


int8_all_to_all.defvjp(_i8_a2a_fwd, _i8_a2a_bwd)


def shard_map_all_reduce(grads, mesh, axes=("pod", "data")):
    """Explicit int8 all-reduce over the DP axes inside shard_map:
    quantise → psum int32 → dequantise (mean).  Collective bytes drop 4x
    vs f32 (2x vs bf16); used by the §Perf collective hillclimb."""
    from jax.sharding import PartitionSpec as P

    axes = tuple(a for a in axes if a in mesh.shape)
    if not axes:
        return grads
    n = 1
    for a in axes:
        n *= mesh.shape[a]

    def island(g):
        q, s = quantize_int8(g.astype(jnp.float32))
        qsum = q.astype(jnp.int32)
        for a in axes:
            qsum = jax.lax.psum(qsum, a)
            s = jax.lax.pmax(s, a)
        return (qsum.astype(jnp.float32) * s / n).astype(g.dtype)

    def one(g):
        return compat.shard_map(
            island, mesh=mesh,
            in_specs=P(*[None] * g.ndim), out_specs=P(*[None] * g.ndim),
            check_vma=False,
        )(g)

    return jax.tree.map(one, grads)

"""Pallas TPU kernel: tiled pairwise squared distances + fused ε-neighbour
counting — the DDC phase-1 hot-spot (DBSCAN region queries).

The paper's DBSCAN does per-point region queries (pointer chasing).  The
TPU-native formulation is a blocked matmul: for tiles X (bn, d), Y (bm, d)

    D2 = |X|^2 + |Y|^2 - 2 X Y^T

which runs on the MXU.  The fused variant accumulates, per row, the count
of points within eps — never materialising the (n, m) distance matrix in
HBM (arithmetic intensity: O(d) flops/byte on the MXU; the count output
is n int32 instead of n*m floats, so the kernel is compute-bound).

Grid layout: (n // bn, m // bm); the m axis is the innermost (sequential)
loop so per-row counts accumulate in the output block across j-steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEF_BN = 512
DEF_BM = 512

# Active-pair flag bits (see ops.build_tile_pairs): bit0 = pair is real
# (not tail padding), bit1 = first pair of its row tile (output block must
# be initialised before accumulating).
PAIR_VALID = 1
PAIR_FIRST = 2


def _dist_kernel(x_ref, y_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    y = y_ref[...].astype(jnp.float32)
    xx = jnp.sum(x * x, axis=-1)[:, None]
    yy = jnp.sum(y * y, axis=-1)[None, :]
    d2 = xx + yy - 2.0 * jax.lax.dot_general(
        x, y, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[...] = jnp.maximum(d2, 0.0)


@functools.partial(jax.jit, static_argnames=("bn", "bm", "interpret"))
def pairwise_dist_sq(
    x: jax.Array, y: jax.Array, *, bn: int = DEF_BN, bm: int = DEF_BM,
    interpret: bool = False,
) -> jax.Array:
    """Tiled (n, m) squared-distance matrix.  n, m must be tile-multiples
    (ops.py pads)."""
    n, d = x.shape
    m = y.shape[0]
    bn = min(bn, n)
    bm = min(bm, m)
    assert n % bn == 0 and m % bm == 0, (n, m, bn, bm)
    return pl.pallas_call(
        _dist_kernel,
        grid=(n // bn, m // bm),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=interpret,
    )(x, y)


def _count_kernel(eps_sq_ref, x_ref, y_ref, xm_ref, ym_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    y = y_ref[...].astype(jnp.float32)
    xx = jnp.sum(x * x, axis=-1)[:, None]
    yy = jnp.sum(y * y, axis=-1)[None, :]
    d2 = xx + yy - 2.0 * jax.lax.dot_general(
        x, y, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    within = (d2 <= eps_sq_ref[0]) & (xm_ref[...] > 0)[:, None] & (ym_ref[...] > 0)[None, :]
    o_ref[...] += jnp.sum(within.astype(jnp.int32), axis=1)


@functools.partial(jax.jit, static_argnames=("bn", "bm", "interpret"))
def neighbor_count(
    x: jax.Array, mask: jax.Array, eps: float | jax.Array, *,
    bn: int = DEF_BN, bm: int = DEF_BM, interpret: bool = False,
) -> jax.Array:
    """Fused per-point ε-neighbour count (self included), masked.

    x: (n, d), mask: (n,) bool -> (n,) int32.  n must be a tile multiple.
    """
    n, d = x.shape
    bn = min(bn, n)
    bm = min(bm, n)
    assert n % bn == 0 and n % bm == 0, (n, bn, bm)
    eps_sq = jnp.asarray([jnp.asarray(eps, jnp.float32) ** 2])
    mask_i = mask.astype(jnp.int32)
    return pl.pallas_call(
        _count_kernel,
        grid=(n // bn, n // bm),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((bn, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bn,), lambda i, j: (i,)),
            pl.BlockSpec((bm,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(eps_sq, x, x, mask_i, mask_i)


def _min_label_kernel(eps_sq_ref, x_ref, y_ref, xm_ref, ym_ref, lab_ref, core_ref, o_ref):
    """One label-propagation sweep tile: o[i] = min(lab[i], min_{j in N(i), core j} lab[j])."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.full(o_ref.shape, 2**30, jnp.int32)

    x = x_ref[...].astype(jnp.float32)
    y = y_ref[...].astype(jnp.float32)
    xx = jnp.sum(x * x, axis=-1)[:, None]
    yy = jnp.sum(y * y, axis=-1)[None, :]
    d2 = xx + yy - 2.0 * jax.lax.dot_general(
        x, y, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    ok = (
        (d2 <= eps_sq_ref[0])
        & (xm_ref[...] > 0)[:, None]
        & (ym_ref[...] > 0)[None, :]
        & (core_ref[...] > 0)[None, :]
    )
    labs = jnp.where(ok, lab_ref[...][None, :], jnp.int32(2**30))
    o_ref[...] = jnp.minimum(o_ref[...], jnp.min(labs, axis=1))


@functools.partial(jax.jit, static_argnames=("bn", "bm", "interpret"))
def min_label_sweep(
    x: jax.Array, mask: jax.Array, labels: jax.Array, core: jax.Array,
    eps: float | jax.Array, *, bn: int = DEF_BN, bm: int = DEF_BM,
    interpret: bool = False,
) -> jax.Array:
    """One blocked sweep of DBSCAN min-label propagation (see dbscan.py).

    Returns new_labels[i] = min over ε-neighbours j (core only) of labels[j],
    (2**30 where none).  Fused distance+min so the adjacency matrix never
    hits HBM.
    """
    n, d = x.shape
    bn = min(bn, n)
    bm = min(bm, n)
    assert n % bn == 0 and n % bm == 0
    eps_sq = jnp.asarray([jnp.asarray(eps, jnp.float32) ** 2])
    out = pl.pallas_call(
        _min_label_kernel,
        grid=(n // bn, n // bm),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((bn, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bn,), lambda i, j: (i,)),
            pl.BlockSpec((bm,), lambda i, j: (j,)),
            pl.BlockSpec((bm,), lambda i, j: (j,)),
            pl.BlockSpec((bm,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(eps_sq, x, x, mask.astype(jnp.int32), mask.astype(jnp.int32),
      labels.astype(jnp.int32), core.astype(jnp.int32))
    return out


# ---------------------------------------------------------------------------
# Block-sparse (gathered-grid) variants — DDC phase 1 on spatially sorted
# points.  The grid iterates an *active-pair list* (built by
# ops.build_tile_pairs from per-tile bounding boxes) instead of the full
# (T, T) tile product: tile pairs provably farther than eps apart are never
# fetched or computed.  Scalar-prefetched row/col indices drive the block
# gather; pairs arrive sorted by row tile so each output block is resident
# for exactly one contiguous run of grid steps (init on PAIR_FIRST,
# accumulate while PAIR_VALID, write-back when the row index advances).
# ---------------------------------------------------------------------------


def _count_sparse_kernel(rows_ref, cols_ref, flags_ref, eps_sq_ref,
                         x_ref, y_ref, xm_ref, ym_ref, o_ref):
    p = pl.program_id(0)
    flags = flags_ref[p]

    @pl.when((flags & PAIR_FIRST) != 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when((flags & PAIR_VALID) != 0)
    def _acc():
        x = x_ref[...].astype(jnp.float32)
        y = y_ref[...].astype(jnp.float32)
        xx = jnp.sum(x * x, axis=-1)[:, None]
        yy = jnp.sum(y * y, axis=-1)[None, :]
        d2 = xx + yy - 2.0 * jax.lax.dot_general(
            x, y, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        within = (
            (d2 <= eps_sq_ref[0])
            & (xm_ref[...] > 0)[:, None]
            & (ym_ref[...] > 0)[None, :]
        )
        o_ref[...] += jnp.sum(within.astype(jnp.int32), axis=1)


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def neighbor_count_sparse(
    x: jax.Array, mask: jax.Array, eps: float | jax.Array,
    rows: jax.Array, cols: jax.Array, flags: jax.Array, *,
    bt: int = DEF_BN, interpret: bool = False,
) -> jax.Array:
    """Masked ε-neighbour count over an active tile-pair list.

    x: (n, d) spatially sorted, n a multiple of ``bt``; rows/cols/flags:
    (P,) int32 pair list sorted by row (every row tile appears — the
    diagonal pair is always active).  Matches the dense ``neighbor_count``
    bit-exactly when the pair list covers every within-eps tile pair.
    """
    n, d = x.shape
    assert n % bt == 0, (n, bt)
    n_pairs = rows.shape[0]
    eps_sq = jnp.asarray([jnp.asarray(eps, jnp.float32) ** 2])
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n_pairs,),
        in_specs=[
            pl.BlockSpec((1,), lambda p, r, c, f: (0,)),
            pl.BlockSpec((bt, d), lambda p, r, c, f: (r[p], 0)),
            pl.BlockSpec((bt, d), lambda p, r, c, f: (c[p], 0)),
            pl.BlockSpec((bt,), lambda p, r, c, f: (r[p],)),
            pl.BlockSpec((bt,), lambda p, r, c, f: (c[p],)),
        ],
        out_specs=pl.BlockSpec((bt,), lambda p, r, c, f: (r[p],)),
    )
    mask_i = mask.astype(jnp.int32)
    return pl.pallas_call(
        _count_sparse_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(rows, cols, flags, eps_sq, x, x, mask_i, mask_i)


def _min_label_sparse_kernel(rows_ref, cols_ref, flags_ref, eps_sq_ref,
                             x_ref, y_ref, xm_ref, ym_ref, lab_ref, core_ref,
                             o_ref):
    p = pl.program_id(0)
    flags = flags_ref[p]

    @pl.when((flags & PAIR_FIRST) != 0)
    def _init():
        o_ref[...] = jnp.full(o_ref.shape, 2**30, jnp.int32)

    @pl.when((flags & PAIR_VALID) != 0)
    def _acc():
        x = x_ref[...].astype(jnp.float32)
        y = y_ref[...].astype(jnp.float32)
        xx = jnp.sum(x * x, axis=-1)[:, None]
        yy = jnp.sum(y * y, axis=-1)[None, :]
        d2 = xx + yy - 2.0 * jax.lax.dot_general(
            x, y, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        ok = (
            (d2 <= eps_sq_ref[0])
            & (xm_ref[...] > 0)[:, None]
            & (ym_ref[...] > 0)[None, :]
            & (core_ref[...] > 0)[None, :]
        )
        labs = jnp.where(ok, lab_ref[...][None, :], jnp.int32(2**30))
        o_ref[...] = jnp.minimum(o_ref[...], jnp.min(labs, axis=1))


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def min_label_sweep_sparse(
    x: jax.Array, mask: jax.Array, labels: jax.Array, core: jax.Array,
    eps: float | jax.Array, rows: jax.Array, cols: jax.Array,
    flags: jax.Array, *, bt: int = DEF_BN, interpret: bool = False,
) -> jax.Array:
    """One min-label propagation sweep over an active tile-pair list.

    Same semantics as the dense ``min_label_sweep`` (2**30 where a point
    has no in-range core neighbour) restricted to listed pairs — identical
    output when the list covers every within-eps tile pair.
    """
    n, d = x.shape
    assert n % bt == 0, (n, bt)
    n_pairs = rows.shape[0]
    eps_sq = jnp.asarray([jnp.asarray(eps, jnp.float32) ** 2])
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n_pairs,),
        in_specs=[
            pl.BlockSpec((1,), lambda p, r, c, f: (0,)),
            pl.BlockSpec((bt, d), lambda p, r, c, f: (r[p], 0)),
            pl.BlockSpec((bt, d), lambda p, r, c, f: (c[p], 0)),
            pl.BlockSpec((bt,), lambda p, r, c, f: (r[p],)),
            pl.BlockSpec((bt,), lambda p, r, c, f: (c[p],)),
            pl.BlockSpec((bt,), lambda p, r, c, f: (c[p],)),
            pl.BlockSpec((bt,), lambda p, r, c, f: (c[p],)),
        ],
        out_specs=pl.BlockSpec((bt,), lambda p, r, c, f: (r[p],)),
    )
    return pl.pallas_call(
        _min_label_sparse_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(rows, cols, flags, eps_sq, x, x, mask.astype(jnp.int32),
      mask.astype(jnp.int32), labels.astype(jnp.int32),
      core.astype(jnp.int32))

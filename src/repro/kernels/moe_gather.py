"""Pallas TPU kernel: fused MoE dispatch gather (+ optional int8 quantise).

The a2a expert-parallel dispatch (§Perf cell B) builds its send buffer
with a chain of gather → mask → scatter → quantise jnp ops — ~6-8 HBM
passes over the (slots, d) buffer in the lowered HLO.  This kernel does
it in one pass: for each send slot, read the source token row (dynamic
HBM load), scale to int8 (per-row absmax) and write the wire buffer +
scales.  Empty slots (row id -1) write zeros.

Grid: one program per slot block; token matrix stays in ANY/HBM memory
space and is row-gathered with dynamic loads; the slot's output block
lives in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEF_BS = 128


def _gather_kernel(idx_ref, x_hbm, out_ref, scale_ref, *, bs: int, quant: bool):
    def body(i, _):
        row = idx_ref[i]
        valid = row >= 0
        safe = jnp.maximum(row, 0)
        vals = pl.load(x_hbm, (pl.dslice(safe, 1), slice(None)))[0]
        vals = jnp.where(valid, vals, 0).astype(jnp.float32)
        if quant:
            absmax = jnp.max(jnp.abs(vals))
            scale = jnp.maximum(absmax / 127.0, 1e-12)
            q = jnp.clip(jnp.round(vals / scale), -127, 127)
            out_ref[i, :] = q.astype(out_ref.dtype)
            scale_ref[i] = jnp.where(valid, scale, 0.0)
        else:
            out_ref[i, :] = vals.astype(out_ref.dtype)
            scale_ref[i] = jnp.where(valid, 1.0, 0.0)
        return 0

    jax.lax.fori_loop(0, bs, body, 0)


@functools.partial(jax.jit, static_argnames=("quant", "bs", "interpret"))
def dispatch_gather(
    x: jax.Array, idx: jax.Array, *, quant: bool = True, bs: int = DEF_BS,
    interpret: bool = False,
):
    """x: (t, d) token rows; idx: (S,) source row per send slot (-1 empty).

    Returns (buf (S, d) [int8 if quant else x.dtype], scales (S,) f32).
    S must be a multiple of ``bs`` (ops pads).
    """
    t, d = x.shape
    s = idx.shape[0]
    bs_ = min(bs, s)
    assert s % bs_ == 0, (s, bs_)
    out_dtype = jnp.int8 if quant else x.dtype
    kernel = functools.partial(_gather_kernel, bs=bs_, quant=quant)
    return pl.pallas_call(
        kernel,
        grid=(s // bs_,),
        in_specs=[
            pl.BlockSpec((bs_,), lambda i: (i,)),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=[
            pl.BlockSpec((bs_, d), lambda i: (i, 0)),
            pl.BlockSpec((bs_,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s, d), out_dtype),
            jax.ShapeDtypeStruct((s,), jnp.float32),
        ],
        interpret=interpret,
    )(idx, x)

"""Pallas TPU kernel: flash attention (forward) with online softmax.

Tiling: grid (batch, q_heads, q_tiles, kv_tiles); the kv axis is the
innermost (sequential on TPU) so the running max / sum / accumulator live
in VMEM scratch across kv steps.  GQA is handled by the K/V BlockSpec
index_map (head h reads kv-head h // rep) — repeated heads are never
materialised in HBM.

Causal masking is two-level: whole kv tiles strictly above the diagonal
are skipped via ``pl.when`` (no MXU work), the diagonal tile applies an
element mask.  Optional ``window`` gives local attention (used by the
hybrid/long-context configs); far-past tiles are likewise skipped.

Block sizes default to (128, 128) — MXU-aligned (multiples of 8x128
registers / 128x128 systolic tiles).  VMEM footprint per step:
q (bq, d) + k, v (bk, d) + acc (bq, d) + logits (bq, bk) in f32
≈ 128*128*4 * 5 ≈ 0.3 MB for d=128 — comfortably inside ~16 MB VMEM;
larger d scales linearly and is still fine at d=256.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEF_BQ = 128
DEF_BK = 128
NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale: float, causal: bool, window: int | None, bq: int, bk: int,
    kv_len: int, q_len: int,
):
    kv_i = pl.program_id(3)
    q_i = pl.program_id(2)
    # Right-aligned positions: query row r has absolute position
    # (kv_len - q_len) + q_i*bq + r, so decode (q_len=1) attends to the
    # whole cache.
    q_off = (kv_len - q_len) + q_i * bq

    @pl.when(kv_i == 0)
    def _init():
        m_scr[...] = jnp.full(m_scr.shape, NEG_INF, jnp.float32)
        l_scr[...] = jnp.zeros(l_scr.shape, jnp.float32)
        acc_scr[...] = jnp.zeros(acc_scr.shape, jnp.float32)

    first_q_pos = q_off
    last_q_pos = q_off + bq - 1
    kv_start = kv_i * bk

    needed = jnp.asarray(True)
    if causal:
        needed = kv_start <= last_q_pos
    if window is not None:
        needed = needed & (kv_start + bk - 1 > first_q_pos - window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bk)
        qpos = q_off + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = kv_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_cur)
        alpha = jnp.exp(m_prev - m_cur)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_cur

    @pl.when(kv_i == pl.num_programs(3) - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "window", "bq", "bk", "interpret"),
)
def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True, scale: float | None = None, window: int | None = None,
    bq: int = DEF_BQ, bk: int = DEF_BK, interpret: bool = False,
) -> jax.Array:
    """q: (b, h, sq, d); k, v: (b, hkv, skv, d) with h % hkv == 0.

    sq/skv must be multiples of bq/bk (ops.py pads).  Returns (b, h, sq, d).
    """
    b, h, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    rep = h // hkv
    bq_ = min(bq, sq)
    bk_ = min(bk, skv)
    assert sq % bq_ == 0 and skv % bk_ == 0, (sq, skv, bq_, bk_)
    scale_ = scale if scale is not None else 1.0 / (d ** 0.5)

    kernel = functools.partial(
        _flash_kernel, scale=scale_, causal=causal, window=window,
        bq=bq_, bk=bk_, kv_len=skv, q_len=sq,
    )
    return pl.pallas_call(
        kernel,
        grid=(b, h, sq // bq_, skv // bk_),
        in_specs=[
            pl.BlockSpec((1, 1, bq_, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, bk_, d), lambda b_, h_, i, j, rep=rep: (b_, h_ // rep, j, 0)),
            pl.BlockSpec((1, 1, bk_, d), lambda b_, h_, i, j, rep=rep: (b_, h_ // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq_, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq_, 1), jnp.float32),   # running max m
            pltpu.VMEM((bq_, 1), jnp.float32),   # running denom l
            pltpu.VMEM((bq_, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)

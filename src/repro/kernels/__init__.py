"""Pallas TPU kernels for the perf-critical hot-spots, with jnp oracles.

- pairwise_dist: DDC/DBSCAN ε-neighbour counting + min-label sweeps (MXU)
- contour_dist: DDC phase-2 slot×slot contour min-distance merge matrix
- flash_attention: tiled online-softmax attention (GQA via index_map)
- ssd_scan: Mamba-2 state-space-duality chunked scan

Use ``repro.kernels.ops`` — it pads, dispatches pallas/ref by backend,
and is what the model stack calls.
"""
from . import ops, ref  # noqa: F401

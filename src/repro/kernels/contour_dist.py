"""Pallas TPU kernel: batched contour-pair min-distance matrix — the DDC
phase-2 hot-spot (cluster-merge proximity tests).

Phase 2 decides which clusters merge by the minimum pairwise distance
between their contour vertex buffers.  The per-pair formulation (one row
of clusters at a time against all vertices, ``lax.map``) serialises M
small reductions; the batched formulation below computes the full
(M, M) slot×slot proximity matrix in one pallas_call:

* contours arrive flattened cluster-major as (M·V, 2) vertices plus an
  (M·V,) validity vector (padding verts and invalid slots masked out);
* each grid step loads a (bi·V, 2) row strip and a (bj·V, 2) column
  strip, computes the (bi·V, bj·V) squared-distance tile with the MXU
  expansion |x|² + |y|² − 2·x·yᵀ (same centred-d2 machinery as
  ``pairwise_dist.py`` — callers centre coordinates so the expansion's
  f32 cancellation error stays far below merge thresholds), and
* min-reduces the (V, V) sub-blocks to a (bi, bj) output tile.

Invalid vertices contribute ``BIG``; a slot with no valid vertices gets a
BIG row/column, which callers treat as "never merges".
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEF_BI = 8
DEF_BJ = 8
BIG = 1e30


def _contour_min_kernel(x_ref, y_ref, xv_ref, yv_ref, o_ref, *, v: int):
    bi, bj = o_ref.shape
    x = x_ref[...].astype(jnp.float32)           # (bi*v, 2)
    y = y_ref[...].astype(jnp.float32)           # (bj*v, 2)
    xx = jnp.sum(x * x, axis=-1)[:, None]
    yy = jnp.sum(y * y, axis=-1)[None, :]
    d2 = xx + yy - 2.0 * jax.lax.dot_general(
        x, y, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    d2 = jnp.maximum(d2, 0.0)
    ok = (xv_ref[...] > 0)[:, None] & (yv_ref[...] > 0)[None, :]
    d2 = jnp.where(ok, d2, BIG)
    # Min over each cluster pair's (V, V) vertex sub-block.
    o_ref[...] = jnp.min(d2.reshape(bi, v, bj, v), axis=(1, 3))


@functools.partial(jax.jit, static_argnames=("v", "bi", "bj", "interpret"))
def contour_min_d2(
    x: jax.Array, xv: jax.Array, v: int, *, bi: int = DEF_BI, bj: int = DEF_BJ,
    interpret: bool = False,
) -> jax.Array:
    """Slot×slot min squared contour distance.

    x: (m·v, 2) flattened contour vertices (cluster-major, pre-centred);
    xv: (m·v,) int32 vertex validity.  m must be a multiple of both ``bi``
    and ``bj`` (ops.py pads with invalid slots).  Returns (m, m) f32 with
    BIG where either slot has no valid vertices.
    """
    n, d = x.shape
    assert n % v == 0, (n, v)
    m = n // v
    bi = min(bi, m)
    bj = min(bj, m)
    assert m % bi == 0 and m % bj == 0, (m, bi, bj)
    return pl.pallas_call(
        functools.partial(_contour_min_kernel, v=v),
        grid=(m // bi, m // bj),
        in_specs=[
            pl.BlockSpec((bi * v, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bj * v, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bi * v,), lambda i, j: (i,)),
            pl.BlockSpec((bj * v,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bi, bj), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, m), jnp.float32),
        interpret=interpret,
    )(x, x, xv, xv)

"""Pallas TPU kernel: Mamba-2 SSD (state-space duality) chunked scan.

The SSD recurrence  S_t = exp(a_t) S_{t-1} + b_t^T x_t,  y_t = c_t S_t
is O(L) sequential.  The duality rewrites a chunk of length Lc as:

  intra-chunk:  y_i += sum_{j<=i} exp(cum_i - cum_j) (c_i . b_j) x_j
                = (causal-masked (C B^T) * decay) @ X          -- MXU matmul
  inter-chunk:  y_i += exp(cum_i) * (c_i @ S_prev)
  state update: S   = exp(cum_last) S_prev
                      + sum_j exp(cum_last - cum_j) b_j^T x_j  -- MXU matmul

(cum = inclusive cumsum of log-decay within the chunk.)  The kernel walks
chunks sequentially (innermost grid axis) carrying S in VMEM scratch, so
the O(L) dependency chain touches only the (ds, dh) state while all the
O(L^2 / chunks) work runs on the MXU — this is the TPU-native adaptation
of Mamba-2's GPU algorithm (DESIGN.md §3).

Grid: (batch, heads, n_chunks).  Block = one (chunk, head) slice.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEF_CHUNK = 128


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, o_ref, s_scr, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros(s_scr.shape, jnp.float32)

    x = x_ref[0, :, 0, :].astype(jnp.float32)      # (Lc, dh)
    a = a_ref[0, :, 0].astype(jnp.float32)         # (Lc,)
    b = b_ref[0, :, 0, :].astype(jnp.float32)      # (Lc, ds)
    c = c_ref[0, :, 0, :].astype(jnp.float32)      # (Lc, ds)

    cum = jnp.cumsum(a)                            # inclusive
    # Intra-chunk: M[i, j] = exp(cum_i - cum_j) for j <= i else 0.
    li = cum[:, None]
    lj = cum[None, :]
    causal = (
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    )
    decay = jnp.where(causal, jnp.exp(li - lj), 0.0)
    cb = jax.lax.dot_general(
        c, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Lc, Lc)
    y = jax.lax.dot_general(
        cb * decay, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (Lc, dh)

    # Inter-chunk: contribution of carried state.
    s_prev = s_scr[...]                            # (ds, dh)
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        c, s_prev, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    # State update for the next chunk.
    last = cum[-1]
    w = jnp.exp(last - cum)[:, None] * b           # (Lc, ds)
    s_scr[...] = jnp.exp(last) * s_prev + jax.lax.dot_general(
        w, x, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    o_ref[0, :, 0, :] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jax.Array, a: jax.Array, b: jax.Array, c: jax.Array, *,
    chunk: int = DEF_CHUNK, interpret: bool = False,
) -> jax.Array:
    """x: (bsz, l, h, dh); a: (bsz, l, h); b, c: (bsz, l, h, ds).

    l must be a multiple of ``chunk`` (ops.py pads).  Matches ref.ssd_scan.
    """
    bsz, l, h, dh = x.shape
    ds = b.shape[-1]
    chunk_ = min(chunk, l)
    assert l % chunk_ == 0, (l, chunk_)
    kernel = functools.partial(_ssd_kernel, chunk=chunk_)
    return pl.pallas_call(
        kernel,
        grid=(bsz, h, l // chunk_),
        in_specs=[
            pl.BlockSpec((1, chunk_, 1, dh), lambda b_, h_, i: (b_, i, h_, 0)),
            pl.BlockSpec((1, chunk_, 1), lambda b_, h_, i: (b_, i, h_)),
            pl.BlockSpec((1, chunk_, 1, ds), lambda b_, h_, i: (b_, i, h_, 0)),
            pl.BlockSpec((1, chunk_, 1, ds), lambda b_, h_, i: (b_, i, h_, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk_, 1, dh), lambda b_, h_, i: (b_, i, h_, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((ds, dh), jnp.float32)],
        interpret=interpret,
    )(x, a, b, c)

"""Public kernel entry points.

Each op dispatches to the Pallas TPU kernel on TPU backends and to the
pure-jnp reference elsewhere (this container is CPU-only; kernels are
validated in interpret mode by tests/test_kernels.py).  Padding to tile
multiples happens here so kernels stay shape-strict.

Set ``repro.kernels.ops.FORCE`` to "pallas" / "ref" / "interpret" to
override dispatch (tests use "interpret").
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import contour_dist as _cd
from . import flash_attention as _fa
from . import pairwise_dist as _pd
from . import ref
from . import ssd_scan as _ssd

FORCE: str | None = None


def _use_pallas() -> bool:
    if FORCE == "pallas":
        return True
    if FORCE in ("ref",):
        return False
    if FORCE == "interpret":
        return True
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return FORCE == "interpret" or jax.default_backend() != "tpu"


def use_pallas_backend() -> bool:
    """Public probe: do ops dispatch to Pallas kernels right now?  Callers
    (dbscan's block-sparse "auto" mode) use this to skip gather-based
    layouts whose wins are kernel-side only."""
    return _use_pallas()


def _pad_to(x: jax.Array, axis: int, mult: int):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


# -- pairwise distance / neighbour counting --------------------------------

def pairwise_dist_sq(x: jax.Array, y: jax.Array, *, bn: int = 512, bm: int = 512) -> jax.Array:
    if not _use_pallas():
        return ref.pairwise_dist_sq(x, y)
    xp, n = _pad_to(x, 0, bn)
    yp, m = _pad_to(y, 0, bm)
    out = _pd.pairwise_dist_sq(xp, yp, bn=min(bn, xp.shape[0]), bm=min(bm, yp.shape[0]),
                               interpret=_interpret())
    return out[:n, :m]


def neighbor_count(x: jax.Array, mask: jax.Array, eps, *, bn: int = 512, bm: int = 512) -> jax.Array:
    if not _use_pallas():
        return ref.neighbor_count(x, mask, eps)
    xp, n = _pad_to(x, 0, bn)
    mp, _ = _pad_to(mask, 0, bn)
    out = _pd.neighbor_count(xp, mp, eps, bn=min(bn, xp.shape[0]), bm=min(bm, xp.shape[0]),
                             interpret=_interpret())
    return out[:n]


def min_label_sweep(x, mask, labels, core, eps, *, bn: int = 512, bm: int = 512) -> jax.Array:
    if not _use_pallas():
        return ref.min_label_sweep(x, mask, labels, core, eps)
    xp, n = _pad_to(x, 0, bn)
    mp, _ = _pad_to(mask, 0, bn)
    lp, _ = _pad_to(labels, 0, bn)
    cp, _ = _pad_to(core, 0, bn)
    out = _pd.min_label_sweep(xp, mp, lp, cp, eps, bn=min(bn, xp.shape[0]),
                              bm=min(bm, xp.shape[0]), interpret=_interpret())
    return out[:n]


def contour_min_d2(contours: jax.Array, counts: jax.Array, valid: jax.Array,
                   *, bi: int = 8, bj: int = 8) -> jax.Array:
    """DDC phase-2 merge matrix: (m, m) min squared distance between every
    pair of padded contour buffers (1e30 where either side is empty).

    contours: (m, v, 2); counts: (m,); valid: (m,) bool.  On the Pallas
    path coordinates are centred on the valid-vertex bbox midpoint first —
    d2 is translation-invariant, but the MXU xx+yy−2xy expansion is
    cancellation-prone (DESIGN.md §4 item 6) and merge thresholds are
    O(cell²).  The jnp reference uses the difference form directly.
    """
    if not _use_pallas():
        return ref.contour_min_d2(contours, counts, valid)
    m, v, d = contours.shape
    big = jnp.float32(3.4e38)
    pts = contours.astype(jnp.float32)
    vert_valid = (jnp.arange(v)[None, :] < counts[:, None]) & valid[:, None]
    lo = jnp.min(jnp.where(vert_valid[..., None], pts, big), axis=(0, 1))
    hi = jnp.max(jnp.where(vert_valid[..., None], pts, -big), axis=(0, 1))
    mid = jnp.where(hi >= lo, 0.5 * (lo + hi), 0.0)
    flat = (pts - mid).reshape(m * v, d)
    fv = vert_valid.reshape(m * v).astype(jnp.int32)
    # Pad the slot axis with invalid slots up to a tile multiple.
    bi = min(bi, m)
    bj = min(bj, m)
    mult = bi * bj // math.gcd(bi, bj)
    pad = (-m) % mult
    if pad:
        flat = jnp.pad(flat, ((0, pad * v), (0, 0)))
        fv = jnp.pad(fv, (0, pad * v))
    out = _cd.contour_min_d2(flat, fv, v, bi=bi, bj=bj, interpret=_interpret())
    return out[:m, :m]


# -- block-sparse spatial pruning (DDC phase 1) ------------------------------


class TilePairs(NamedTuple):
    """Static-shape active tile-pair list for the block-sparse kernels.

    rows/cols/flags: (T*T,) int32 — active pairs first, in row-major
    order (so the kernels' output blocks see one contiguous run per row
    tile), tail-padded by repeating the last active pair with flags=0.
    flags bit0 = pair is real, bit1 = first pair of its row tile.
    n_active / frac are traced scalars (the pair *values* are data
    dependent; only shapes are static).
    """

    rows: jax.Array     # (P,) int32 row-tile index
    cols: jax.Array     # (P,) int32 col-tile index
    flags: jax.Array    # (P,) int32 PAIR_VALID | PAIR_FIRST bits
    n_active: jax.Array  # () int32 — number of real pairs
    frac: jax.Array     # () f32 — n_active / T², the active-tile fraction


def build_tile_pairs(x: jax.Array, mask: jax.Array, eps, *, bt: int = 512) -> TilePairs:
    """Bounding-box pruning over ``bt``-point tiles of spatially sorted x.

    A tile pair (i, j) is *active* when the min distance between the two
    tiles' bounding boxes is <= eps — every within-eps point pair lives in
    an active tile pair, so skipping inactive pairs is exact, not an
    approximation.  Diagonal pairs are always active, which also
    guarantees every row tile appears in the list (the kernels rely on
    that to initialise all output blocks).  jit-traceable.
    """
    n, d = x.shape
    assert n % bt == 0, (n, bt)
    t = n // bt
    big = jnp.float32(3.4e38)
    xb = x.astype(jnp.float32).reshape(t, bt, d)
    mb = mask.reshape(t, bt)
    lo = jnp.min(jnp.where(mb[..., None], xb, big), axis=1)    # (T, d)
    hi = jnp.max(jnp.where(mb[..., None], xb, -big), axis=1)   # (T, d)
    has_pts = jnp.any(mb, axis=1)
    # Per-dim gap between boxes i and j (0 when they overlap on that dim).
    gap = jnp.maximum(lo[:, None, :] - hi[None, :, :],
                      lo[None, :, :] - hi[:, None, :])
    gap = jnp.maximum(gap, 0.0)
    gap_d2 = jnp.sum(gap * gap, axis=-1)                       # (T, T)
    eps_sq = jnp.asarray(eps, jnp.float32) ** 2
    active = (gap_d2 <= eps_sq) & has_pts[:, None] & has_pts[None, :]
    active = active | jnp.eye(t, dtype=bool)
    flat = active.reshape(t * t)
    n_active = jnp.sum(flat.astype(jnp.int32))
    # Active flat indices in row-major order; pad by repeating the last
    # active pair (same row tile -> no spurious output-block switch).
    (idx,) = jnp.nonzero(flat, size=t * t, fill_value=0)
    p = t * t
    is_real = jnp.arange(p, dtype=jnp.int32) < n_active
    last = idx[jnp.maximum(n_active - 1, 0)]
    idx = jnp.where(is_real, idx, last)
    rows = (idx // t).astype(jnp.int32)
    cols = (idx % t).astype(jnp.int32)
    first = is_real & jnp.concatenate(
        [jnp.asarray([True]), rows[1:] != rows[:-1]]
    )
    flags = (is_real.astype(jnp.int32) * _pd.PAIR_VALID
             | first.astype(jnp.int32) * _pd.PAIR_FIRST)
    frac = n_active.astype(jnp.float32) / float(t * t)
    return TilePairs(rows, cols, flags, n_active, frac)


def neighbor_count_sparse(x, mask, eps, pairs: TilePairs, *, bt: int = 512) -> jax.Array:
    """Block-sparse ``neighbor_count`` over spatially sorted points.

    x must already be padded to a multiple of ``bt`` (the block-sparse
    dbscan path owns the sort+pad so the pair list and data agree)."""
    if not _use_pallas():
        return ref.neighbor_count_sparse(x, mask, eps, pairs.rows,
                                         pairs.cols, pairs.flags, bt)
    return _pd.neighbor_count_sparse(x, mask, eps, pairs.rows, pairs.cols,
                                     pairs.flags, bt=bt,
                                     interpret=_interpret())


def min_label_sweep_sparse(x, mask, labels, core, eps, pairs: TilePairs, *,
                           bt: int = 512) -> jax.Array:
    """Block-sparse ``min_label_sweep`` over spatially sorted points."""
    if not _use_pallas():
        return ref.min_label_sweep_sparse(x, mask, labels, core, eps,
                                          pairs.rows, pairs.cols,
                                          pairs.flags, bt)
    return _pd.min_label_sweep_sparse(x, mask, labels, core, eps, pairs.rows,
                                      pairs.cols, pairs.flags, bt=bt,
                                      interpret=_interpret())


# -- attention ---------------------------------------------------------------

def flash_attention(q, k, v, *, causal: bool = True, scale=None, window=None,
                    bq: int = 128, bk: int = 128) -> jax.Array:
    """q: (b, h, sq, d); k, v: (b, hkv, skv, d)."""
    if not _use_pallas() or v.shape[-1] != q.shape[-1]:
        # (MLA trains with d_v != d_qk; the pallas kernel assumes equal dims
        # — on TPU the MLA layer pads v, on CPU the ref handles it.)
        if q.shape[2] * k.shape[2] > 2**21 and v.shape[-1] == q.shape[-1]:
            # Large sequences: chunked online softmax — the CPU stand-in for
            # the Pallas kernel.  The named scope tells the roofline analyzer
            # (launch/hlo_cost.py) that these intermediates live in VMEM on
            # the TPU target and must not count as HBM traffic.
            with jax.named_scope("vmem_kernel_attn"):
                return ref.flash_attention_chunked(
                    q, k, v, causal=causal, scale=scale, window=window)
        return ref.flash_attention(q, k, v, causal=causal, scale=scale, window=window)
    qp, sq = _pad_to(q, 2, bq)
    kp, skv = _pad_to(k, 2, bk)
    vp, _ = _pad_to(v, 2, bk)
    # Padding keys get masked out by causality only when padding is at the
    # end and queries are right-aligned; pad K with +inf positions instead:
    # simplest correct route — require multiples for the pallas path.
    if qp.shape[2] != sq or kp.shape[2] != skv:
        return ref.flash_attention(q, k, v, causal=causal, scale=scale, window=window)
    return _fa.flash_attention(q, k, v, causal=causal, scale=scale, window=window,
                               bq=bq, bk=bk, interpret=_interpret())


# -- SSD scan ----------------------------------------------------------------

def ssd_scan(x, a, b, c, *, chunk: int = 128) -> jax.Array:
    if not _use_pallas():
        if x.shape[1] >= 2 * chunk:
            with jax.named_scope("vmem_kernel_ssd"):
                return ref.ssd_scan_chunked(x, a, b, c, chunk=chunk)
        return ref.ssd_scan(x, a, b, c)
    if x.shape[1] % min(chunk, x.shape[1]) != 0:
        return ref.ssd_scan(x, a, b, c)
    return _ssd.ssd_scan(x, a, b, c, chunk=chunk, interpret=_interpret())

"""Public kernel entry points.

Each op dispatches to the Pallas TPU kernel on TPU backends and to the
pure-jnp reference elsewhere (this container is CPU-only; kernels are
validated in interpret mode by tests/test_kernels.py).  Padding to tile
multiples happens here so kernels stay shape-strict.

Set ``repro.kernels.ops.FORCE`` to "pallas" / "ref" / "interpret" to
override dispatch (tests use "interpret").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import flash_attention as _fa
from . import pairwise_dist as _pd
from . import ref
from . import ssd_scan as _ssd

FORCE: str | None = None


def _use_pallas() -> bool:
    if FORCE == "pallas":
        return True
    if FORCE in ("ref",):
        return False
    if FORCE == "interpret":
        return True
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return FORCE == "interpret" or jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


# -- pairwise distance / neighbour counting --------------------------------

def pairwise_dist_sq(x: jax.Array, y: jax.Array, *, bn: int = 512, bm: int = 512) -> jax.Array:
    if not _use_pallas():
        return ref.pairwise_dist_sq(x, y)
    xp, n = _pad_to(x, 0, bn)
    yp, m = _pad_to(y, 0, bm)
    out = _pd.pairwise_dist_sq(xp, yp, bn=min(bn, xp.shape[0]), bm=min(bm, yp.shape[0]),
                               interpret=_interpret())
    return out[:n, :m]


def neighbor_count(x: jax.Array, mask: jax.Array, eps, *, bn: int = 512, bm: int = 512) -> jax.Array:
    if not _use_pallas():
        return ref.neighbor_count(x, mask, eps)
    xp, n = _pad_to(x, 0, bn)
    mp, _ = _pad_to(mask, 0, bn)
    out = _pd.neighbor_count(xp, mp, eps, bn=min(bn, xp.shape[0]), bm=min(bm, xp.shape[0]),
                             interpret=_interpret())
    return out[:n]


def min_label_sweep(x, mask, labels, core, eps, *, bn: int = 512, bm: int = 512) -> jax.Array:
    if not _use_pallas():
        d2 = ref.pairwise_dist_sq(x, x)
        ok = (d2 <= jnp.asarray(eps, jnp.float32) ** 2) & mask[None, :] & mask[:, None] & core[None, :]
        labs = jnp.where(ok, labels[None, :], 2**30)
        return jnp.min(labs, axis=1).astype(jnp.int32)
    xp, n = _pad_to(x, 0, bn)
    mp, _ = _pad_to(mask, 0, bn)
    lp, _ = _pad_to(labels, 0, bn)
    cp, _ = _pad_to(core, 0, bn)
    out = _pd.min_label_sweep(xp, mp, lp, cp, eps, bn=min(bn, xp.shape[0]),
                              bm=min(bm, xp.shape[0]), interpret=_interpret())
    return out[:n]


# -- attention ---------------------------------------------------------------

def flash_attention(q, k, v, *, causal: bool = True, scale=None, window=None,
                    bq: int = 128, bk: int = 128) -> jax.Array:
    """q: (b, h, sq, d); k, v: (b, hkv, skv, d)."""
    if not _use_pallas() or v.shape[-1] != q.shape[-1]:
        # (MLA trains with d_v != d_qk; the pallas kernel assumes equal dims
        # — on TPU the MLA layer pads v, on CPU the ref handles it.)
        if q.shape[2] * k.shape[2] > 2**21 and v.shape[-1] == q.shape[-1]:
            # Large sequences: chunked online softmax — the CPU stand-in for
            # the Pallas kernel.  The named scope tells the roofline analyzer
            # (launch/hlo_cost.py) that these intermediates live in VMEM on
            # the TPU target and must not count as HBM traffic.
            with jax.named_scope("vmem_kernel_attn"):
                return ref.flash_attention_chunked(
                    q, k, v, causal=causal, scale=scale, window=window)
        return ref.flash_attention(q, k, v, causal=causal, scale=scale, window=window)
    qp, sq = _pad_to(q, 2, bq)
    kp, skv = _pad_to(k, 2, bk)
    vp, _ = _pad_to(v, 2, bk)
    # Padding keys get masked out by causality only when padding is at the
    # end and queries are right-aligned; pad K with +inf positions instead:
    # simplest correct route — require multiples for the pallas path.
    if qp.shape[2] != sq or kp.shape[2] != skv:
        return ref.flash_attention(q, k, v, causal=causal, scale=scale, window=window)
    return _fa.flash_attention(q, k, v, causal=causal, scale=scale, window=window,
                               bq=bq, bk=bk, interpret=_interpret())


# -- SSD scan ----------------------------------------------------------------

def ssd_scan(x, a, b, c, *, chunk: int = 128) -> jax.Array:
    if not _use_pallas():
        if x.shape[1] >= 2 * chunk:
            with jax.named_scope("vmem_kernel_ssd"):
                return ref.ssd_scan_chunked(x, a, b, c, chunk=chunk)
        return ref.ssd_scan(x, a, b, c)
    if x.shape[1] % min(chunk, x.shape[1]) != 0:
        return ref.ssd_scan(x, a, b, c)
    return _ssd.ssd_scan(x, a, b, c, chunk=chunk, interpret=_interpret())

"""Pure-jnp oracles for every Pallas kernel in this package.

Each function here defines the semantics; the Pallas kernels must match it
(tests sweep shapes/dtypes and assert_allclose against these).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def pairwise_dist_sq(x: Array, y: Array) -> Array:
    """Squared Euclidean distances.  x: (n, d), y: (m, d) -> (n, m).

    Uses the MXU-friendly expansion ||x||^2 + ||y||^2 - 2 x.y^T but computed
    here in full precision as the semantic reference.
    """
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    d2 = (
        jnp.sum(x * x, axis=-1)[:, None]
        + jnp.sum(y * y, axis=-1)[None, :]
        - 2.0 * (x @ y.T)
    )
    return jnp.maximum(d2, 0.0)


def neighbor_count(x: Array, mask: Array, eps: float) -> Array:
    """DDC/DBSCAN hot-spot: per-point count of masked points within eps
    (self included).  x: (n, d), mask: (n,) bool -> (n,) int32."""
    d2 = pairwise_dist_sq(x, x)
    adj = (d2 <= eps * eps) & mask[None, :] & mask[:, None]
    return jnp.sum(adj, axis=1).astype(jnp.int32)


def contour_min_d2(contours: Array, counts: Array, valid: Array) -> Array:
    """DDC phase-2 merge matrix: min squared distance between every pair
    of padded contour buffers.

    contours: (m, v, 2); counts: (m,) valid verts per slot; valid: (m,)
    slot validity.  Returns (m, m) f32 with 1e30 where either slot has no
    valid vertices.  Memory-bounded: one row of clusters at a time against
    all vertices (the difference form here is the semantic reference; the
    Pallas kernel uses the centred MXU expansion and must match within
    tolerance)."""
    m, v, _ = contours.shape
    big = jnp.float32(1e30)
    pts = contours.astype(jnp.float32)
    vert_valid = (jnp.arange(v)[None, :] < counts[:, None]) & valid[:, None]
    flat = pts.reshape(m * v, 2)
    flat_valid = vert_valid.reshape(m * v)

    def row(i):
        d2 = jnp.sum((pts[i][:, None, :] - flat[None, :, :]) ** 2, axis=-1)
        d2 = jnp.where(vert_valid[i][:, None] & flat_valid[None, :], d2, big)
        return jnp.min(d2.reshape(v, m, v), axis=(0, 2))  # (m,)

    return jax.lax.map(row, jnp.arange(m))


def min_label_sweep(x: Array, mask: Array, labels: Array, core: Array,
                    eps) -> Array:
    """One DBSCAN min-label sweep: per point, the min label over masked
    core points within eps (2**30 where none)."""
    d2 = pairwise_dist_sq(x, x)
    ok = (
        (d2 <= jnp.asarray(eps, jnp.float32) ** 2)
        & mask[:, None] & mask[None, :] & core[None, :]
    )
    labs = jnp.where(ok, labels[None, :].astype(jnp.int32), jnp.int32(2**30))
    return jnp.min(labs, axis=1)


# -- block-sparse variants (active tile-pair lists; see ops.build_tile_pairs)


def _pair_scan(x: Array, mask: Array, rows: Array, cols: Array,
               flags: Array, bt: int, init, contrib, combine):
    """Shared skeleton: sequentially fold listed (row, col) tile pairs into
    a per-row-tile accumulator — O(P · bt²) work and O(bt²) memory, the
    jnp mirror of the gathered-grid Pallas kernels."""
    n, d = x.shape
    t = n // bt
    xb = x.reshape(t, bt, d)
    mb = mask.reshape(t, bt)

    def step(acc, pair):
        r, c, f = pair
        valid = (f & 1) != 0
        out = contrib(jnp.take(xb, r, axis=0), jnp.take(xb, c, axis=0),
                      jnp.take(mb, r, axis=0), jnp.take(mb, c, axis=0),
                      r, c, valid)
        return combine(acc, r, out), None

    acc0 = jnp.full((t, bt), init, jnp.int32)
    acc, _ = jax.lax.scan(step, acc0, (rows, cols, flags))
    return acc.reshape(n)


def neighbor_count_sparse(x: Array, mask: Array, eps,
                          rows: Array, cols: Array, flags: Array,
                          bt: int) -> Array:
    """``neighbor_count`` restricted to listed tile pairs — bit-identical
    to the dense path when the list covers every within-eps tile pair."""
    eps_sq = jnp.asarray(eps, jnp.float32) ** 2

    def contrib(xt, yt, xm, ym, r, c, valid):
        d2 = pairwise_dist_sq(xt, yt)
        within = (d2 <= eps_sq) & xm[:, None] & ym[None, :] & valid
        return jnp.sum(within.astype(jnp.int32), axis=1)

    return _pair_scan(x, mask, rows, cols, flags, bt, 0, contrib,
                      lambda acc, r, out: acc.at[r].add(out))


def min_label_sweep_sparse(x: Array, mask: Array, labels: Array, core: Array,
                           eps, rows: Array, cols: Array, flags: Array,
                           bt: int) -> Array:
    """``min_label_sweep`` restricted to listed tile pairs."""
    n = x.shape[0]
    t = n // bt
    eps_sq = jnp.asarray(eps, jnp.float32) ** 2
    lb = labels.astype(jnp.int32).reshape(t, bt)
    cb = core.reshape(t, bt)

    def contrib(xt, yt, xm, ym, r, c, valid):
        d2 = pairwise_dist_sq(xt, yt)
        ok = ((d2 <= eps_sq) & xm[:, None] & ym[None, :]
              & jnp.take(cb, c, axis=0)[None, :] & valid)
        labs = jnp.where(ok, jnp.take(lb, c, axis=0)[None, :], jnp.int32(2**30))
        return jnp.min(labs, axis=1)

    return _pair_scan(x, mask, rows, cols, flags, bt, 2**30, contrib,
                      lambda acc, r, out: acc.at[r].min(out))


def flash_attention(
    q: Array, k: Array, v: Array, *, causal: bool = True, scale: float | None = None,
    window: int | None = None,
) -> Array:
    """Reference attention. q: (b, h, sq, d), k/v: (b, hkv, skv, d).

    GQA: h may be a multiple of hkv.  ``window``: optional local-attention
    width (attend to keys in (i - window, i]).
    """
    b, h, sq, d = q.shape
    hkv = k.shape[1]
    rep = h // hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    skv = k.shape[2]
    qpos = jnp.arange(sq)[:, None] + (skv - sq)  # right-aligned (decode-friendly)
    kpos = jnp.arange(skv)[None, :]
    if causal:
        logits = jnp.where(kpos <= qpos, logits, -jnp.inf)
    if window is not None:
        logits = jnp.where(kpos > qpos - window, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def flash_attention_chunked(
    q: Array, k: Array, v: Array, *, causal: bool = True,
    scale: float | None = None, window: int | None = None,
    bq: int = 512, bk: int = 512,
) -> Array:
    """Pure-jnp online-softmax attention, chunked over Q and KV blocks.

    Numerically matches ``flash_attention`` but never materialises the
    (sq, skv) logits — O(bq*bk) temporaries, like the Pallas kernel's
    VMEM behaviour.  This is what the model stack runs on non-TPU
    backends (incl. the dry-run), so memory_analysis reflects the TPU
    kernel's footprint rather than a quadratic jnp fallback.  The inner
    step is checkpointed so the backward pass recomputes logits blocks
    instead of storing them.
    """
    b, h, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    rep = h // hkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    bq = min(bq, sq)
    bk = min(bk, skv)
    # Pad to block multiples.
    pq = (-sq) % bq
    pk = (-skv) % bk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0))) if pk else v
    nq, nk = qp.shape[2] // bq, kp.shape[2] // bk
    qb = qp.reshape(b, hkv, rep, nq, bq, d).astype(jnp.float32) * scale
    kb = kp.reshape(b, hkv, nk, bk, d).astype(jnp.float32)
    vb = vp.reshape(b, hkv, nk, bk, d).astype(jnp.float32)
    q_off = skv - sq  # right-aligned positions

    def kv_step(carry, j):
        m_run, l_run, acc, qi = carry
        kj = jax.lax.dynamic_index_in_dim(kb, j, axis=2, keepdims=False)
        vj = jax.lax.dynamic_index_in_dim(vb, j, axis=2, keepdims=False)
        qi_blk = jax.lax.dynamic_index_in_dim(qb, qi, axis=3, keepdims=False)
        s = jnp.einsum("bgrqd,bgkd->bgrqk", qi_blk, kj)      # (b,hkv,rep,bq,bk)
        qpos = q_off + qi * bq + jnp.arange(bq)[:, None]
        kpos = j * bk + jnp.arange(bk)[None, :]
        mask = kpos < skv  # padding
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(m_run, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_run - m_new)
        l_new = l_run * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum("bgrqk,bgkd->bgrqd", p, vj)
        return (m_new, l_new, acc, qi), None

    kv_step = jax.checkpoint(kv_step)

    def q_step(_, qi):
        m0 = jnp.full((b, hkv, rep, bq), -1e30, jnp.float32)
        l0 = jnp.zeros((b, hkv, rep, bq), jnp.float32)
        a0 = jnp.zeros((b, hkv, rep, bq, d), jnp.float32)
        (m, l, acc, _), _ = jax.lax.scan(
            kv_step, (m0, l0, a0, qi), jnp.arange(nk)
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out

    _, blocks = jax.lax.scan(q_step, None, jnp.arange(nq))  # (nq,b,hkv,rep,bq,d)
    out = jnp.moveaxis(blocks, 0, 3).reshape(b, hkv, rep, nq * bq, d)
    out = out.reshape(b, h, nq * bq, d)[:, :, :sq]
    return out.astype(q.dtype)


def ssd_scan_chunked(x: Array, a: Array, b: Array, c: Array, *, chunk: int = 128) -> Array:
    """Chunked SSD in pure jnp — same math as the Pallas kernel
    (intra-chunk masked matmul + carried inter-chunk state).  Used as the
    CPU/dry-run stand-in for long sequences; see kernels/ssd_scan.py for
    the chunking algebra."""
    bsz, l, h, dh = x.shape
    ds = b.shape[-1]
    ch = min(chunk, l)
    pad = (-l) % ch
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n = x.shape[1] // ch

    def to_chunks(t):
        return jnp.moveaxis(
            t.reshape((bsz, n, ch) + t.shape[2:]), 1, 0
        ).astype(jnp.float32)

    xs, as_, bs, cs = map(to_chunks, (x, a, b, c))   # (n, bsz, ch, ...)
    causal = jnp.tril(jnp.ones((ch, ch), jnp.float32))

    def step(state, inp):
        xc, ac, bc, cc = inp                          # (bsz, ch, h, ...)
        cum = jnp.cumsum(ac, axis=1)                  # (bsz, ch, h)
        decay = jnp.exp(cum[:, :, None] - cum[:, None, :])  # (bsz, ch, ch, h)
        decay = decay * causal[None, :, :, None]
        cb = jnp.einsum("bihs,bjhs->bijh", cc, bc)
        y = jnp.einsum("bijh,bjhd->bihd", cb * decay, xc)
        y += jnp.exp(cum)[..., None] * jnp.einsum("bihs,bhsd->bihd", cc, state)
        last = cum[:, -1]                             # (bsz, h)
        w = jnp.exp(last[:, None] - cum)              # (bsz, ch, h)
        state = jnp.exp(last)[..., None, None] * state + jnp.einsum(
            "bihs,bihd,bih->bhsd", bc, xc, w
        )
        return state, y

    s0 = jnp.zeros((bsz, h, ds, dh), jnp.float32)
    _, ys = jax.lax.scan(step, s0, (xs, as_, bs, cs))
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, n * ch, h, dh)
    return y[:, :l].astype(x.dtype)


def ssd_scan(x: Array, a: Array, b: Array, c: Array) -> Array:
    """Mamba-2 SSD (state-space dual) reference, sequential scan.

    x: (b, l, h, dh)  input (already gated/projected)
    a: (b, l, h)      per-step log-decay (a = -softplus(...), i.e. <= 0)
    b: (b, l, h, ds)  input->state projection ("B" in SSD)
    c: (b, l, h, ds)  state->output projection ("C" in SSD)
    returns y: (b, l, h, dh) with state recurrence
        S_t = exp(a_t) * S_{t-1} + b_t^T x_t       (ds, dh)
        y_t = c_t @ S_t
    """
    bsz, l, h, dh = x.shape
    ds = b.shape[-1]

    def step(state, inp):
        xt, at, bt, ct = inp
        decay = jnp.exp(at)[..., None, None]  # (b, h, 1, 1)
        state = state * decay + bt[..., :, None] * xt[..., None, :]
        yt = jnp.einsum("bhs,bhsd->bhd", ct, state)
        return state, yt

    s0 = jnp.zeros((bsz, h, ds, dh), jnp.float32)
    xs = (
        jnp.moveaxis(x, 1, 0).astype(jnp.float32),
        jnp.moveaxis(a, 1, 0).astype(jnp.float32),
        jnp.moveaxis(b, 1, 0).astype(jnp.float32),
        jnp.moveaxis(c, 1, 0).astype(jnp.float32),
    )
    _, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)

"""Optimizers + LR schedules (no optax dependency — pure JAX pytrees).

* adamw     — default.  Moments live in f32 with the params' sharding, so
              FSDP shards optimizer state too (ZeRO).
* adafactor — factored second moments for ndim>=2 leaves; the memory
              answer for the 1 T-param config (Adam state for kimi-k2
              would need ~16 TB > a pod's 8.2 TB HBM — EXPERIMENTS.md).
              Supports bf16 params with stochastic rounding.
* sgdm      — baseline.

All updates are pure: (grads, state, params) -> (new_params, new_state).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"
    lr: float = 3e-4
    warmup: int = 100
    decay_steps: int = 10_000
    schedule: str = "cosine"        # "cosine" | "linear" | "const"
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # adafactor
    factored_threshold: int = 2
    stochastic_rounding: bool = False


def lr_at(cfg: OptConfig, step) -> jax.Array:
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup, 1), 1.0)
    t = jnp.clip((step - cfg.warmup) / jnp.maximum(cfg.decay_steps - cfg.warmup, 1), 0, 1)
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    elif cfg.schedule == "linear":
        decay = 1.0 - (1 - cfg.min_lr_frac) * t
    else:
        decay = jnp.asarray(1.0)
    return cfg.lr * warm * decay


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def clip_by_norm(tree, max_norm):
    g = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), tree), g


# ---------------------------------------------------------------------------


def init_state(cfg: OptConfig, params) -> dict:
    if cfg.name == "adamw":
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params), "v": jax.tree.map(zeros, params)}
    if cfg.name == "adafactor":
        def fact(p):
            if p.ndim >= cfg.factored_threshold and p.ndim >= 2:
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"f": jax.tree.map(fact, params, is_leaf=lambda x: hasattr(x, "shape"))}
    if cfg.name == "sgdm":
        return {"m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)}
    raise ValueError(cfg.name)


def _bf16_neighbor(down: jax.Array, toward_up: jax.Array) -> jax.Array:
    """Adjacent bf16 value in the given direction (bit-level nextafter —
    f32 nextafter would round back to the same bf16)."""
    bits = jax.lax.bitcast_convert_type(down, jnp.uint16)
    positive = (bits & 0x8000) == 0
    inc = jnp.where(positive == toward_up, jnp.uint16(1), jnp.uint16(0xFFFF))
    stepped = (bits + inc).astype(jnp.uint16)
    # ±0 special case: step into the smallest (sub)normal of the right sign.
    is_zero = (bits & 0x7FFF) == 0
    stepped = jnp.where(is_zero,
                        jnp.where(toward_up, jnp.uint16(0x0001), jnp.uint16(0x8001)),
                        stepped)
    return jax.lax.bitcast_convert_type(stepped, jnp.bfloat16)


def _stochastic_round_to(x32: jax.Array, dtype, key) -> jax.Array:
    if dtype != jnp.bfloat16:
        return x32.astype(dtype)
    down = x32.astype(jnp.bfloat16)          # round-to-nearest anchor
    down32 = down.astype(jnp.float32)
    toward_up = x32 > down32
    other = _bf16_neighbor(down, toward_up)  # bracket x32 between bf16s
    other32 = other.astype(jnp.float32)
    span = jnp.abs(other32 - down32)
    pfar = jnp.where(span > 0, jnp.abs(x32 - down32) / jnp.maximum(span, 1e-45), 0.0)
    u = jax.random.uniform(key, x32.shape)
    return jnp.where(u < pfar, other, down)


def apply_updates(cfg: OptConfig, grads, state, params, step, key=None):
    """Returns (new_params, new_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    grads, gnorm = clip_by_norm(grads, cfg.clip_norm)
    lr = lr_at(cfg, step)
    t = jnp.asarray(step, jnp.float32) + 1.0

    if cfg.name == "adamw":
        b1, b2 = cfg.b1, cfg.b2
        new_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
        new_v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def upd(p, m, v):
            u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
            u = u + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, new_m, new_v)
        return new_params, {"m": new_m, "v": new_v}, {"gnorm": gnorm, "lr": lr}

    if cfg.name == "adafactor":
        d2 = 0.999  # v decay
        keys = None
        if cfg.stochastic_rounding:
            n = len(jax.tree.leaves(params))
            key = key if key is not None else jax.random.PRNGKey(0)
            keys = list(jax.random.split(key, n))

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_f = tdef.flatten_up_to(state["f"])
        new_f, new_p = [], []
        for i, (p, g, f) in enumerate(zip(flat_p, flat_g, flat_f)):
            g2 = g * g + 1e-30
            if "vr" in f:
                vr = d2 * f["vr"] + (1 - d2) * g2.mean(-1)
                vc = d2 * f["vc"] + (1 - d2) * g2.mean(-2)
                denom = (
                    (vr / jnp.maximum(vr.mean(-1, keepdims=True), 1e-30))[..., None]
                    * vc[..., None, :]
                )
                u = g / jnp.sqrt(denom + 1e-30)
                nf = {"vr": vr, "vc": vc}
            else:
                v = d2 * f["v"] + (1 - d2) * g2
                u = g / jnp.sqrt(v + 1e-30)
                nf = {"v": v}
            # Update clipping (Adafactor RMS rule).
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms)
            u = u + cfg.weight_decay * p.astype(jnp.float32)
            p32 = p.astype(jnp.float32) - lr * u
            if cfg.stochastic_rounding and p.dtype == jnp.bfloat16:
                new_p.append(_stochastic_round_to(p32, p.dtype, keys[i]))
            else:
                new_p.append(p32.astype(p.dtype))
            new_f.append(nf)
        return (
            jax.tree.unflatten(tdef, new_p),
            {"f": jax.tree.unflatten(tdef, new_f)},
            {"gnorm": gnorm, "lr": lr},
        )

    if cfg.name == "sgdm":
        new_m = jax.tree.map(lambda m, g: 0.9 * m + g, state["m"], grads)
        new_params = jax.tree.map(
            lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype), params, new_m
        )
        return new_params, {"m": new_m}, {"gnorm": gnorm, "lr": lr}

    raise ValueError(cfg.name)

"""Fault-tolerant sharded checkpointing.

Design (multi-host ready, no external deps):

* Each *host* writes only the shards it owns (``addressable_shards``) as
  one ``.npz`` per host plus a JSON manifest describing the pytree
  structure, global shapes, dtypes and the mesh the state was saved
  under.
* Writes go to ``step_<N>.tmp-<nonce>/`` and are atomically renamed to
  ``step_<N>/`` after an fsync barrier — a crashed/preempted writer can
  never corrupt the latest checkpoint (restart safety).
* ``restore`` re-shards onto *any* mesh: values are assembled from
  shard files and re-dispatched with ``jax.device_put`` against the new
  sharding — this is the **elastic scaling** path (resume a 512-chip run
  on 256 chips or vice versa).
* ``CheckpointManager`` keeps the newest K checkpoints, runs saves on a
  background thread (compute/IO overlap), and can restore "latest".

On this single-process container every shard is addressable, which is
exactly the degenerate case of the same code path.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import tempfile
import threading

import jax
import numpy as np

SEP = "/"


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = SEP.join(_part(p) for p in path)
        out[key] = leaf
    return out, treedef


def _part(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save(path: str, state, step: int, extra: dict | None = None) -> str:
    """Write a checkpoint; returns the final directory path."""
    flat, _ = _flatten_with_paths(state)
    os.makedirs(path, exist_ok=True)
    final = os.path.join(path, f"step_{step:010d}")
    tmp = tempfile.mkdtemp(prefix=f"step_{step:010d}.tmp-", dir=path)

    host = jax.process_index()
    manifest = {
        "step": step,
        "extra": extra or {},
        "n_hosts": jax.process_count(),
        "leaves": {},
    }
    arrays = {}
    for key, leaf in flat.items():
        leaf = jax.tree.leaves(leaf)[0] if not hasattr(leaf, "shape") else leaf
        manifest["leaves"][key] = {
            "shape": list(leaf.shape),
            "dtype": str(leaf.dtype),
        }
        if isinstance(leaf, jax.Array) and hasattr(leaf, "addressable_shards"):
            for sh in leaf.addressable_shards:
                if sh.replica_id != 0:
                    continue
                idx = _index_id(sh.index, leaf.shape)
                arrays[f"{key}::{idx}"] = np.asarray(sh.data)
            manifest["leaves"][key]["sharded"] = True
        else:
            arrays[f"{key}::full"] = np.asarray(leaf)
            manifest["leaves"][key]["sharded"] = False

    np.savez(os.path.join(tmp, f"host_{host:05d}.npz"), **arrays)
    with open(os.path.join(tmp, f"manifest_{host:05d}.json"), "w") as f:
        json.dump(manifest, f)
    # fsync barrier then atomic publish
    for fn in os.listdir(tmp):
        fd = os.open(os.path.join(tmp, fn), os.O_RDONLY)
        os.fsync(fd)
        os.close(fd)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def _index_id(index, shape) -> str:
    parts = []
    for sl, dim in zip(index, shape):
        start = 0 if sl.start is None else sl.start
        stop = dim if sl.stop is None else sl.stop
        parts.append(f"{start}-{stop}")
    return "_".join(parts) or "scalar"


def _index_slices(idx_id: str, shape):
    if idx_id in ("full", "scalar", ""):
        return tuple(slice(None) for _ in shape)
    out = []
    for part in idx_id.split("_"):
        a, b = part.split("-")
        out.append(slice(int(a), int(b)))
    return tuple(out)


def list_steps(path: str) -> list[int]:
    if not os.path.isdir(path):
        return []
    steps = []
    for d in os.listdir(path):
        if d.startswith("step_") and ".tmp" not in d:
            steps.append(int(d.split("_")[1]))
    return sorted(steps)


def restore(path: str, state_like, step: int | None = None, shardings=None):
    """Rebuild ``state_like``-shaped state from disk, re-sharded onto
    ``shardings`` (any mesh — elastic restore).  ``state_like`` may be
    ShapeDtypeStructs (no allocation needed before restore)."""
    steps = list_steps(path)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {path}")
    step = step if step is not None else steps[-1]
    d = os.path.join(path, f"step_{step:010d}")

    manifests = sorted(f for f in os.listdir(d) if f.startswith("manifest"))
    with open(os.path.join(d, manifests[0])) as f:
        manifest = json.load(f)

    flat_like, treedef = _flatten_with_paths(state_like)
    buffers: dict[str, np.ndarray] = {}
    for fn in sorted(os.listdir(d)):
        if not fn.endswith(".npz"):
            continue
        with np.load(os.path.join(d, fn)) as z:
            for full_key in z.files:
                key, idx_id = full_key.split("::")
                if key not in flat_like:
                    continue
                info = manifest["leaves"][key]
                if key not in buffers:
                    buffers[key] = np.zeros(info["shape"], dtype=info["dtype"])
                sl = _index_slices(idx_id, info["shape"])
                buffers[key][sl] = z[full_key]

    flat_sh, _ = _flatten_with_paths(shardings) if shardings is not None else ({}, None)
    out = {}
    for key, like in flat_like.items():
        if key not in buffers:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = buffers[key]
        sh = flat_sh.get(key)
        out[key] = jax.device_put(arr, sh) if sh is not None else jax.device_put(arr)
    leaves = [out[k] for k in flat_like]
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest


@dataclasses.dataclass
class CheckpointManager:
    path: str
    keep: int = 3
    _thread: threading.Thread | None = None

    def save_async(self, state, step: int, extra: dict | None = None):
        """Snapshot to host memory synchronously, write in background."""
        state = jax.tree.map(lambda x: np.asarray(x) if hasattr(x, "shape") else x,
                             jax.device_get(state))
        self.wait()
        self._thread = threading.Thread(
            target=self._save_and_gc, args=(state, step, extra), daemon=True
        )
        self._thread.start()

    def save(self, state, step: int, extra: dict | None = None):
        self.wait()
        self._save_and_gc(state, step, extra)

    def _save_and_gc(self, state, step, extra):
        save(self.path, state, step, extra)
        steps = list_steps(self.path)
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.path, f"step_{s:010d}"),
                          ignore_errors=True)

    def wait(self):
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    def latest_step(self) -> int | None:
        steps = list_steps(self.path)
        return steps[-1] if steps else None

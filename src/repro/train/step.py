"""Training step builder: value_and_grad + optimizer under a mesh.

The returned step is a single jit with explicit in/out shardings (state
donated).  Grad accumulation happens inside the jit via lax.scan over
microbatches; optional int8 error-feedback gradient compression wraps
the cross-DP gradient reduction (parallel/compress.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.parallel import api as par
from repro.parallel import sharding as shard_rules
from repro.train import optimizer as opt_mod


class TrainState(NamedTuple):
    params: Any
    opt: Any
    step: jax.Array


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: opt_mod.OptConfig = opt_mod.OptConfig()
    microbatches: int = 1
    param_dtype: str = "float32"
    seed: int = 0


def make_train_state(cfg: ModelConfig, tcfg: TrainConfig, key=None) -> TrainState:
    key = key if key is not None else jax.random.PRNGKey(tcfg.seed)
    dtype = jnp.dtype(tcfg.param_dtype)
    params = T.init_params(cfg, key, dtype=dtype)
    opt_state = opt_mod.init_state(tcfg.opt, params)
    return TrainState(params, opt_state, jnp.zeros((), jnp.int32))


def _loss_and_grads(cfg, tcfg, params, batch, grad_shardings=None):
    if tcfg.microbatches <= 1:
        loss, grads = jax.value_and_grad(lambda p: T.loss_fn(cfg, p, batch))(params)
        return loss, grads

    n = tcfg.microbatches

    def constrain_g(tree):
        # Keep accumulated grads in their FSDP-sharded layout: XLA then
        # reduce-scatters each microbatch's gradient instead of
        # all-reducing it (bytes / (2 * dp_lanes) — §Perf iteration C2).
        if grad_shardings is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                            grad_shardings)

    micro = jax.tree.map(lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)

    def body(acc, mb):
        loss_acc, g_acc = acc
        loss, g = jax.value_and_grad(lambda p: T.loss_fn(cfg, p, mb))(params)
        g_acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), g_acc, g)
        return (loss_acc + loss, constrain_g(g_acc)), None

    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss, grads), _ = jax.lax.scan(body, (jnp.zeros(()), constrain_g(g0)), micro)
    inv = 1.0 / n
    return loss * inv, jax.tree.map(lambda g: g * inv, grads)


def build_train_step(cfg: ModelConfig, tcfg: TrainConfig, pctx: par.ParallelCtx):
    """Returns (step_fn, state_shardings, batch_sharding_fn).

    step_fn(state, batch) -> (state, metrics); jit-with-shardings happens
    in the caller (launch/train.py or launch/dryrun.py) so dry-runs can
    .lower() without allocating."""

    grad_shardings = None
    if pctx.mesh is not None:
        def _gs(path, leaf):
            from jax.sharding import NamedSharding
            p = shard_rules._path_strs(path)
            return NamedSharding(pctx.mesh, shard_rules.spec_for(p, leaf.shape, pctx))
        params_shape = jax.eval_shape(
            lambda: T.init_params(cfg, jax.random.PRNGKey(0),
                                  dtype=jnp.dtype(tcfg.param_dtype)))
        grad_shardings = jax.tree_util.tree_map_with_path(_gs, params_shape)

    def step_fn(state: TrainState, batch):
        with par.use(pctx):
            loss, grads = _loss_and_grads(cfg, tcfg, state.params, batch,
                                          grad_shardings)
            if pctx.compress_grads and pctx.mesh is not None:
                from repro.parallel import compress
                grads = compress.compress_decompress(grads)
            new_params, new_opt, metrics = opt_mod.apply_updates(
                tcfg.opt, grads, state.opt, state.params, state.step
            )
            metrics = dict(metrics, loss=loss)
            return TrainState(new_params, new_opt, state.step + 1), metrics

    return step_fn


def state_shardings(state_shapes, pctx: par.ParallelCtx):
    return shard_rules.param_shardings(state_shapes, pctx)


def batch_shardings(batch_shapes, pctx: par.ParallelCtx):
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = pctx.mesh
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp = 1
    for a in batch_axes:
        dp *= mesh.shape[a]
    bspec = batch_axes if len(batch_axes) > 1 else batch_axes[0]

    def one(leaf):
        # Replicate when the global batch doesn't divide the DP lanes
        # (e.g. long_500k's batch of 1).
        if leaf.shape[0] % dp != 0:
            if leaf.shape[0] > 1:
                import warnings
                warnings.warn(
                    f"batch dim {leaf.shape[0]} does not divide the {dp} DP "
                    f"lanes — REPLICATING (every lane computes the full "
                    f"batch). Check global_batch / microbatches vs mesh.",
                    stacklevel=2)
            spec = None
        else:
            spec = bspec
        return NamedSharding(mesh, P(spec, *([None] * (leaf.ndim - 1))))

    return jax.tree.map(one, batch_shapes)

"""Package."""

"""Package."""

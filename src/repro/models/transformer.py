"""Unified transformer stack: decoder-only, enc-dec, hybrid, SSM.

Layers are organised in *pattern groups*: ``cfg.block_pattern`` (e.g.
jamba's 7×mamba + 1×attn) repeats ``cfg.n_groups`` times; parameters are
stacked over groups and the stack is traversed with ``lax.scan`` so the
compiled HLO contains each distinct block body once (critical for the
512-device dry-run compile times of 62-layer models).

All functions are pure; sharding enters via parallel.api constraints and
the MoE shard_map island in layers.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.parallel import api as par

Params = dict

AUX_COEF = 0.01


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _block_init(cfg: ModelConfig, key, kind: str, is_moe: bool, cross: bool) -> Params:
    ks = jax.random.split(key, 6)
    p: Params = {"norm1": L.norm_init(cfg, cfg.d_model)}
    if kind == "attn":
        p["mixer"] = L.attn_init(cfg, ks[0])
    else:
        p["mixer"] = L.mamba_init(cfg, ks[0])
    if cross:
        p["norm_x"] = L.norm_init(cfg, cfg.d_model)
        p["cross"] = L.attn_init(cfg, ks[1], cross=True)
    if cfg.d_ff > 0 or is_moe:
        p["norm2"] = L.norm_init(cfg, cfg.d_model)
        p["ffn"] = L.moe_init(cfg, ks[2]) if is_moe else L.mlp_init(
            cfg, ks[2], cfg.d_model, cfg.d_ff
        )
    return p


def init_params(cfg: ModelConfig, key, dtype=jnp.float32) -> Params:
    keys = jax.random.split(key, 8)
    v, d = cfg.padded_vocab, cfg.d_model
    params: Params = {
        "embed": (jax.random.normal(keys[0], (v, d)) * 0.02),
        "final_norm": L.norm_init(cfg, d),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(keys[1], (v, d)) * 0.02)

    kinds = cfg.layer_kinds()
    cross = cfg.encoder_layers > 0

    def one_group(gkey):
        gks = jax.random.split(gkey, len(kinds))
        return {
            f"l{i}": _block_init(cfg, gks[i], kind, is_moe, cross)
            for i, (kind, is_moe) in enumerate(kinds)
        }

    gkeys = jax.random.split(keys[2], cfg.n_groups)
    params["blocks"] = jax.vmap(one_group)(gkeys)

    if cfg.encoder_layers:
        def enc_group(gkey):
            gks = jax.random.split(gkey, 2)
            return {
                "norm1": L.norm_init(cfg, d),
                "mixer": L.attn_init(cfg, gks[0]),
                "norm2": L.norm_init(cfg, d),
                "ffn": L.mlp_init(cfg, gks[1], d, cfg.d_ff),
            }
        ekeys = jax.random.split(keys[3], cfg.encoder_layers)
        params["encoder"] = jax.vmap(enc_group)(ekeys)
        params["enc_final_norm"] = L.norm_init(cfg, d)

    return jax.tree.map(lambda x: x.astype(dtype) if x.dtype == jnp.float32 else x,
                        params)


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _block_apply(cfg, kind, is_moe, bp, x, positions, window, enc_out=None):
    """One block, full-sequence.  Returns (x, aux)."""
    h = L.norm_apply(cfg, bp["norm1"], x)
    if kind == "attn":
        if cfg.attn_kind == "mla":
            o, _ = L.mla_apply(cfg, bp["mixer"], h, positions=positions,
                               window=window)
        else:
            o, _ = L.attn_apply(cfg, bp["mixer"], h, positions=positions,
                                window=window)
    else:
        o, _ = L.mamba_apply(cfg, bp["mixer"], h)
    x = x + o
    if enc_out is not None and "cross" in bp:
        hx = L.norm_apply(cfg, bp["norm_x"], x)
        kv = L.cross_kv(cfg, bp["cross"], enc_out)
        x = x + L.cross_apply(cfg, bp["cross"], hx, kv)
    aux = jnp.zeros((), jnp.float32)
    if "ffn" in bp:
        h2 = L.norm_apply(cfg, bp["norm2"], x)
        if is_moe:
            y, aux = L.moe_apply(cfg, bp["ffn"], h2)
        else:
            y = L.mlp_apply(cfg, bp["ffn"], h2)
        x = x + y
    return x, aux


def _scan_blocks(cfg, params, x, positions, window, enc_out=None):
    kinds = cfg.layer_kinds()

    def body(carry, bp):
        x, aux = carry
        for i, (kind, is_moe) in enumerate(kinds):
            x, a = _block_apply(cfg, kind, is_moe, bp[f"l{i}"], x,
                                positions, window, enc_out)
            aux = aux + a
        return (x, aux), None

    if par.ctx().remat == "full":
        body = jax.checkpoint(body)
    elif par.ctx().remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               params["blocks"])
    return x, aux


def encode(cfg, params, frames):
    """Whisper-style encoder over stub frame embeddings (B, Fs, d)."""
    x = frames + L.sinusoid_pos(frames.shape[1], cfg.d_model).astype(frames.dtype)
    x = par.constrain(x, "batch", None, None)

    def body(x, bp):
        h = L.norm_apply(cfg, bp["norm1"], x)
        o, _ = L.attn_apply(cfg, bp["mixer"], h, causal=False)
        x = x + o
        h2 = L.norm_apply(cfg, bp["norm2"], x)
        x = x + L.mlp_apply(cfg, bp["ffn"], h2)
        return x, None

    if par.ctx().remat != "none":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["encoder"])
    return L.norm_apply(cfg, params["enc_final_norm"], x)


# ---------------------------------------------------------------------------
# Training forward / loss
# ---------------------------------------------------------------------------


def forward(cfg: ModelConfig, params: Params, tokens, *, prefix=None,
            frames=None, window="cfg"):
    """Training forward.  tokens: (B, S) int32.  prefix: (B, P, d) VLM
    patch embeddings.  frames: (B, Fs, d) audio stub (enc-dec only).
    Returns logits (B, S, padded_vocab)."""
    win = cfg.window if window == "cfg" else window
    x = jnp.take(params["embed"], tokens, axis=0)
    x = par.constrain(x, "batch", None, None)
    pos_offset = 0
    if prefix is not None:
        x = jnp.concatenate([prefix.astype(x.dtype), x], axis=1)
        pos_offset = prefix.shape[1]
    s_total = x.shape[1]
    if cfg.pos_embed == "sinusoid":
        x = x + L.sinusoid_pos(s_total, cfg.d_model).astype(x.dtype)
    positions = jnp.arange(s_total)

    enc_out = encode(cfg, params, frames) if frames is not None else None
    x, aux = _scan_blocks(cfg, params, x, positions, win, enc_out)
    x = L.norm_apply(cfg, params["final_norm"], x)
    if prefix is not None:
        x = x[:, pos_offset:]
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,vd->bsv", x, head)
    logits = par.constrain(logits, "batch", None, "vocab")
    return logits, aux


def loss_fn(cfg: ModelConfig, params: Params, batch) -> jax.Array:
    """Next-token cross entropy (+ MoE aux)."""
    tokens = batch["tokens"]
    logits, aux = forward(
        cfg, params, tokens,
        prefix=batch.get("prefix"), frames=batch.get("frames"),
    )
    logits = logits.astype(jnp.float32)
    # Mask padded vocab entries out of the partition function.
    if cfg.padded_vocab != cfg.vocab:
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    targets = tokens[:, 1:]
    lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    nll = -jnp.take_along_axis(lp, targets[..., None], axis=-1)[..., 0]
    return nll.mean() + AUX_COEF * aux


# ---------------------------------------------------------------------------
# Serving: cache init / prefill / decode
# ---------------------------------------------------------------------------


def _cache_len(cfg, max_len: int, window) -> int:
    win = cfg.window if window == "cfg" else window
    return min(max_len, win) if win else max_len


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.float32,
               window="cfg") -> dict:
    g = cfg.n_groups
    s = _cache_len(cfg, max_len, window)
    kinds = cfg.layer_kinds()
    cache: dict = {}
    for i, (kind, _) in enumerate(kinds):
        if kind == "attn":
            if cfg.attn_kind == "mla":
                c = {
                    "ckv": jnp.zeros((g, batch, s, cfg.kv_lora_rank), dtype),
                    "kr": jnp.zeros((g, batch, s, cfg.qk_rope_dim), dtype),
                }
            else:
                c = {
                    "k": jnp.zeros((g, batch, cfg.n_kv_heads, s, cfg.head_dim), dtype),
                    "v": jnp.zeros((g, batch, cfg.n_kv_heads, s, cfg.head_dim), dtype),
                }
        else:
            conv_dim = cfg.d_inner + 2 * cfg.ssm_state
            c = {
                "conv": jnp.zeros((g, batch, cfg.conv_kernel - 1, conv_dim), dtype),
                "ssm": jnp.zeros(
                    (g, batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim),
                    jnp.float32,
                ),
            }
        if cfg.encoder_layers:
            c["xk"] = jnp.zeros(
                (g, batch, cfg.n_kv_heads, cfg.frontend_seq, cfg.head_dim), dtype
            )
            c["xv"] = jnp.zeros_like(c["xk"])
        cache[f"l{i}"] = c
    return cache


def _block_decode(cfg, kind, is_moe, bp, x, cache_slice, pos, window=None,
                  ring=False):
    h = L.norm_apply(cfg, bp["norm1"], x)
    if kind == "attn":
        if cfg.attn_kind == "mla":
            o, new_kv = L.mla_decode(cfg, bp["mixer"], h, cache_slice, pos)
        else:
            o, new_kv = L.attn_decode(cfg, bp["mixer"], h, cache_slice, pos,
                                      window=window, ring=ring)
    else:
        o, new_kv = L.mamba_decode(cfg, bp["mixer"], h, cache_slice, pos)
    x = x + o
    if "cross" in bp:
        hx = L.norm_apply(cfg, bp["norm_x"], x)
        x = x + L.cross_apply(cfg, bp["cross"], hx,
                              (cache_slice["xk"], cache_slice["xv"]))
        new_kv = dict(new_kv, xk=cache_slice["xk"], xv=cache_slice["xv"])
    if "ffn" in bp:
        h2 = L.norm_apply(cfg, bp["norm2"], x)
        if is_moe:
            y, _ = L.moe_apply(cfg, bp["ffn"], h2)
        else:
            y = L.mlp_apply(cfg, bp["ffn"], h2)
        x = x + y
    return x, new_kv


def decode_step(cfg: ModelConfig, params: Params, token, cache, pos,
                window="cfg"):
    """One decode step.  token: (B, 1) int32; pos: () int32 — the absolute
    position being written.  Returns (logits (B, V), new cache)."""
    kinds = cfg.layer_kinds()
    win = cfg.window if window == "cfg" else window
    # Ring-buffer mode: a windowed cache shorter than the position range.
    s_cache = None
    for i, (kind, _) in enumerate(kinds):
        if kind == "attn" and cfg.attn_kind != "mla":
            s_cache = cache[f"l{i}"]["k"].shape[3]
            break
    ring = win is not None and s_cache is not None and s_cache == win
    x = jnp.take(params["embed"], token, axis=0)
    if cfg.pos_embed == "sinusoid":
        half = cfg.d_model // 2
        freqs = 1.0 / (
            10000 ** (2.0 * jnp.arange(half, dtype=jnp.float32) / cfg.d_model)
        )
        ang = pos.astype(jnp.float32) * freqs
        pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])
        x = x + pe.astype(x.dtype)

    def body(x, scanned):
        bp, csl = scanned
        new = {}
        for i, (kind, is_moe) in enumerate(kinds):
            x, nkv = _block_decode(cfg, kind, is_moe, bp[f"l{i}"], x,
                                   csl[f"l{i}"], pos, window=win, ring=ring)
            new[f"l{i}"] = nkv
        return x, new

    x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
    x = L.norm_apply(cfg, params["final_norm"], x)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,vd->bsv", x, head)[:, 0]
    return logits, new_cache


def prefill(cfg: ModelConfig, params: Params, tokens, *, prefix=None,
            frames=None, max_len: int | None = None, window="cfg"):
    """Process the prompt, returning (last-token logits, cache, next_pos).

    Runs the full-sequence forward and writes K/V (or SSM states) into a
    fresh cache of length ``max_len`` (defaults to prompt length)."""
    b, s = tokens.shape
    win = cfg.window if window == "cfg" else window
    max_len = max_len or s
    kinds = cfg.layer_kinds()
    cache = init_cache(cfg, b, max_len, dtype=params["embed"].dtype,
                       window=window)
    s_cache = _cache_len(cfg, max_len, window)

    x = jnp.take(params["embed"], tokens, axis=0)
    pos_offset = 0
    if prefix is not None:
        x = jnp.concatenate([prefix.astype(x.dtype), x], axis=1)
        pos_offset = prefix.shape[1]
    s_total = x.shape[1]
    if cfg.pos_embed == "sinusoid":
        x = x + L.sinusoid_pos(s_total, cfg.d_model).astype(x.dtype)
    positions = jnp.arange(s_total)
    enc_out = encode(cfg, params, frames) if frames is not None else None

    def body(x, bp):
        new = {}
        for i, (kind, is_moe) in enumerate(kinds):
            bpi = bp[f"l{i}"]
            h = L.norm_apply(cfg, bpi["norm1"], x)
            if kind == "attn":
                if cfg.attn_kind == "mla":
                    o, (ckv, kr) = L.mla_apply(cfg, bpi["mixer"], h,
                                               positions=positions, window=win)
                    c = {
                        "ckv": _fit(ckv, s_cache, axis=1),
                        "kr": _fit(kr[:, 0], s_cache, axis=1),
                    }
                else:
                    o, (k, v) = L.attn_apply(cfg, bpi["mixer"], h,
                                             positions=positions, window=win)
                    c = {"k": _fit(k, s_cache, axis=2), "v": _fit(v, s_cache, axis=2)}
            else:
                o, mc = L.mamba_apply(cfg, bpi["mixer"], h, return_state=True)
                c = mc
            x = x + o
            if enc_out is not None and "cross" in bpi:
                hx = L.norm_apply(cfg, bpi["norm_x"], x)
                kv = L.cross_kv(cfg, bpi["cross"], enc_out)
                x = x + L.cross_apply(cfg, bpi["cross"], hx, kv)
                c = dict(c, xk=kv[0], xv=kv[1])
            if "ffn" in bpi:
                h2 = L.norm_apply(cfg, bpi["norm2"], x)
                if is_moe:
                    y, _ = L.moe_apply(cfg, bpi["ffn"], h2)
                else:
                    y = L.mlp_apply(cfg, bpi["ffn"], h2)
                x = x + y
            new[f"l{i}"] = c
        return x, new

    x, cache_out = jax.lax.scan(body, x, params["blocks"])
    # Pad/trim collected caches into the target cache length.
    cache = jax.tree.map(lambda dst, src: src.astype(dst.dtype), cache, cache_out)
    x = L.norm_apply(cfg, params["final_norm"], x)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bd,vd->bv", x[:, -1], head)
    return logits, cache, s_total


def _fit(x, target_len: int, axis: int):
    """Pad (with zeros, right) or keep the trailing window of ``x`` along
    ``axis`` so it matches the cache length."""
    s = x.shape[axis]
    if s == target_len:
        return x
    if s > target_len:  # windowed cache: keep the last target_len entries
        idx = [slice(None)] * x.ndim
        idx[axis] = slice(s - target_len, s)
        return x[tuple(idx)]
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, target_len - s)
    return jnp.pad(x, pad)

"""Model configuration covering all 10 assigned architectures.

One frozen dataclass parameterises the unified transformer stack
(models/transformer.py): dense / GQA / MQA / MLA attention, qk-norm,
MoE (+ shared experts), Mamba-2 SSD blocks and hybrid interleaves,
encoder-decoder (whisper) and prefix-embedding VLM stubs.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


def pad_to(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128

    # --- attention variant ---------------------------------------------
    attn_kind: str = "gqa"          # "gqa" | "mla"
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    pos_embed: str = "rope"         # "rope" | "sinusoid"
    window: int | None = None       # local-attention width (None = full)
    long_window: int | None = None  # window used only for long_500k cells

    # --- MLA (multi-head latent attention) ------------------------------
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_dim: int = 32
    qk_nope_dim: int = 64
    v_head_dim: int = 0             # 0 -> head_dim

    # --- MoE -------------------------------------------------------------
    n_experts: int = 0
    topk: int = 0
    moe_d_ff: int = 0               # expert hidden dim (0 -> d_ff)
    n_shared_experts: int = 0
    shared_d_ff: int = 0
    moe_pattern: Tuple[int, ...] = ()  # which layers in the block pattern are MoE
    capacity_factor: float = 1.25

    # --- block pattern / SSM ---------------------------------------------
    block_pattern: Tuple[str, ...] = ("attn",)  # cycled across layers
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_kernel: int = 4

    # --- encoder-decoder / modality stub ---------------------------------
    encoder_layers: int = 0          # > 0 => enc-dec (whisper)
    frontend: str = "none"           # "none" | "audio_stub" | "vision_stub"
    frontend_seq: int = 0            # stub embedding sequence length
    prefix_len: int = 0              # VLM: patch-embedding prefix length

    # --- misc --------------------------------------------------------------
    act: str = "silu"                # "silu" | "gelu"
    norm: str = "rmsnorm"            # "rmsnorm" | "layernorm"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    vocab_pad: int = 128

    # ------------------------------------------------------------------ #
    @property
    def padded_vocab(self) -> int:
        return pad_to(self.vocab, self.vocab_pad)

    @property
    def q_dim(self) -> int:
        if self.attn_kind == "mla":
            return self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
        return self.n_heads * self.head_dim

    @property
    def v_dim_per_head(self) -> int:
        if self.attn_kind == "mla":
            return self.v_head_dim or self.head_dim
        return self.head_dim

    @property
    def expert_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def pattern_len(self) -> int:
        return len(self.block_pattern)

    @property
    def n_groups(self) -> int:
        assert self.n_layers % self.pattern_len == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"pattern {self.block_pattern}"
        )
        return self.n_layers // self.pattern_len

    def layer_kinds(self) -> Tuple[Tuple[str, bool], ...]:
        """Per-pattern-position (kind, is_moe)."""
        out = []
        for i, kind in enumerate(self.block_pattern):
            is_moe = self.n_experts > 0 and (
                not self.moe_pattern or i in self.moe_pattern
            )
            out.append((kind, is_moe and kind != "mamba"))
        return tuple(out)

    # --- parameter counting (for roofline MODEL_FLOPS) -------------------
    def param_counts(self) -> dict:
        d, h, kv, hd = self.d_model, self.n_heads, self.n_kv_heads, self.head_dim
        counts = {"embed": self.padded_vocab * d}
        attn = 0
        if self.attn_kind == "mla":
            qr = self.q_lora_rank or d
            attn += d * qr + qr * self.q_dim                      # q down/up
            attn += d * (self.kv_lora_rank + self.qk_rope_dim)    # kv down
            attn += self.kv_lora_rank * self.n_heads * (
                self.qk_nope_dim + self.v_dim_per_head
            )
            attn += self.n_heads * self.v_dim_per_head * d        # out
        else:
            attn += d * h * hd + 2 * d * kv * hd + h * hd * d
        dense_ffn = 3 * d * self.d_ff if self.act == "silu" else 2 * d * self.d_ff
        moe_ffn = self.n_experts * 3 * d * self.expert_ff + d * self.n_experts
        moe_ffn += self.n_shared_experts * 3 * d * (self.shared_d_ff or self.expert_ff)
        mamba = (
            d * (2 * self.d_inner + 2 * self.ssm_state + self.ssm_heads)  # in_proj-ish
            + self.d_inner * d
            + self.conv_kernel * self.d_inner
        )
        per_pattern = 0
        active_per_pattern = 0
        for kind, is_moe in self.layer_kinds():
            if kind == "attn":
                per_pattern += attn
                active_per_pattern += attn
            else:
                per_pattern += mamba
                active_per_pattern += mamba
            if kind == "mamba":
                continue
            if is_moe:
                per_pattern += moe_ffn
                active = (
                    (self.topk + self.n_shared_experts) * 3 * d * self.expert_ff
                    + d * self.n_experts
                )
                active_per_pattern += active
            else:
                per_pattern += dense_ffn
                active_per_pattern += dense_ffn
        counts["blocks"] = self.n_groups * per_pattern
        counts["blocks_active"] = self.n_groups * active_per_pattern
        if self.encoder_layers:
            counts["encoder"] = self.encoder_layers * (attn + dense_ffn)
        counts["lm_head"] = 0 if self.tie_embeddings else self.padded_vocab * d
        counts["total"] = (
            counts["embed"] + counts["blocks"] + counts.get("encoder", 0)
            + counts["lm_head"]
        )
        counts["active"] = (
            counts["embed"] + counts["blocks_active"] + counts.get("encoder", 0)
            + counts["lm_head"]
        )
        return counts

    def tiny(self, **overrides) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        small = dict(
            n_layers=self.pattern_len * 2,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads > 1 else 1,
            head_dim=16,
            d_ff=128,
            vocab=512,
            q_lora_rank=32 if self.q_lora_rank else 0,
            kv_lora_rank=32 if self.kv_lora_rank else 0,
            qk_rope_dim=8 if self.attn_kind == "mla" else self.qk_rope_dim,
            qk_nope_dim=16 if self.attn_kind == "mla" else self.qk_nope_dim,
            v_head_dim=16 if self.attn_kind == "mla" else 0,
            n_experts=min(self.n_experts, 4),
            topk=min(self.topk, 2),
            moe_d_ff=64 if self.moe_d_ff else 0,
            shared_d_ff=64 if self.shared_d_ff else 0,
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            # Drop-free capacity so decode == teacher-forced forward exactly
            # (production configs keep 1.25 and accept routed drops).
            capacity_factor=float(max(self.n_experts, 1)),
            encoder_layers=2 if self.encoder_layers else 0,
            frontend_seq=16 if self.frontend_seq else 0,
            prefix_len=4 if self.prefix_len else 0,
            name=self.name + "-tiny",
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)

"""Neural-net layers for the unified transformer stack.

Pure functions over param pytrees (no framework dependency).  Compute is
bf16-friendly: matmuls accept whatever dtype params carry; softmax, norms
and the SSD scan accumulate in f32.

Parallelism: activations get logical-axis sharding constraints
(parallel.api.constrain); the MoE layer is a shard_map island —
activations are replicated across the 'model' axis (standard TP), each
model-lane owns E/M experts, routes the *same* token set to its local
experts, and a single psum over 'model' combines — comm cost of one
all-reduce, identical to a TP dense layer (DESIGN.md §5; an all_to_all
variant is the §Perf hillclimb comparison).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.kernels import ops
from repro.parallel import api as par

Params = dict


def _init(key, shape, scale=None, dtype=jnp.float32):
    scale = scale if scale is not None else 1.0 / math.sqrt(shape[0])
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms / positional
# ---------------------------------------------------------------------------


@jax.custom_vjp
def _f32c(x):
    return x.astype(jnp.float32)


def _f32c_fwd(x):
    return x.astype(jnp.float32), jnp.zeros((0,), x.dtype)


def _f32c_bwd(token, dy):
    # Norms upcast to f32 internally; without this, the residual-stream
    # cotangent crosses the TP all-reduce in f32 — 2x the wire bytes
    # (§Perf iteration C3).  Standard mixed-precision practice: the
    # boundary cotangent lives in the params' dtype.  (The zero-size
    # ``token`` smuggles the static dtype through the vjp residuals.)
    return (dy.astype(token.dtype),)


_f32c.defvjp(_f32c_fwd, _f32c_bwd)


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = _f32c(x)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def layernorm(x, w, b, eps: float = 1e-5):
    x32 = _f32c(x)
    mu = jnp.mean(x32, -1, keepdims=True)
    var = jnp.var(x32, -1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w + b


def norm_apply(cfg, p: Params, x):
    if cfg.norm == "layernorm":
        return layernorm(x, p["w"], p["b"], cfg.norm_eps)
    return rmsnorm(x, p["w"], cfg.norm_eps)


def norm_init(cfg, d: int) -> Params:
    if cfg.norm == "layernorm":
        return {"w": jnp.ones((d,)), "b": jnp.zeros((d,))}
    return {"w": jnp.ones((d,))}


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, H, S, D) with even D; positions: (S,) or (B, S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    if positions.ndim == 1:
        ang = positions.astype(jnp.float32)[None, None, :, None] * freqs
    else:
        ang = positions.astype(jnp.float32)[:, None, :, None] * freqs
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    rot = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return rot.astype(x.dtype)


def sinusoid_pos(seq: int, d: int, offset: int = 0) -> jax.Array:
    pos = np.arange(offset, offset + seq)[:, None]
    dim = np.arange(d // 2)[None, :]
    ang = pos / (10000 ** (2 * dim / d))
    emb = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(emb, jnp.float32)


# ---------------------------------------------------------------------------
# Dense MLP
# ---------------------------------------------------------------------------


def mlp_init(cfg, key, d: int, ff: int) -> Params:
    ks = jax.random.split(key, 3)
    p = {"w1": _init(ks[0], (d, ff)), "w2": _init(ks[1], (ff, d))}
    if cfg.act == "silu":
        p["w3"] = _init(ks[2], (d, ff))
    return p


def mlp_apply(cfg, p: Params, x):
    h = x @ p["w1"]
    h = par.constrain(h, "batch", None, "ff")
    if cfg.act == "silu":
        h = jax.nn.silu(h) * (x @ p["w3"])
    else:
        h = jax.nn.gelu(h)
    out = h @ p["w2"]
    return par.constrain(out, "batch", None, None)


# ---------------------------------------------------------------------------
# Attention — GQA/MQA (+ qk-norm, windows) and MLA
# ---------------------------------------------------------------------------


def attn_init(cfg, key, *, cross: bool = False) -> Params:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if cfg.attn_kind == "mla" and not cross:
        return mla_init(cfg, key)
    ks = jax.random.split(key, 4)
    p = {
        "wq": _init(ks[0], (d, h * hd)),
        "wk": _init(ks[1], (d, kv * hd)),
        "wv": _init(ks[2], (d, kv * hd)),
        "wo": _init(ks[3], (h * hd, d), scale=1.0 / math.sqrt(h * hd)),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,))
        p["k_norm"] = jnp.ones((hd,))
    return p


def _split_heads(x, n):  # (B,S,n*hd) -> (B,n,S,hd)
    b, s, _ = x.shape
    return x.reshape(b, s, n, -1).transpose(0, 2, 1, 3)


def _merge_heads(x):  # (B,n,S,hd) -> (B,S,n*hd)
    b, n, s, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, n * hd)


def gqa_qkv(cfg, p, x, positions):
    q = _split_heads(x @ p["wq"], cfg.n_heads)
    k = _split_heads(x @ p["wk"], cfg.n_kv_heads)
    v = _split_heads(x @ p["wv"], cfg.n_kv_heads)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if cfg.pos_embed == "rope":
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_apply(cfg, p, x, *, causal=True, window=None, positions=None):
    """Training/prefill attention.  Returns (out, (k, v)) so prefill can
    seed the cache."""
    b, s, d = x.shape
    if positions is None:
        positions = jnp.arange(s)
    q, k, v = gqa_qkv(cfg, p, x, positions)
    q = par.constrain(q, "batch", "heads", None, None)
    k = par.constrain(k, "batch", "kv_heads", None, None)
    o = ops.flash_attention(q, k, v, causal=causal, window=window)
    out = _merge_heads(o) @ p["wo"]
    return par.constrain(out, "batch", None, None), (k, v)


def attn_decode(cfg, p, x, cache, pos, window=None, ring=False):
    """One-token decode against a (B, kv, S, hd) cache.  ``pos``: () int.

    ``ring``: the cache is a circular buffer of exactly ``window`` slots
    (long-context local attention) — slot = pos % S, and every slot's
    absolute position is recovered arithmetically for masking.
    """
    k_cache, v_cache = cache["k"], cache["v"]
    b = x.shape[0]
    s_max = k_cache.shape[2]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k_new, v_new = gqa_qkv(cfg, p, x, positions)
    slot = jnp.asarray(pos) % s_max if ring else jnp.asarray(pos)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new, slot, axis=2)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new, slot, axis=2)
    kv = k_cache.shape[1]
    rep = cfg.n_heads // kv
    qg = q.reshape(b, kv, rep, cfg.head_dim)  # (B,kv,rep,hd) from (B,H,1,hd)
    logits = jnp.einsum(
        "bkrd,bksd->bkrs", qg.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) / math.sqrt(cfg.head_dim)
    slots = jnp.arange(s_max)
    if ring:
        # Absolute position stored in each slot: the largest value <= pos
        # congruent to the slot index (mod s_max); negative = never written.
        abs_pos = pos - ((pos - slots) % s_max)
        mask = (abs_pos >= 0)[None, None, None, :]
    else:
        mask = (slots <= pos)[None, None, None, :]
        if window is not None:
            mask = mask & (slots > pos - window)[None, None, None, :]
    logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkrs,bksd->bkrd", w, v_cache.astype(jnp.float32))
    o = o.reshape(b, 1, cfg.n_heads * cfg.head_dim).astype(x.dtype)
    return o @ p["wo"], {"k": k_cache, "v": v_cache}


# --- Cross-attention (enc-dec: whisper) -----------------------------------


def cross_kv(cfg, p, enc_out):
    """Precompute cross-attention K/V from encoder output (once per
    sequence; cached for decode)."""
    k = _split_heads(enc_out @ p["wk"], cfg.n_kv_heads)
    v = _split_heads(enc_out @ p["wv"], cfg.n_kv_heads)
    return k, v


def cross_apply(cfg, p, x, kv):
    """Decoder cross-attention: no mask, no rope."""
    k, v = kv
    q = _split_heads(x @ p["wq"], cfg.n_heads)
    o = ops.flash_attention(q, k, v, causal=False)
    out = _merge_heads(o) @ p["wo"]
    return par.constrain(out, "batch", None, None)


# --- MLA (multi-head latent attention, DeepSeek/MiniCPM3 style) ----------


def mla_init(cfg, key) -> Params:
    d = cfg.d_model
    h = cfg.n_heads
    nope, rope_d = cfg.qk_nope_dim, cfg.qk_rope_dim
    vd = cfg.v_dim_per_head
    qr = cfg.q_lora_rank
    ks = jax.random.split(key, 8)
    p: Params = {}
    if qr:
        p["w_dq"] = _init(ks[0], (d, qr))
        p["q_norm"] = jnp.ones((qr,))
        p["w_uq"] = _init(ks[1], (qr, h * (nope + rope_d)))
    else:
        p["w_uq"] = _init(ks[1], (d, h * (nope + rope_d)))
    p["w_dkv"] = _init(ks[2], (d, cfg.kv_lora_rank + rope_d))
    p["kv_norm"] = jnp.ones((cfg.kv_lora_rank,))
    p["w_uk"] = _init(ks[3], (cfg.kv_lora_rank, h * nope))
    p["w_uv"] = _init(ks[4], (cfg.kv_lora_rank, h * vd))
    p["wo"] = _init(ks[5], (h * vd, d), scale=1.0 / math.sqrt(h * vd))
    return p


def _mla_q(cfg, p, x, positions):
    h, nope, rope_d = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    cq = x
    if cfg.q_lora_rank:
        cq = rmsnorm(x @ p["w_dq"], p["q_norm"], cfg.norm_eps)
    q = _split_heads(cq @ p["w_uq"], h)               # (B,H,S,nope+rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_ckv(cfg, p, x, positions):
    rope_d = cfg.qk_rope_dim
    dkv = x @ p["w_dkv"]                              # (B,S,kv_lora+rope)
    c_kv = rmsnorm(dkv[..., : cfg.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = dkv[..., cfg.kv_lora_rank :][:, None]    # (B,1,S,rope)
    k_rope = rope(k_rope, positions, cfg.rope_theta)
    return c_kv, k_rope


def mla_apply(cfg, p, x, *, causal=True, window=None, positions=None,
              pad_v: bool = True):
    b, s, d = x.shape
    h, nope, rope_d = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    vd = cfg.v_dim_per_head
    if positions is None:
        positions = jnp.arange(s)
    q_nope, q_rope = _mla_q(cfg, p, x, positions)
    c_kv, k_rope = _mla_ckv(cfg, p, x, positions)
    k_nope = _split_heads(c_kv @ p["w_uk"], h)        # (B,H,S,nope)
    v = _split_heads(c_kv @ p["w_uv"], h)             # (B,H,S,vd)
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, h, s, rope_d))], -1)
    dq = nope + rope_d
    if pad_v and vd < dq:
        # Pad V to the QK head dim so the flash kernel path applies — MLA
        # with d_v != d_qk otherwise falls back to exact attention, which
        # materialises the (S, S) logits (§Perf iteration A: the padding
        # costs (dq/vd - 1)x extra PV flops but removes the O(S^2) HBM
        # traffic; same trick the TPU Pallas kernel uses).
        v = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dq - vd)))
        o = ops.flash_attention(
            q, k, v, causal=causal, window=window, scale=1.0 / math.sqrt(dq)
        )[..., :vd]
    else:
        o = ops.flash_attention(
            q, k, v, causal=causal, window=window, scale=1.0 / math.sqrt(dq)
        )
    out = _merge_heads(o) @ p["wo"]
    return par.constrain(out, "batch", None, None), (c_kv, k_rope)


def mla_decode(cfg, p, x, cache, pos):
    """Absorbed MLA decode: the cache stores only (c_kv, k_rope) —
    the latent compression is the whole point of MLA."""
    b = x.shape[0]
    h, nope, rope_d = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    vd = cfg.v_dim_per_head
    positions = jnp.full((b, 1), pos, jnp.int32)
    q_nope, q_rope = _mla_q(cfg, p, x, positions)     # (B,H,1,·)
    c_new, kr_new = _mla_ckv(cfg, p, x, positions)    # (B,1,r) / (B,1,1,rope)
    ckv = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], c_new, pos, axis=1)
    krope = jax.lax.dynamic_update_slice_in_dim(
        cache["kr"], kr_new[:, 0], pos, axis=1
    )                                                  # (B,S,rope)
    s_max = ckv.shape[1]
    w_uk = p["w_uk"].reshape(cfg.kv_lora_rank, h, nope)
    # Absorb W_uk into q: q_lat (B,H,1,r)
    q_lat = jnp.einsum("bhqn,rhn->bhqr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    logits = (
        jnp.einsum("bhqr,bsr->bhqs", q_lat, ckv.astype(jnp.float32))
        + jnp.einsum("bhqd,bsd->bhqs", q_rope.astype(jnp.float32),
                     krope.astype(jnp.float32))
    ) / math.sqrt(nope + rope_d)
    mask = (jnp.arange(s_max) <= pos)[None, None, None, :]
    logits = jnp.where(mask, logits, -1e30)
    wts = jax.nn.softmax(logits, axis=-1)
    o_lat = jnp.einsum("bhqs,bsr->bhqr", wts, ckv.astype(jnp.float32))  # (B,H,1,r)
    w_uv = p["w_uv"].reshape(cfg.kv_lora_rank, h, vd)
    o = jnp.einsum("bhqr,rhv->bhqv", o_lat, w_uv.astype(jnp.float32))
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, h * vd).astype(x.dtype)
    return o @ p["wo"], {"ckv": ckv, "kr": krope}


# ---------------------------------------------------------------------------
# MoE — expert parallel over the 'model' axis
# ---------------------------------------------------------------------------


def moe_init(cfg, key) -> Params:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.expert_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": _init(ks[0], (d, e), scale=0.02),
        "w1": _init(ks[1], (e, d, f)),
        "w3": _init(ks[2], (e, d, f)),
        "w2": _init(ks[3], (e, f, d), scale=1.0 / math.sqrt(f)),
    }
    if cfg.n_shared_experts:
        sf = (cfg.shared_d_ff or cfg.expert_ff) * cfg.n_shared_experts
        p["shared"] = mlp_init(cfg, ks[4], d, sf)
    return p


def _moe_local(cfg, p_router, w1, w3, w2, x_flat, e_lo, e_local: int,
               capacity: int):
    """Route x_flat (t, d) to experts [e_lo, e_lo + e_local) held locally.

    ``e_local``/``capacity`` are static (shape-bearing); ``e_lo`` may be a
    traced ``axis_index`` product.  Returns (y (t, d), aux loss).  Used
    verbatim by the single-device fallback (e_lo=0, e_local=E) and by
    each model-lane in the shard_map island.
    """
    t, d = x_flat.shape
    e_hi = e_lo + e_local
    k = cfg.topk
    logits = (x_flat @ p_router).astype(jnp.float32)          # (t, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, k)                      # (t, k)
    gates = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    fe = topi.reshape(-1)                                     # (t*k,)
    gate_flat = gates.reshape(-1)
    mine = (fe >= e_lo) & (fe < e_hi)
    le = jnp.where(mine, fe - e_lo, e_local)                  # local expert id
    order = jnp.argsort(le, stable=True)
    le_s = le[order]
    tok_s = order // k
    gate_s = gate_flat[order]
    first = jnp.searchsorted(le_s, jnp.arange(e_local + 1))
    rank = jnp.arange(t * k) - first[jnp.clip(le_s, 0, e_local)]
    keep = (le_s < e_local) & (rank < capacity)
    slot = jnp.where(keep, le_s * capacity + rank, e_local * capacity)

    xe = jnp.zeros((e_local * capacity + 1, d), x_flat.dtype)
    xe = xe.at[slot].set(jnp.where(keep[:, None], x_flat[tok_s], 0))
    xe = xe[:-1].reshape(e_local, capacity, d)

    h = jnp.einsum("ecd,edf->ecf", xe, w1)
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", xe, w3)
    ye = jnp.einsum("ecf,efd->ecd", h, w2).reshape(e_local * capacity, d)
    ye = jnp.concatenate([ye, jnp.zeros((1, d), ye.dtype)], 0)

    contrib = ye[slot] * (gate_s * keep)[:, None].astype(ye.dtype)
    y = jnp.zeros((t, d), x_flat.dtype).at[tok_s].add(contrib)

    # Load-balance aux parts (Switch): per-expert top-1 counts and prob
    # sums.  Returned as SUMS so shards combine linearly (aux is nonlinear
    # in the means, so per-shard aux values cannot simply be averaged).
    onehot = jax.nn.one_hot(topi[:, 0], cfg.n_experts, dtype=jnp.float32)
    aux_parts = (onehot.sum(0), probs.sum(0), jnp.asarray(t, jnp.float32))
    return y, aux_parts


def _moe_a2a_island(cfg, x_loc, router, w1, w3, w2, *, n_dlanes: int,
                    tokens_sharded: bool, int8_wire: bool = False):
    """DeepSeek-style expert parallelism: expert weights are FULLY sharded
    (experts over 'data', expert-FFN dim over 'model') and never move;
    only the routed tokens cross the wire via all_to_all over 'data'.

    This is the paper's core insight applied to MoE dispatch — ship the
    small representatives (top-k routed tokens, ~k/E of activations), not
    the big thing (expert weights).  §Perf iteration B replaces the
    epsum baseline (replicated activations + FSDP weight re-gathers)
    with this; collective bytes drop by the weights/activations ratio.
    """
    d = x_loc.shape[-1]
    e, k = cfg.n_experts, cfg.topk
    D = n_dlanes
    e_per = e // D
    t = x_loc.shape[0] * x_loc.shape[1]
    xf = x_loc.reshape(t, d)

    logits = (xf @ router).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, k)
    gates = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    fe = topi.reshape(-1)
    gate_flat = gates.reshape(-1)

    if tokens_sharded:
        # --- dispatch: sort token-copies by destination data-lane -------
        dest = fe // e_per
        le = fe % e_per
        order = jnp.argsort(dest, stable=True)
        dest_s, le_s = dest[order], le[order]
        tok_s, gate_s = order // k, gate_flat[order]
        first = jnp.searchsorted(dest_s, jnp.arange(D + 1))
        rank = jnp.arange(t * k) - first[jnp.clip(dest_s, 0, D)]
        cap = int(math.ceil(t * k / D * cfg.capacity_factor))
        keep = rank < cap
        slot = jnp.where(keep, dest_s * cap + rank, D * cap)
        # Send-buffer build = one fused gather pass on TPU
        # (kernels/moe_gather.dispatch_gather); the jnp chain below is its
        # stand-in, so intermediates count as VMEM in the roofline.
        with jax.named_scope("vmem_kernel_dispatch"):
            send_x = jnp.zeros((D * cap + 1, d), xf.dtype).at[slot].set(
                jnp.where(keep[:, None], xf[tok_s], 0))[: D * cap]
        send_le = jnp.full((D * cap + 1,), -1, jnp.int32).at[slot].set(
            jnp.where(keep, le_s, -1))[: D * cap]
        if int8_wire:
            from repro.parallel.compress import int8_all_to_all
            recv_x = int8_all_to_all(
                send_x.reshape(D, cap, d), "data").reshape(D * cap, d)
        else:
            recv_x = jax.lax.all_to_all(
                send_x.reshape(D, cap, d), "data", 0, 0).reshape(D * cap, d)
        recv_le = jax.lax.all_to_all(
            send_le.reshape(D, cap), "data", 0, 0).reshape(D * cap)
        n_recv = D * cap
    else:
        # Tokens replicated over 'data' (tiny batches): every lane holds
        # all tokens — just select the copies routed to MY experts.
        dlane = jax.lax.axis_index("data")
        mine = (fe >= dlane * e_per) & (fe < (dlane + 1) * e_per)
        recv_le = jnp.where(mine, fe - dlane * e_per, -1)
        recv_x = xf[jnp.arange(t * k) // k]
        n_recv = t * k

    # --- group received tokens by local expert -------------------------
    key2 = jnp.where(recv_le >= 0, recv_le, e_per)
    order2 = jnp.argsort(key2, stable=True)
    rl_s = key2[order2]
    first2 = jnp.searchsorted(rl_s, jnp.arange(e_per + 1))
    rank2 = jnp.arange(n_recv) - first2[jnp.clip(rl_s, 0, e_per)]
    # n_recv already carries the dispatch capacity factor; don't stack a
    # second one (§Perf iteration B2).
    cap_e = int(math.ceil(n_recv / e_per))
    keep2 = (rl_s < e_per) & (rank2 < cap_e)
    slot2 = jnp.where(keep2, rl_s * cap_e + rank2, e_per * cap_e)
    with jax.named_scope("vmem_kernel_dispatch"):  # second gather pass
        xe = jnp.zeros((e_per * cap_e + 1, d), recv_x.dtype).at[slot2].set(
            jnp.where(keep2[:, None], recv_x[order2], 0)
        )[:-1].reshape(e_per, cap_e, d)

    # --- expert compute (f sharded over 'model') ------------------------
    h = jnp.einsum("ecd,edf->ecf", xe, w1)
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", xe, w3)
    ye = jnp.einsum("ecf,efd->ecd", h, w2)
    # ye is PARTIAL over the f-shards ('model' lanes).  The psum happens
    # AFTER the return-trip combine, on (t, d) token rows instead of
    # (E_local, cap_e, d) expert slots — k*cf times fewer all-reduce
    # bytes (§Perf iteration B2; linearity of the f-contraction makes the
    # reordering exact).

    # --- un-group + return trip + combine -------------------------------
    with jax.named_scope("vmem_kernel_dispatch"):  # inverse gather pass
        ye_flat = jnp.concatenate(
            [ye.reshape(e_per * cap_e, d), jnp.zeros((1, d), ye.dtype)])
        back = jnp.zeros((n_recv, d), ye.dtype).at[order2].set(ye_flat[slot2])
    if tokens_sharded:
        if int8_wire:
            from repro.parallel.compress import int8_all_to_all
            ret = int8_all_to_all(
                back.reshape(D, cap, d), "data").reshape(D * cap, d)
        else:
            ret = jax.lax.all_to_all(
                back.reshape(D, cap, d), "data", 0, 0).reshape(D * cap, d)
        ret = jnp.concatenate([ret, jnp.zeros((1, d), ret.dtype)])
        contrib = ret[slot] * (gate_s * keep)[:, None].astype(ret.dtype)
        y = jnp.zeros((t, d), xf.dtype).at[tok_s].add(contrib)
        y = jax.lax.psum(y, "model")
    else:
        contrib = back * jnp.where(recv_le >= 0, gate_flat, 0.0)[:, None].astype(back.dtype)
        y = jnp.zeros((t, d), xf.dtype).at[jnp.arange(n_recv) // k].add(contrib)
        y = jax.lax.psum(y, ("data", "model"))

    onehot = jax.nn.one_hot(topi[:, 0], e, dtype=jnp.float32)
    aux_parts = (onehot.sum(0), probs.sum(0), jnp.asarray(t, jnp.float32))
    return y.reshape(x_loc.shape), aux_parts


def _aux_from_parts(e, parts):
    f_sum, p_sum, t = parts
    t = jnp.maximum(t, 1.0)
    return e * jnp.sum((f_sum / t) * (p_sum / t))


def moe_apply(cfg, p: Params, x):
    """x: (B, S, d) -> (y, aux_loss).

    Implementations (ParallelCtx.moe_impl):
      epsum — activations replicated over 'model', experts sharded over
              'model', psum combine.  Simple; weights FSDP-gathered.
      a2a   — experts over 'data' x FFN-dim over 'model' (weights never
              move); routed tokens all_to_all'd (§Perf iteration B).
      (no mesh) — single-device fallback, identical math.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.topk
    c = par.ctx()
    m = c.axis_size("experts")
    from jax.sharding import PartitionSpec as P

    pod = "pod" if c.mesh is not None and "pod" in c.mesh.shape else None
    batch_axes = (pod, "data") if pod else ("data",)
    dp = 1
    if c.mesh is not None:
        for a_ in batch_axes:
            if a_:
                dp *= c.mesh.shape[a_]
    # Tiny batches (long-context decode, global_batch=1) replicate across
    # DP inside the island instead of sharding.
    bspec = batch_axes if b % dp == 0 else None
    psum_axes = tuple(a_ for a_ in batch_axes if a_) if bspec else ()

    n_data = c.mesh.shape.get("data", 1) if c.mesh is not None else 1
    f_loc_ok = cfg.expert_ff % max(m, 1) == 0

    if c.mesh is None or m <= 1 or e % m != 0:
        t = b * s
        cap = int(math.ceil(t * k / e * cfg.capacity_factor))
        y, parts = _moe_local(cfg, p["router"], p["w1"], p["w3"], p["w2"],
                              x.reshape(t, d), e_lo=0, e_local=e, capacity=cap)
        y = y.reshape(x.shape)
        aux = _aux_from_parts(e, parts)
    elif (c.moe_impl == "a2a" and e % n_data == 0 and n_data > 1 and f_loc_ok):
        def island(x_loc, router, w1, w3, w2):
            y, parts = _moe_a2a_island(
                cfg, x_loc, router, w1, w3, w2, n_dlanes=n_data,
                tokens_sharded=bspec is not None, int8_wire=c.a2a_int8)
            if psum_axes:
                parts = jax.tree.map(lambda a_: jax.lax.psum(a_, psum_axes), parts)
            return y, parts

        y, parts = compat.shard_map(
            island,
            mesh=c.mesh,
            in_specs=(
                P(bspec, None, None),
                P(None, None),
                P("data", None, "model"),
                P("data", None, "model"),
                P("data", "model", None),
            ),
            out_specs=(P(bspec, None, None), (P(), P(), P())),
            check_vma=False,
        )(x, p["router"], p["w1"], p["w3"], p["w2"])
        aux = _aux_from_parts(e, parts)
    else:
        def island(x_loc, router, w1, w3, w2):
            lane = jax.lax.axis_index("model")
            t = x_loc.shape[0] * x_loc.shape[1]
            cap = int(math.ceil(t * k / e * cfg.capacity_factor))
            e_local = e // m
            y, parts = _moe_local(
                cfg, router, w1, w3, w2, x_loc.reshape(t, d),
                e_lo=lane * e_local, e_local=e_local, capacity=cap)
            y = jax.lax.psum(y.reshape(x_loc.shape), "model")
            if psum_axes:
                parts = jax.tree.map(lambda a_: jax.lax.psum(a_, psum_axes), parts)
            return y, parts

        y, parts = compat.shard_map(
            island,
            mesh=c.mesh,
            in_specs=(
                P(bspec, None, None),
                P(None, None),
                P("model", None, None),
                P("model", None, None),
                P("model", None, None),
            ),
            out_specs=(P(bspec, None, None), (P(), P(), P())),
            check_vma=False,
        )(x, p["router"], p["w1"], p["w3"], p["w2"])
        aux = _aux_from_parts(e, parts)

    if cfg.n_shared_experts:
        y = y + mlp_apply(cfg, p["shared"], x)
    return par.constrain(y, "batch", None, None), aux


# ---------------------------------------------------------------------------
# Mamba-2 (SSD) block
# ---------------------------------------------------------------------------


def mamba_init(cfg, key) -> Params:
    d, di, st, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ks = jax.random.split(key, 4)
    conv_dim = di + 2 * st
    return {
        "w_in": _init(ks[0], (d, 2 * di + 2 * st + h)),
        "conv": _init(ks[1], (cfg.conv_kernel, conv_dim), scale=0.2),
        "a_log": jnp.zeros((h,)),
        "dt_bias": jnp.zeros((h,)),
        "d_skip": jnp.ones((h,)),
        "out_norm": jnp.ones((di,)),
        "w_out": _init(ks[2], (di, d)),
    }


def _causal_conv(x, w, state=None):
    """Depthwise causal conv.  x: (B, S, C); w: (K, C).  ``state``: (B, K-1, C)
    tail from the previous segment (decode).  Returns (y, new_state)."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(k))
    return jax.nn.silu(y), xp[:, -(k - 1) :]


def _mamba_project(cfg, p, x):
    di, st, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    zxbcdt = x @ p["w_in"]
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : di + di + 2 * st]
    dt = jax.nn.softplus(zxbcdt[..., -h:] + p["dt_bias"])     # (B,S,h)
    return z, xbc, dt


def _mamba_ssd_inputs(cfg, p, xbc, dt):
    b_, s_ = xbc.shape[0], xbc.shape[1]
    di, st, h, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    xs = xbc[..., :di].reshape(b_, s_, h, hd)
    bmat = xbc[..., di : di + st][:, :, None, :]               # (B,S,1,st)
    cmat = xbc[..., di + st :][:, :, None, :]
    a = -jnp.exp(p["a_log"].astype(jnp.float32))               # (h,) < 0
    a_dt = a[None, None, :] * dt                               # (B,S,h) log-decay
    b_eff = jnp.broadcast_to(bmat, (b_, s_, h, st)) * dt[..., None]
    c_eff = jnp.broadcast_to(cmat, (b_, s_, h, st))
    return xs, a_dt, b_eff, c_eff


def mamba_apply(cfg, p: Params, x, conv_state=None, return_state: bool = False):
    """Full-sequence Mamba-2 block.  Returns (out, cache|None); with
    ``return_state`` the cache {"conv", "ssm"} seeds decode."""
    z, xbc, dt = _mamba_project(cfg, p, x)
    xbc, conv_tail = _causal_conv(xbc, p["conv"], conv_state)
    xs, a_dt, b_eff, c_eff = _mamba_ssd_inputs(cfg, p, xbc, dt)
    y = ops.ssd_scan(xs, a_dt, b_eff, c_eff)                   # (B,S,h,hd)
    y = y + xs * p["d_skip"][None, None, :, None]
    y = y.reshape(x.shape[0], x.shape[1], cfg.d_inner)
    y = rmsnorm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    out = y @ p["w_out"]
    cache = None
    if return_state:
        # Final SSM state: S = sum_j exp(cum_last - cum_j) b_j^T x_j
        # (decayed contributions of every step; old steps underflow to 0,
        # which is the mathematically correct limit).
        cum = jnp.cumsum(a_dt.astype(jnp.float32), axis=1)      # (B,S,h)
        w = jnp.exp(cum[:, -1:, :] - cum)                       # (B,S,h)
        s_fin = jnp.einsum("bsht,bshd,bsh->bhtd", b_eff.astype(jnp.float32),
                           xs.astype(jnp.float32), w)
        cache = {"conv": conv_tail, "ssm": s_fin}
    return par.constrain(out, "batch", None, None), cache


def mamba_decode(cfg, p: Params, x, cache, pos):
    """One-step Mamba-2 recurrence.  cache: {"conv": (B,K-1,C), "ssm":
    (B,h,st,hd)}."""
    z, xbc, dt = _mamba_project(cfg, p, x)                     # S = 1
    xbc, conv_tail = _causal_conv(xbc, p["conv"], cache["conv"])
    xs, a_dt, b_eff, c_eff = _mamba_ssd_inputs(cfg, p, xbc, dt)
    s_prev = cache["ssm"]                                      # (B,h,st,hd)
    decay = jnp.exp(a_dt[:, 0])[..., None, None]               # (B,h,1,1)
    s_new = s_prev * decay + b_eff[:, 0][..., :, None] * xs[:, 0][..., None, :]
    y = jnp.einsum("bhs,bhsd->bhd", c_eff[:, 0], s_new)[:, None]  # (B,1,h,hd)
    y = y + xs * p["d_skip"][None, None, :, None]
    y = y.reshape(x.shape[0], 1, cfg.d_inner).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    return y @ p["w_out"], {"conv": conv_tail, "ssm": s_new}

"""`repro.ddc.DDC` — the estimator-style front door to the whole repo.

One object, one lifecycle, every deployment style::

    from repro.ddc import DDC, DDCConfig

    cfg = DDCConfig(eps=0.02, min_pts=5, backend="stream", shards=8,
                    capacity=4096).validate(sample=pts)
    model = DDC(cfg).fit(pts, t=t0)      # batch fit (any backend)
    model.partial_fit(shard=3, batch=new_pts, t=now)   # streaming write
    model.expire(now - window)           # TTL eviction (stream backend)
    model.labels_                        # global labels of fitted points
    model.query(probes)                  # point -> global cluster id
    model.comm_stats()                   # exact wire-byte accounting
    model.save("ckpt/"); DDC.load("ckpt/")   # bit-identical resume

The backend (``host`` | ``jit`` | ``stream`` | ``dist``) is a config
knob; all
backends produce the identical global clustering on the same per-shard
membership.  Configs are validated at construction (``DDCConfig
.validate``), so schedule/backend mismatches and DESIGN.md §7 sizing
violations fail loudly before any distributed work runs.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import zipfile

import numpy as np

from repro.core import ddc as core_ddc
from repro.ddc import backends as backends_mod
from repro.ddc.config import DDCConfig
from repro.serve import faults as faults_mod

SNAPSHOT_FORMAT = "repro-ddc/v1"


class SnapshotError(RuntimeError):
    """A snapshot directory that cannot be loaded (truncated npz,
    corrupt or missing manifest, wrong format tag).  Raised by
    ``DDC.load`` *before* any model state is constructed, so a failed
    load never disturbs a live service."""


class DDC:
    """Estimator facade over a pluggable DDC execution backend."""

    def __init__(self, config: DDCConfig,
                 meter: core_ddc.CommMeter | None = None,
                 faults: "faults_mod.FaultPlan | None" = None):
        self.config = config.validate()
        self._faults = faults
        self.backend = backends_mod.BACKENDS[config.backend](
            self.config, meter=meter, faults=faults)

    # -- write path --------------------------------------------------------

    def fit(self, points: np.ndarray, t: float | None = None) -> "DDC":
        """Cluster ``points`` (n, 2), block-partitioned over the
        configured shards.  Replaces any previously fitted state.

        ``t`` stamps the batch for TTL eviction (stream backend).  Pass
        it whenever later ``partial_fit``/``expire`` calls use wall-clock
        timestamps — the default stamp is the ingest sequence number,
        which any wall-clock ``expire`` cutoff would treat as ancient."""
        self.backend.fit(points, t=t)
        return self

    def partial_fit(self, shard: int, batch: np.ndarray,
                    t: float | None = None) -> "DDC":
        """Append ``batch`` to ``shard`` and fold it into the global
        clustering on the next read.  ``t`` stamps the batch for TTL
        eviction (stream backend; defaults to an ingest sequence
        number).  Batch backends re-run the full pipeline lazily; the
        stream backend repairs incrementally (delta-merge)."""
        self.backend.partial_fit(shard, batch, t=t)
        return self

    def expire(self, t: float) -> int:
        """Evict every point ingested with timestamp < ``t`` from all
        shards (stream/dist backends only).  Returns the eviction count."""
        return self.backend.expire(t)

    def tracks(self):
        """The cluster-tracking read view (DESIGN.md §14): the
        ``repro.serve.TrackSnapshot`` published alongside the query
        tier's versioned ``Snapshot`` — same version, so pairing
        ``labels_``/``query`` reads with ``tracks()`` observes one
        consistent generation.  Stream/dist backends with
        ``track=True`` only; folds pending writes first (like
        ``read_snapshot``), and returns None before anything is
        ingested."""
        return self.backend.tracks()

    # -- read path ---------------------------------------------------------

    @property
    def labels_(self) -> np.ndarray:
        """Global cluster ids of the fitted (live) points, in per-shard
        ingest order (== input order after a plain ``fit``)."""
        return self.backend.labels()

    @property
    def points_(self) -> np.ndarray:
        """The fitted (live) points, aligned with ``labels_``."""
        return self.backend.points()

    @property
    def n_clusters_(self) -> int:
        labels = self.labels_
        return len(set(labels[labels >= 0].tolist()))

    def query(self, points: np.ndarray, legacy: bool = False):
        """Global cluster id per query point: nearest clustered fitted
        point within ``eps`` (DBSCAN's border rule), else -1.

        Returns a ``repro.serve.QueryResult``: the labels plus the
        snapshot ``version`` that answered, the ``degraded`` flag, the
        routed ``scanned_shards``, and per-request latency.  The result
        duck-types as its labels ndarray (``np.asarray``, comparisons,
        indexing all work), so pre-redesign callers run unchanged;
        ``legacy=True`` returns the bare ndarray outright."""
        return self.backend.query(points, legacy=legacy)

    @property
    def query_tier(self):
        """The pipelined high-QPS read loop (DESIGN.md §12): bounded
        ``submit``/``drain`` queue, per-request deadlines, coalesced
        batched launches, snapshot-staleness policy from the config's
        ``max_staleness``."""
        return self.backend.query_tier

    def stats(self):
        """The typed ``repro.serve.ServiceStats`` contract: monotonic
        counters vs point-in-time gauges vs comm accounting, identical
        across all four backends.  ``stats().as_dict()`` /
        ``stats().comm_dict()`` are the legacy dict views."""
        return self.backend.service_stats()

    def comm_stats(self) -> dict:
        """Exact trace-time wire accounting for the chosen backend
        (legacy flat dict view; see ``stats()`` for the typed form)."""
        return self.backend.comm_stats()

    # -- snapshot / restore ------------------------------------------------

    def save(self, path: str) -> str:
        """Serialise config + full backend state under directory ``path``.

        Both files are written to a sibling temp directory, fsynced, and
        published with ONE rename (the ``train/checkpoint.py`` idiom), so
        a reader can never observe a manifest from one save paired with
        arrays from another.  Overwrites swap via two renames: the
        previous snapshot is moved aside first and deleted last, so a
        crash mid-save leaves either the new snapshot at ``path`` or the
        old one recoverable under ``<path>.old-*`` — never a long
        no-checkpoint window.  A restored model resumes bit-identically —
        for the stream backend that includes the ring buffers, per-shard
        ClusterSets, and the cached pair-d2 matrix, so no re-cluster is
        needed on restart."""
        arrays, state_manifest = self.backend.state()
        manifest = {
            "format": SNAPSHOT_FORMAT,
            "config": self.config.to_manifest(),
            "state": state_manifest,
        }
        path = path.rstrip(os.sep)
        parent = os.path.dirname(os.path.abspath(path))
        os.makedirs(parent, exist_ok=True)
        tmp = tempfile.mkdtemp(prefix=os.path.basename(path) + ".tmp-",
                               dir=parent)
        np.savez(os.path.join(tmp, "state.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        for fn in os.listdir(tmp):
            fd = os.open(os.path.join(tmp, fn), os.O_RDONLY)
            os.fsync(fd)
            os.close(fd)
        old = None
        if os.path.exists(path):
            old = tempfile.mkdtemp(prefix=os.path.basename(path) + ".old-",
                                   dir=parent)
            os.rmdir(old)
            os.rename(path, old)
        os.rename(tmp, path)
        if old is not None:
            shutil.rmtree(old, ignore_errors=True)
        if self._faults is not None and self._faults.take_torn_snapshot():
            faults_mod.tear_snapshot(path)
        return path

    @classmethod
    def load(cls, path: str,
             meter: core_ddc.CommMeter | None = None,
             faults: "faults_mod.FaultPlan | None" = None) -> "DDC":
        """Rebuild a saved model; the stream backend resumes exactly
        where ``save`` left off (same labels, same cached matrices).
        ``meter`` becomes the restored backend's comm meter — it counts
        traffic from this process on; a snapshot does not replay the
        saved run's collectives.

        Every snapshot defect — missing or corrupt ``manifest.json``, a
        truncated/torn ``state.npz``, a format-tag mismatch, missing
        manifest keys — raises ``SnapshotError``, and it is raised
        *before* the model object is built: both files are parsed fully
        up front, so a failed load cannot leave a half-restored model or
        touch any live service the caller keeps running."""
        # Parse-then-construct: read and validate EVERYTHING before
        # building the model, so failure here is side-effect free.
        try:
            with open(os.path.join(path, "manifest.json")) as f:
                manifest = json.load(f)
        except (OSError, json.JSONDecodeError, UnicodeDecodeError) as e:
            raise SnapshotError(f"{path}: unreadable manifest.json: {e}") \
                from e
        if not isinstance(manifest, dict) \
                or manifest.get("format") != SNAPSHOT_FORMAT:
            fmt = manifest.get("format") if isinstance(manifest, dict) \
                else type(manifest).__name__
            raise SnapshotError(
                f"{path}: unknown snapshot format {fmt!r} "
                f"(expected {SNAPSHOT_FORMAT!r})")
        try:
            config = DDCConfig.from_manifest(manifest["config"])
            state_manifest = manifest["state"]
        except (KeyError, TypeError, ValueError) as e:
            raise SnapshotError(f"{path}: malformed manifest.json: {e}") \
                from e
        try:
            with np.load(os.path.join(path, "state.npz")) as z:
                arrays = {k: z[k] for k in z.files}
        except (OSError, ValueError, KeyError, EOFError,
                zipfile.BadZipFile) as e:
            raise SnapshotError(
                f"{path}: truncated or corrupt state.npz: {e}") from e
        model = cls(config, meter=meter, faults=faults)
        try:
            model.backend.load_state(arrays, state_manifest)
        except (KeyError, TypeError, ValueError) as e:
            raise SnapshotError(
                f"{path}: snapshot state does not restore: {e}") from e
        return model

    # -- stream-backend introspection --------------------------------------

    @property
    def service(self):
        """The underlying service engine (stream/dist backends only) for
        callers that need engine internals (benchmarks, tests)."""
        return self.backend.service

"""Pluggable execution backends behind the `repro.ddc.DDC` facade.

A ``Backend`` executes the paper's two-phase pipeline for one deployment
style; the facade is backend-agnostic, which is the point — the paper's
contribution is communication-model-agnostic, so switching between the
host oracle, the jitted ``shard_map`` collectives, and the streaming
delta-merge engine must be a config knob, not a caller rewrite.

* ``host``   — wraps ``repro.core.ddc.ddc_host`` (NumPy, exact
  polygon-overlap merge): the paper-faithful oracle.
* ``jit``    — wraps ``repro.core.ddc.make_ddc_fn`` over a host mesh:
  phase 1 per lane, phase 2 across the configured collective schedule.
* ``stream`` — wraps ``repro.serve.ClusterService``: ring-buffer ingest,
  dirty-shard phase 1, exact delta-merge, TTL eviction, snapshots.
* ``dist``   — wraps ``repro.serve.DistClusterService``: the same
  streaming engine with every shard's buffers pinned to its own mesh
  device (shard_map ingest/evict/phase 1); only delta ClusterSets and
  slot-map rows cross the mesh axis, so its CommMeter counts are real
  transfer bytes, not a model (DESIGN.md §10).  Needs
  ``len(jax.devices()) >= shards``.

All four consume the same per-shard membership (the block
``np.array_split`` partition), so they produce the identical global
clustering (``repro.core.ddc.same_clustering``) — asserted by
``tests/test_ddc_api.py`` / ``tests/test_dist_backend.py`` on every
``PHASE2_LAYOUTS`` layout.

Batch backends (``host``, ``jit``) support ``partial_fit`` by buffering
per-shard points and lazily re-running the full pipeline on the next
read; the streaming backends (``stream``, ``dist``) repair the global
state incrementally and support TTL eviction (``expire``).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Type

import numpy as np

from repro.core import ddc as core_ddc
from repro.ddc.config import ConfigError, DDCConfig

BACKENDS: Dict[str, Type["Backend"]] = {}


def register_backend(name: str):
    """Class decorator: make ``name`` constructible via ``DDCConfig``."""
    def deco(cls):
        cls.name = name
        BACKENDS[name] = cls
        return cls
    return deco


def _query_nearest(q: np.ndarray, pts: np.ndarray, labels: np.ndarray,
                   eps: float, chunk: int = 512) -> np.ndarray:
    """DBSCAN's border rule against a frozen clustering: the label of the
    nearest *clustered* fitted point within ``eps``, else noise.  The
    same read-path semantics as ``ClusterService.query``."""
    out = np.full(len(q), -1, np.int32)
    keep = labels >= 0
    if not keep.any():
        return out
    ref = pts[keep].astype(np.float64)
    ref_lab = labels[keep]
    for off in range(0, len(q), chunk):
        block = q[off:off + chunk].astype(np.float64)
        d2 = ((block[:, None, :] - ref[None, :, :]) ** 2).sum(-1)
        j = np.argmin(d2, axis=1)
        hit = d2[np.arange(len(block)), j] <= eps * eps
        out[off:off + chunk] = np.where(hit, ref_lab[j], -1)
    return out


class Backend:
    """Execution-engine interface the facade drives (see module doc).

    ``faults`` (an optional ``repro.serve.FaultPlan``) arms the
    streaming engines' fault-injection seam for reproducible chaos
    runs; the batch backends accept and ignore it (they have no
    exchange to fault)."""

    name = "?"

    def __init__(self, cfg: DDCConfig,
                 meter: core_ddc.CommMeter | None = None,
                 faults=None):
        self.cfg = cfg
        self.meter = meter or core_ddc.CommMeter()
        self.faults = faults

    # write path
    def fit(self, points: np.ndarray, t: float | None = None) -> None:
        raise NotImplementedError

    def partial_fit(self, shard: int, batch: np.ndarray,
                    t: float | None = None) -> None:
        raise NotImplementedError

    def expire(self, t: float) -> int:
        raise ConfigError(
            f"TTL eviction needs a streaming backend ('stream' or "
            f"'dist'), not {self.name!r}")

    def tracks(self):
        """The last published ``TrackSnapshot`` (DESIGN.md §14)."""
        raise ConfigError(
            f"cluster tracking needs a streaming backend ('stream' or "
            f"'dist') with track=True, not {self.name!r}: tracking is a "
            f"fold over refresh generations, and the batch backends "
            f"have none")

    # read path
    def labels(self) -> np.ndarray:
        raise NotImplementedError

    def points(self) -> np.ndarray:
        raise NotImplementedError

    def query(self, points: np.ndarray, legacy: bool = False):
        """Label query points against the fitted clustering.  Returns a
        ``repro.serve.QueryResult`` (labels + snapshot version +
        degraded flag + routing + latency) that duck-types as the bare
        labels array; ``legacy=True`` returns the ndarray outright."""
        raise NotImplementedError

    # snapshot-versioned read path (DESIGN.md §12)
    def snapshot(self):
        """The last published immutable read view, or None."""
        raise NotImplementedError

    def read_snapshot(self):
        """Freshness-seeking read view: fold pending writes, then return
        the published snapshot (None for an empty model)."""
        raise NotImplementedError

    @property
    def quarantined(self) -> dict:
        """shard -> reason for currently quarantined shards ({} for the
        batch backends: they have no failure model)."""
        return {}

    @property
    def query_tier(self):
        """The backend's ``QueryTier``: the pipelined, coalescing,
        snapshot-serving read loop (built lazily from the config's
        queue_depth / query_bucket_min / max_staleness knobs)."""
        from repro.serve import query_tier as qt

        if getattr(self, "_tier", None) is None:
            self._tier = qt.QueryTier(
                self._tier_source(),
                max_queries=self.cfg.max_queries,
                queue_depth=self.cfg.queue_depth,
                bucket_min=self.cfg.query_bucket_min,
                max_staleness=self.cfg.max_staleness)
        return self._tier

    def _tier_source(self):
        """The snapshot source the tier reads (the backend itself for
        batch backends; the serve engine for stream/dist)."""
        return self

    def service_stats(self):
        """The typed ``ServiceStats`` contract (counters vs gauges),
        surfaced identically by every backend (DESIGN.md §12)."""
        raise NotImplementedError

    def comm_stats(self) -> dict:
        return {"backend": self.name} | self.meter.snapshot()

    # snapshot/restore
    def state(self) -> tuple[dict, dict]:
        """(arrays, manifest): everything needed to resume bit-identically."""
        raise NotImplementedError

    def load_state(self, arrays: dict, manifest: dict) -> None:
        raise NotImplementedError


class _BufferedBatchBackend(Backend):
    """Shared machinery for the batch backends: per-shard point buffers,
    lazy refit, block-partition bookkeeping."""

    def __init__(self, cfg: DDCConfig, meter=None, faults=None):
        super().__init__(cfg, meter, faults=faults)
        self._shard_pts: List[np.ndarray] = [
            np.zeros((0, 2), np.float32) for _ in range(cfg.shards)]
        self._labels: Optional[np.ndarray] = None
        self._snapshot = None
        self._snapshot_version = 0
        self.refits = 0           # monotonic: full-pipeline recomputes

    def fit(self, points: np.ndarray, t: float | None = None) -> None:
        pts = np.asarray(points, np.float32).reshape(-1, 2)
        parts = np.array_split(np.arange(len(pts)), self.cfg.shards)
        self._shard_pts = [pts[idx] for idx in parts]
        self._labels = None
        self._snapshot = None

    def partial_fit(self, shard, batch, t=None) -> None:
        if not 0 <= shard < self.cfg.shards:
            raise ConfigError(f"shard {shard} out of range [0, {self.cfg.shards})")
        batch = np.asarray(batch, np.float32).reshape(-1, 2)
        self._shard_pts[shard] = np.concatenate([self._shard_pts[shard], batch])
        self._labels = None
        self._snapshot = None

    def points(self) -> np.ndarray:
        return (np.concatenate(self._shard_pts) if any(len(p) for p in self._shard_pts)
                else np.zeros((0, 2), np.float32))

    def parts(self) -> List[np.ndarray]:
        out, base = [], 0
        for p in self._shard_pts:
            out.append(np.arange(base, base + len(p)))
            base += len(p)
        return out

    def labels(self) -> np.ndarray:
        if self._labels is None:
            self._labels = self._refit()
            self.refits += 1
        return self._labels

    def query(self, points: np.ndarray, legacy: bool = False):
        """Label queries via the published-snapshot path (the DESIGN.md
        §12 fix for the silent full-pipeline recompute per call): the
        first read after a write refits ONCE and publishes a snapshot;
        every further query is answered from it — O(points), one bounded
        batched kernel, no recompute (the ``refits`` counter proves it).
        """
        res = self.query_tier.query(points)
        return res.labels if legacy else res

    # -- snapshot publish (the batch edition of the serve engines') --------

    def snapshot(self):
        # A write since the last publish invalidates (fit/partial_fit
        # set _snapshot = None), so a held snapshot is never torn.
        return self._snapshot

    def read_snapshot(self):
        if not any(len(p) for p in self._shard_pts):
            return None
        if self._snapshot is None:
            self._publish_snapshot()
        return self._snapshot

    def _publish_snapshot(self):
        """Cut an immutable read view from the buffered shard points +
        (lazily recomputed) labels: pow2-padded (K, cap) buffers, global
        labels per slot, per-shard live bboxes — the same layout the
        serve engines publish, so one QueryTier serves all four
        backends bit-identically."""
        import jax.numpy as jnp

        from repro.serve import query_tier as qt

        labels = self.labels()          # refits at most once per write
        k = self.cfg.shards
        lens = [len(p) for p in self._shard_pts]
        cap = max(16, 1 << (max(lens) - 1).bit_length())
        pts = np.zeros((k, cap, 2), np.float32)
        mask = np.zeros((k, cap), bool)
        glab = np.full((k, cap), -1, np.int32)
        bboxes = []
        base = 0
        for s, p in enumerate(self._shard_pts):
            pts[s, :len(p)] = p
            mask[s, :len(p)] = True
            glab[s, :len(p)] = labels[base:base + len(p)]
            base += len(p)
            bboxes.append(
                (float(p[:, 0].min()), float(p[:, 1].min()),
                 float(p[:, 0].max()), float(p[:, 1].max()))
                if len(p) else None)
        self._snapshot_version += 1
        self._snapshot = qt.Snapshot(
            version=self._snapshot_version,
            epoch=self.refits,
            published_at=time.monotonic(),
            eps=float(self.cfg.eps),
            pts=jnp.asarray(pts), mask=jnp.asarray(mask),
            glabels=jnp.asarray(glab),
            bboxes=tuple(bboxes),
            quarantined=frozenset(),
            n_live=sum(lens),
            n_clusters=len(set(labels[labels >= 0].tolist())),
        )
        return self._snapshot

    def service_stats(self):
        from repro.serve import query_tier as qt

        tier = getattr(self, "_tier", None)
        tc = tier.counters() if tier is not None else {}
        labels = self.labels() if any(len(p) for p in self._shard_pts) \
            else np.zeros((0,), np.int32)
        counters = qt.ServiceCounters(
            refreshes=self.refits,
            refits=self.refits,
            snapshots_published=self._snapshot_version,
            queries_served=tc.get("queries_served", 0),
            query_launches=tc.get("query_launches", 0),
            coalesced_requests=tc.get("coalesced_requests", 0),
            query_rows=tc.get("query_rows", 0),
            deadline_misses=tc.get("deadline_misses", 0),
            degraded_queries=tc.get("degraded_queries", 0),
        )
        gauges = qt.ServiceGauges(
            shards=self.cfg.shards,
            capacity=int(self._snapshot.pts.shape[1])
            if self._snapshot is not None else 0,
            n_live=sum(len(p) for p in self._shard_pts),
            n_clusters=len(set(labels[labels >= 0].tolist())),
            snapshot_version=self._snapshot_version,
            snapshot_epoch=self._snapshot.epoch
            if self._snapshot is not None else 0,
            queue_pending=tier.pending if tier is not None else 0,
            jit_cache_entries=qt.snapshot_query_cache_entries(),
        )
        return qt.ServiceStats(backend=self.name, counters=counters,
                               gauges=gauges, comm=self.meter.snapshot())

    def _refit(self) -> np.ndarray:
        raise NotImplementedError

    def comm_stats(self) -> dict:
        self.labels()     # the meter fills when the (lazy) pipeline runs
        return super().comm_stats()

    def state(self) -> tuple[dict, dict]:
        arrays = {f"shard_{s}": p for s, p in enumerate(self._shard_pts)}
        arrays["labels"] = self.labels()
        return arrays, {"n_shards": self.cfg.shards}

    def load_state(self, arrays, manifest) -> None:
        self._shard_pts = [np.asarray(arrays[f"shard_{s}"], np.float32)
                           for s in range(int(manifest["n_shards"]))]
        self._labels = np.asarray(arrays["labels"], np.int32)
        self._snapshot = None


@register_backend("host")
class HostBackend(_BufferedBatchBackend):
    """Paper-faithful NumPy reference: per-partition ``dbscan_ref`` +
    exact polygon-overlap union-find (``ddc_host``, grid contours)."""

    def __init__(self, cfg: DDCConfig, meter=None, faults=None):
        super().__init__(cfg, meter, faults=faults)
        self._exchanged = 0

    def _refit(self) -> np.ndarray:
        pts = self.points()
        parts = self.parts()
        if len(pts) == 0:
            return np.zeros((0,), np.int32)
        labels, _, exchanged = core_ddc.ddc_host(
            pts, len(parts), self.cfg.eps, self.cfg.min_pts,
            partition=parts, contour="grid")
        self._exchanged = int(exchanged)
        # Contour vertices are the only phase-2 traffic (the 1–2 % claim):
        # each crosses once as an (x, y) f32 pair.
        self.meter.add_collective(1, self._exchanged * 8)
        self.meter.add_merge(len(parts), self.cfg.max_clusters)
        return labels.astype(np.int32)

    def comm_stats(self) -> dict:
        return super().comm_stats() | {"contour_vertices": self._exchanged}

    def state(self) -> tuple[dict, dict]:
        arrays, manifest = super().state()
        # labels() ran inside super().state(), so the counter is current;
        # a restored model must report it without re-running the fit.
        return arrays, manifest | {"exchanged": self._exchanged}

    def load_state(self, arrays, manifest) -> None:
        super().load_state(arrays, manifest)
        self._exchanged = int(manifest.get("exchanged", 0))


@register_backend("jit")
class JitBackend(_BufferedBatchBackend):
    """Jitted ``shard_map`` pipeline over a host mesh: zero-communication
    phase 1 per lane, then the configured collective schedule (sync
    all-gather / async butterfly / tree) for phase 2.

    Per-shard buffers are padded to a common static width so the mesh
    sees exactly the block partition the other backends use; the padding
    mask keeps padded rows out of phase 1.
    """

    def __init__(self, cfg: DDCConfig, meter=None, faults=None):
        super().__init__(cfg, meter, faults=faults)
        self._runners: dict = {}

    def make_runner(self, n_points: int):
        """The jitted distributed entry point for ``n_points`` inputs
        ((n, 2) + (n,) mask, sharded over the mesh).  Exposed for the
        benchmarks/dry-runs that lower + compile it explicitly;
        ``n_points`` must be a multiple of ``shards``."""
        import jax

        from repro.launch import mesh as mesh_mod

        k = self.cfg.shards
        if n_points % k:
            raise ConfigError(f"n_points {n_points} not a multiple of shards {k}")
        if len(jax.devices()) < k:
            raise ConfigError(
                f"jit backend needs >= {k} devices but jax sees "
                f"{len(jax.devices())}; set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={k} before jax "
                f"initialises (or lower shards)")
        key = n_points
        if key not in self._runners:
            if len(self._runners) >= 4:   # drop stale executables: every
                self._runners.clear()     # distinct width is a recompile
            mesh = mesh_mod.make_host_mesh(k)
            self._runners[key] = core_ddc.make_ddc_fn(
                mesh, "data", self.cfg.core(), self.meter)
        return self._runners[key]

    def _refit(self) -> np.ndarray:
        import jax.numpy as jnp

        k = self.cfg.shards
        lens = [len(p) for p in self._shard_pts]
        if sum(lens) == 0:
            return np.zeros((0,), np.int32)
        # Round the padded width up so a partial_fit-driven trickle of
        # growth re-uses one compiled program instead of recompiling the
        # whole shard_map pipeline at every new max-shard length.
        cap = max(lens)
        cap = max(16, 1 << (cap - 1).bit_length())
        padded = np.zeros((k, cap, 2), np.float32)
        mask = np.zeros((k, cap), bool)
        for s, p in enumerate(self._shard_pts):
            padded[s, :len(p)] = p
            mask[s, :len(p)] = True
        run = self.make_runner(k * cap)
        glabels, _, _ = run(
            jnp.asarray(padded.reshape(k * cap, 2)),
            jnp.asarray(mask.reshape(k * cap)))
        flat = np.asarray(glabels).reshape(k, cap)
        return np.concatenate(
            [flat[s, :n] for s, n in enumerate(lens)]).astype(np.int32)


@register_backend("stream")
class StreamBackend(Backend):
    """The online serve engine: ring-buffer ingest, dirty-shard phase 1,
    exact delta-merge, bbox-routed point queries, TTL eviction, and
    bit-identical snapshot/restore.  ``fit`` streams the batch in;
    ``partial_fit`` is the native write path."""

    def __init__(self, cfg: DDCConfig, meter=None, faults=None):
        super().__init__(cfg, meter, faults=faults)
        self._svc = None

    @classmethod
    def _svc_cls(cls):
        from repro.serve import ClusterService

        return ClusterService

    @property
    def service(self):
        """The underlying service engine (lazily built: the ring
        capacity may be sized by the first ``fit``)."""
        if self._svc is None:
            if self.cfg.capacity is None:
                raise ConfigError(
                    f"backend={self.name!r} with partial_fit before fit "
                    f"needs an explicit capacity in DDCConfig (fit() would "
                    f"size it from the batch)")
            self._svc = self._build(self.cfg.capacity)
        return self._svc

    def _stream_config(self, capacity: int):
        from repro.serve import StreamConfig

        return StreamConfig(
            shards=self.cfg.shards, capacity=capacity,
            max_batch=min(self.cfg.max_batch, capacity),
            max_queries=self.cfg.max_queries,
            merge_mode=self.cfg.merge_mode,
            max_retries=self.cfg.max_retries,
            retry_backoff=self.cfg.retry_backoff,
            journal_limit=self.cfg.journal_limit,
            agg_degree=self.cfg.agg_degree,
            track=self.cfg.track,
            track_history=self.cfg.track_history,
            match_min_overlap=self.cfg.match_min_overlap,
            ddc=self.cfg.core())

    def _build(self, capacity: int):
        return self._svc_cls()(self._stream_config(capacity),
                               meter=self.meter, faults=self.faults)

    def fit(self, points: np.ndarray, t: float | None = None) -> None:
        from repro.data import spatial

        pts = np.asarray(points, np.float32).reshape(-1, 2)
        k = self.cfg.shards
        cap = self.cfg.capacity or spatial.shard_capacity(len(pts), k)
        self._svc = self._build(cap)
        batch = min(self.cfg.max_batch, cap)
        for shard, chunk in spatial.stream_batches(pts, k, batch):
            self._svc.ingest(shard, chunk, t=t)
        self._svc.refresh()

    def partial_fit(self, shard, batch, t=None) -> None:
        self.service.ingest(shard, batch, t=t)

    def expire(self, t: float) -> int:
        return sum(self.service.evict_older_than(s, t)
                   for s in range(self.cfg.shards))

    def tracks(self):
        if not self.cfg.track:
            raise ConfigError(
                "cluster tracking is disabled for this model; construct "
                "with DDCConfig(track=True, backend='stream'|'dist') to "
                "assign stable track IDs at refresh")
        # Freshness-seeking like read_snapshot: fold pending writes so
        # the returned TrackSnapshot reflects everything ingested.
        self.service.read_snapshot()
        return self.service.track_snapshot()

    def labels(self) -> np.ndarray:
        _, _, labels = self.service.live()
        return labels

    def points(self) -> np.ndarray:
        pts, _, _ = self.service.live()
        return pts

    def parts(self) -> List[np.ndarray]:
        _, parts, _ = self.service.live()
        return parts

    def query(self, points: np.ndarray, legacy: bool = False):
        return self.service.query(points, legacy=legacy)

    # -- snapshot-versioned reads (delegate to the serve engine) -----------

    def snapshot(self):
        return self._svc.snapshot() if self._svc is not None else None

    def read_snapshot(self):
        if self._svc is None and self.cfg.capacity is None:
            return None          # nothing fitted, nothing to publish
        return self.service.read_snapshot()

    @property
    def quarantined(self) -> dict:
        return self._svc.quarantined if self._svc is not None else {}

    def service_stats(self):
        from repro.serve import query_tier as qt

        tier = getattr(self, "_tier", None)
        if self._svc is None:
            return qt.ServiceStats(
                backend=self.name, counters=qt.ServiceCounters(),
                gauges=qt.ServiceGauges(shards=self.cfg.shards),
                comm=self.meter.snapshot())
        return self.service.service_stats(tier=tier)

    def comm_stats(self) -> dict:
        # Derived from the typed contract so the dict view can't drift;
        # same flat shape as ever (backend tag + service stats + meter).
        if self._svc is None:
            return {"backend": self.name} | self.meter.snapshot()
        return self.service_stats().comm_dict()

    def state(self) -> tuple[dict, dict]:
        return self.service.state_dict()

    def load_state(self, arrays, manifest) -> None:
        from repro.serve import StreamConfig

        scfg = StreamConfig(
            shards=int(manifest["shards"]),
            capacity=int(manifest["capacity"]),
            max_batch=int(manifest["max_batch"]),
            max_queries=int(manifest["max_queries"]),
            merge_mode=manifest["merge_mode"],
            max_retries=int(manifest.get("max_retries",
                                         self.cfg.max_retries)),
            retry_backoff=float(manifest.get("retry_backoff",
                                             self.cfg.retry_backoff)),
            journal_limit=int(manifest.get("journal_limit",
                                           self.cfg.journal_limit)),
            agg_degree=manifest.get("agg_degree", self.cfg.agg_degree),
            track=bool(manifest.get("track", self.cfg.track)),
            track_history=int(manifest.get("track_history",
                                           self.cfg.track_history)),
            match_min_overlap=float(manifest.get("match_min_overlap",
                                                 self.cfg.match_min_overlap)),
            ddc=self.cfg.core())
        self._svc = self._svc_cls().from_state(
            scfg, arrays, manifest, meter=self.meter, faults=self.faults)


@register_backend("dist")
class DistBackend(StreamBackend):
    """The device-resident streaming engine: the ``stream`` control
    plane over a ``shard_map`` data plane that pins each shard's ring
    buffers to its own mesh device.  Ingest/evict/dirty-shard phase 1
    run lane-local; only delta ClusterSets (up) and slot-map rows
    (down) cross the mesh axis, so ``comm_stats()`` reports *real*
    axis-crossing bytes.  Bit-identical to ``stream`` (and ``host``) on
    the same call sequence; snapshots are interchangeable with the
    ``stream`` backend's.  Requires ``len(jax.devices()) >= shards``
    (``XLA_FLAGS=--xla_force_host_platform_device_count=K`` on CPU)."""

    @classmethod
    def _svc_cls(cls):
        from repro.serve import DistClusterService

        return DistClusterService

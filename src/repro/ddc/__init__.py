"""Public DDC API: one estimator facade, pluggable backends.

    from repro.ddc import DDC, DDCConfig
    model = DDC(DDCConfig(backend="stream", shards=8, capacity=4096))

The implementation primitives stay importable where they always lived —
``repro.core.ddc`` (ddc_host, make_ddc_fn, merge_many, …) and
``repro.serve`` (ClusterService) — and the facade delegates to them;
they are re-exported here for discoverability.  New call sites should
go through ``DDC``.
"""
from repro.core.ddc import (
    ClusterSet,
    CommMeter,
    ddc_host,
    make_ddc_fn,
    same_clustering,
)
from repro.ddc.api import DDC, SNAPSHOT_FORMAT, SnapshotError
from repro.ddc.backends import BACKENDS, Backend, register_backend
from repro.ddc.config import ConfigError, DDCConfig
from repro.serve.query_tier import (
    QueryResult,
    QueryTier,
    QueueFull,
    ServiceCounters,
    ServiceGauges,
    ServiceStats,
    Snapshot,
)

__all__ = [
    "DDC", "DDCConfig", "ConfigError", "SNAPSHOT_FORMAT", "SnapshotError",
    "BACKENDS", "Backend", "register_backend",
    "ClusterSet", "CommMeter", "ddc_host", "make_ddc_fn",
    "same_clustering",
    "QueryResult", "QueryTier", "QueueFull", "Snapshot",
    "ServiceStats", "ServiceCounters", "ServiceGauges",
]

"""Validated deployment configuration for the `repro.ddc` facade.

One config describes the *whole* deployment — the phase-1/phase-2 math
(mirroring ``repro.core.ddc.DDCConfig``), the backend that executes it
(``host`` | ``jit`` | ``stream`` | ``dist``), and the streaming-engine
knobs.  The
point of the split from the core config is ``validate()``: every
backend/schedule compatibility rule and the DESIGN.md §7 sizing rule is
checked when the config is built, not discovered as a silent cluster
unmapping (or a trace-time assert) deep inside a distributed run.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core import dbscan as dbscan_mod
from repro.core import ddc as core_ddc
from repro.core import geometry

SCHEDULES = ("sync", "async", "tree")
LOCAL_ALGOS = ("dbscan", "kmeans")
MERGE_MODES = ("delta", "full")


class ConfigError(ValueError):
    """A DDCConfig that cannot run correctly on its chosen backend."""


@dataclasses.dataclass(frozen=True)
class DDCConfig:
    """Estimator-facade configuration (hashable; see ``validate``).

    Clustering math (forwarded verbatim to ``repro.core.ddc.DDCConfig``):
    ``eps``..``block_tile``.  Deployment: ``backend`` picks the execution
    engine, ``shards`` the partition width.  Streaming-only knobs
    (``capacity``..``merge_mode``) configure the serve engine and are
    ignored by the batch backends.
    """

    eps: float = 0.05
    min_pts: int = 5
    bounds: Tuple[float, float, float, float] = (0.0, 0.0, 1.0, 1.0)
    grid: int = 128
    max_clusters: int = 32
    max_verts: int = 128
    merge_eps: Optional[float] = None
    local_algo: str = "dbscan"
    kmeans_k: int = 8
    schedule: str = "async"
    tree_degree: int = 2
    merge_refine: str = "grid"
    block_sparse: str = "auto"
    block_tile: int = 512

    backend: str = "host"
    shards: int = 4

    capacity: Optional[int] = None   # per-shard ring slots; None: sized at fit()
    max_batch: int = 256
    max_queries: int = 256
    merge_mode: str = "delta"
    max_retries: int = 2             # delta re-deliveries per refresh
    retry_backoff: float = 0.0       # seconds; doubles per retry round
    journal_limit: int = 1024        # per-shard WAL entries before compaction
    agg_degree: Optional[int] = None  # None: flat aggregator; >=2: the
    #                                  DESIGN §13 tree-of-aggregators fan-in

    # Cluster tracking knobs (DESIGN.md §14; stream/dist backends).
    track: bool = False              # fold stable track IDs at refresh
    track_history: int = 16          # per-track motion-history ring length
    match_min_overlap: float = 0.0   # tighten the match gate: d2 <=
    #                                  r²·(1-overlap), r = merge radius

    # Query-tier knobs (DESIGN.md §12; all backends).
    queue_depth: int = 64            # bounded request queue (backpressure)
    query_bucket_min: int = 16       # smallest pow2 query-width bucket
    max_staleness: Optional[float] = None   # seconds a snapshot may serve;
    #                                  None: always fresh (refresh-on-read),
    #                                  inf: never refresh (pure snapshot reads)

    _CORE_FIELDS = ("eps", "min_pts", "bounds", "grid", "max_clusters",
                    "max_verts", "merge_eps", "local_algo", "kmeans_k",
                    "schedule", "tree_degree", "merge_refine",
                    "block_sparse", "block_tile")

    def core(self) -> core_ddc.DDCConfig:
        """The jit-static core config this deployment config wraps."""
        kw = {f: getattr(self, f) for f in self._CORE_FIELDS}
        kw["bounds"] = tuple(kw["bounds"])
        return core_ddc.DDCConfig(**kw)

    def to_manifest(self) -> dict:
        """JSON-serialisable field dict (snapshot manifests)."""
        out = dataclasses.asdict(self)
        out["bounds"] = list(self.bounds)
        return out

    @classmethod
    def from_manifest(cls, doc: dict) -> "DDCConfig":
        kw = dict(doc)
        kw["bounds"] = tuple(kw["bounds"])
        return cls(**kw)

    # -- the validated-construction contract -------------------------------

    def validate(self, sample: np.ndarray | None = None) -> "DDCConfig":
        """Check every statically decidable correctness rule; returns self.

        Raises ``ConfigError`` on: malformed math parameters, an
        unregistered backend, a schedule the chosen backend cannot run
        (the async butterfly needs power-of-two shards), or streaming
        knobs that would corrupt the ring buffers.

        With ``sample`` (a representative (n, 2) point set) it also runs
        the DESIGN.md §7 sizing probe: sequential DBSCAN on the sample,
        then the occupancy-grid contour of every *global* (i.e. merged)
        cluster must fit ``max_verts``, and the global cluster count must
        fit ``max_clusters``.  This is the check that used to fail only
        as silently unmapped clusters inside ``match_to_global`` at
        runtime.
        """
        self._check_math()
        self._check_deployment()
        if sample is not None:
            self._check_sizing(np.asarray(sample, np.float64).reshape(-1, 2))
        return self

    def _check_math(self) -> None:
        x0, y0, x1, y1 = self.bounds
        if not (x1 > x0 and y1 > y0):
            raise ConfigError(f"degenerate bounds {self.bounds}")
        if not self.eps > 0:
            raise ConfigError(f"eps must be > 0, got {self.eps}")
        if self.merge_eps is not None and not self.merge_eps > 0:
            raise ConfigError(f"merge_eps must be > 0, got {self.merge_eps}")
        if self.min_pts < 1:
            raise ConfigError(f"min_pts must be >= 1, got {self.min_pts}")
        if self.grid < 2:
            raise ConfigError(f"grid must be >= 2, got {self.grid}")
        if self.max_clusters < 1 or self.max_verts < 4:
            raise ConfigError(
                f"cluster/vertex budgets too small: C={self.max_clusters}, "
                f"V={self.max_verts}")
        if self.local_algo not in LOCAL_ALGOS:
            raise ConfigError(f"unknown local_algo {self.local_algo!r}")
        if self.local_algo == "kmeans" and self.kmeans_k < 1:
            raise ConfigError(f"kmeans_k must be >= 1, got {self.kmeans_k}")
        if self.schedule not in SCHEDULES:
            raise ConfigError(
                f"unknown schedule {self.schedule!r}; pick one of {SCHEDULES}")
        if self.tree_degree < 2:
            raise ConfigError(f"tree_degree must be >= 2, got {self.tree_degree}")
        if self.merge_refine not in ("grid", "fps"):
            raise ConfigError(f"unknown merge_refine {self.merge_refine!r}")

    def _check_deployment(self) -> None:
        from repro.ddc import backends   # late: backends imports this module

        if self.backend not in backends.BACKENDS:
            raise ConfigError(
                f"unknown backend {self.backend!r}; registered: "
                f"{sorted(backends.BACKENDS)}")
        if self.shards < 1:
            raise ConfigError(f"shards must be >= 1, got {self.shards}")
        if self.backend == "jit" and self.schedule == "async" \
                and self.shards & (self.shards - 1):
            raise ConfigError(
                f"the async butterfly schedule needs a power-of-two shard "
                f"count, got shards={self.shards}; use schedule='sync' or "
                f"'tree', or round shards to a power of two")
        if self.backend == "dist":
            # The dist data plane lays one shard per mesh device; the
            # mesh-vs-shards rule (and its fix-it message) lives in the
            # data-plane module — surface it as a ConfigError here.
            from repro.serve import dist_service

            try:
                dist_service.require_devices(self.shards)
            except ValueError as e:
                raise ConfigError(str(e)) from None
        if self.merge_mode not in MERGE_MODES:
            raise ConfigError(f"unknown merge_mode {self.merge_mode!r}")
        if self.max_batch < 1 or self.max_queries < 1:
            raise ConfigError(
                f"max_batch/max_queries must be >= 1, got "
                f"{self.max_batch}/{self.max_queries}")
        if self.capacity is not None and self.capacity < self.max_batch:
            raise ConfigError(
                f"capacity {self.capacity} < max_batch {self.max_batch}: an "
                f"append chunk could overwrite itself in the ring scatter")
        if self.max_retries < 0:
            raise ConfigError(
                f"max_retries must be >= 0, got {self.max_retries}")
        if self.retry_backoff < 0:
            raise ConfigError(
                f"retry_backoff must be >= 0, got {self.retry_backoff}")
        if self.journal_limit < 1:
            raise ConfigError(
                f"journal_limit must be >= 1, got {self.journal_limit}")
        if self.agg_degree is not None:
            if self.backend not in ("stream", "dist"):
                raise ConfigError(
                    f"agg_degree (the hierarchical aggregator tree, DESIGN "
                    f"§13) only applies to the serving backends, got "
                    f"backend={self.backend!r}; batch backends use "
                    f"schedule='tree' + tree_degree instead")
            if self.agg_degree < 2:
                raise ConfigError(
                    f"agg_degree must be >= 2 (a degree-1 tree is an "
                    f"infinite chain of no-op folds), got {self.agg_degree}")
            if self.agg_degree & (self.agg_degree - 1):
                raise ConfigError(
                    f"agg_degree must be a power of two, got "
                    f"{self.agg_degree}: node caches patch dirty child rows "
                    f"through pow2-padded updates, and a pow2 fan-in keeps "
                    f"every level's jit compilation count bounded")
        if self.track and self.backend not in ("stream", "dist"):
            raise ConfigError(
                f"track=True (the cluster tracking subsystem, DESIGN §14) "
                f"needs a streaming backend ('stream' or 'dist'), got "
                f"backend={self.backend!r}: tracking is a fold over refresh "
                f"generations, and the batch backends have none")
        if self.track_history < 2:
            raise ConfigError(
                f"track_history must be >= 2 (velocity needs two history "
                f"samples), got {self.track_history}")
        if not 0.0 <= self.match_min_overlap < 1.0:
            raise ConfigError(
                f"match_min_overlap must be in [0, 1) (1 would demand "
                f"exactly-zero contour distance), got "
                f"{self.match_min_overlap}")
        if self.queue_depth < 1:
            raise ConfigError(
                f"queue_depth must be >= 1, got {self.queue_depth}")
        if self.query_bucket_min < 1 \
                or self.query_bucket_min > self.max_queries:
            raise ConfigError(
                f"query_bucket_min must be in [1, max_queries="
                f"{self.max_queries}], got {self.query_bucket_min}")
        if self.query_bucket_min & (self.query_bucket_min - 1):
            raise ConfigError(
                f"query_bucket_min must be a power of two (it is the "
                f"smallest jit shape bucket), got {self.query_bucket_min}")
        if self.max_staleness is not None and self.max_staleness < 0:
            raise ConfigError(
                f"max_staleness must be >= 0 (or None for always-fresh), "
                f"got {self.max_staleness}")

    def _check_sizing(self, sample: np.ndarray) -> None:
        labels = dbscan_mod.dbscan_ref(sample, self.eps, self.min_pts)
        ids = sorted(set(labels[labels >= 0].tolist()))
        if len(ids) > self.max_clusters:
            raise ConfigError(
                f"sizing probe: the sample holds {len(ids)} global clusters "
                f"but max_clusters={self.max_clusters}; the merge would "
                f"overflow the slot budget (DESIGN.md §7)")
        for cid in ids:
            occ = len(geometry.grid_contour_np(
                sample[labels == cid], tuple(self.bounds), self.grid))
            if occ > self.max_verts:
                raise ConfigError(
                    f"sizing probe: the merged contour of cluster {cid} "
                    f"occupies {occ} boundary cells at grid={self.grid} but "
                    f"max_verts={self.max_verts}; a truncated global outline "
                    f"silently unmaps distant fragments in match_to_global "
                    f"(DESIGN.md §7) — raise max_verts or coarsen grid")

"""Serving drivers.

Two modes behind one entry point:

* ``--mode lm`` (default) — batched LM request loop over prefill + decode.
* ``--mode ddc`` — the streaming spatial-clustering service: ingest a
  synthetic layout shard-by-shard with an incremental delta-merge
  refresh after every batch, then serve point->cluster queries.
* ``--mode track`` — the cluster-tracking subsystem (DESIGN.md §14):
  play a seeded trajectory stream (``--layout`` from
  ``TRAJECTORY_LAYOUTS``, default ``drifting_blobs``) through a
  ``track=True`` deployment with sliding-window eviction, then print
  the per-track IDs, velocities/headings, motion classes, and the
  lifecycle-event census as a JSON line.
  ``--backend stream`` (default) is the host-driven engine
  (serve/cluster_service.py); ``--backend dist`` pins each shard's
  buffers to its own mesh device (serve/dist_service.py) so the printed
  comm volume is real axis-crossing bytes.  Prints a JSON line of
  ingest/query latency, delta-path comm volume, and query-routing
  counters.

  ``--qps-requests N`` appends the pipelined high-QPS request loop
  (DESIGN.md §12): N requests flow through the bounded ``QueryTier``
  queue — coalesced into batched snapshot reads — while the tail of the
  ingest stream keeps writing and republishing under them, so the
  printed p50/p99/QPS measures decoupled snapshot serving, not
  refresh-blocked reads.

CPU-scale examples:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --tiny \
      --requests 4 --prompt-len 32 --gen 16
  PYTHONPATH=src python -m repro.launch.serve --mode ddc --layout rings \
      --shards 8 --queries 512
  PYTHONPATH=src python -m repro.launch.serve --mode ddc --backend dist \
      --shards 8 --qps-requests 64 --deadline-ms 50
  PYTHONPATH=src python -m repro.launch.serve --mode track \
      --layout merging_crowds --shards 4
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

# One source of truth for the ddc-mode defaults: the pre-jax-init
# device-count pass below and main()'s real parser must never drift.
DEF_BACKEND = "stream"
DEF_SHARDS = 4

# --backend dist pins one shard per device: the CPU device count must be
# forced before jax initialises, i.e. before the import below runs.
if __name__ == "__main__":
    _pre = argparse.ArgumentParser(add_help=False)
    _pre.add_argument("--backend", default=DEF_BACKEND)
    _pre.add_argument("--shards", type=int, default=DEF_SHARDS)
    _ns, _ = _pre.parse_known_args(sys.argv[1:])
    if _ns.backend == "dist":
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={_ns.shards}"
        ).strip()

import jax
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("lm", "ddc", "track"), default="lm")
    # LM mode
    ap.add_argument("--arch")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--mesh-devices", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    # DDC streaming mode
    ap.add_argument("--layout", default="rings",
                    help="a data/spatial.py PHASE2_LAYOUTS name (--mode "
                         "ddc) or TRAJECTORY_LAYOUTS name (--mode track, "
                         "default drifting_blobs)")
    ap.add_argument("--backend", choices=("stream", "dist"),
                    default=DEF_BACKEND,
                    help="host-driven or device-resident serve engine")
    ap.add_argument("--shards", type=int, default=DEF_SHARDS)
    ap.add_argument("--n", type=int, default=2048)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--fault-seed", type=int, default=None,
                    help="arm a seeded FaultPlan.random against the serve "
                         "engine (chaos drill; DESIGN.md §11)")
    ap.add_argument("--faults", type=int, default=3,
                    help="number of injected fault events (--fault-seed)")
    # DDC high-QPS request loop (DESIGN.md §12)
    ap.add_argument("--qps-requests", type=int, default=0,
                    help="run N requests through the pipelined QueryTier "
                         "loop, interleaved with ingest (0: skip)")
    ap.add_argument("--request-points", type=int, default=32,
                    help="query points per pipelined request")
    ap.add_argument("--queue-depth", type=int, default=64,
                    help="bounded request-queue depth (backpressure)")
    ap.add_argument("--max-staleness", default="inf",
                    help="seconds a published snapshot may keep serving "
                         "('inf': never refresh mid-loop, 'none': fold "
                         "pending writes before every drain)")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="per-request deadline; misses are counted (and "
                         "still answered) (0: no deadline)")
    # DDC tracking mode (DESIGN.md §14)
    ap.add_argument("--steps", type=int, default=0,
                    help="trajectory frames to play (--mode track; "
                         "0: the layout's default)")
    args = ap.parse_args(argv)
    if args.mode == "ddc":
        return serve_ddc(args)
    if args.mode == "track":
        return serve_track(args)
    if not args.arch:
        ap.error("--arch is required for --mode lm")
    return serve_lm(args)


def serve_ddc(args):
    from repro.data import spatial
    from repro.ddc import DDC, CommMeter, DDCConfig
    from repro.serve import faults as faults_mod

    spec = spatial.PHASE2_LAYOUTS[args.layout]
    pts = spec["make"](args.n)
    cap = spatial.shard_capacity(args.n, args.shards)
    staleness = None if str(args.max_staleness).lower() == "none" \
        else float(args.max_staleness)
    cfg = DDCConfig(
        eps=spec["eps"], min_pts=spec["min_pts"], grid=spec["grid"],
        max_clusters=spec["max_clusters"], max_verts=spec["max_verts"],
        backend=args.backend, shards=args.shards, capacity=cap,
        max_batch=min(args.batch, cap), max_queries=args.queries,
        queue_depth=args.queue_depth, max_staleness=staleness,
    ).validate()
    meter = CommMeter()
    plan = None
    if args.fault_seed is not None:
        plan = faults_mod.FaultPlan.random(
            seed=args.fault_seed, shards=args.shards, n_faults=args.faults)
    model = DDC(cfg, meter=meter, faults=plan)

    # With a request loop armed, hold back the stream's tail so writes
    # keep landing (and republishing snapshots) UNDER the readers.
    batches = list(spatial.stream_batches(pts, args.shards, cfg.max_batch))
    n_held = 0
    if args.qps_requests > 0:
        n_held = min(len(batches) - 1, max(args.shards, 2))
    head, held = batches[:len(batches) - n_held], batches[len(batches) - n_held:]

    t0 = time.time()
    n_batches = 0
    for shard, chunk in head:
        model.partial_fit(shard, chunk)
        model.service.refresh()
        n_batches += 1
    ingest_s = time.time() - t0

    recovered = []
    if plan is not None:
        # Chaos drill epilogue: rejoin every quarantined shard and fold
        # the replayed state back in before measuring queries.
        recovered = model.service.recover_all()
        model.service.refresh()

    rng = np.random.default_rng(args.seed)
    q = rng.uniform(0, 1, (args.queries, 2)).astype(np.float32)
    model.query(q[:1])         # compile
    t0 = time.time()
    labels = model.query(q)
    query_s = time.time() - t0

    qps_out = {}
    if args.qps_requests > 0:
        qps_out = _request_loop(model, held, args, rng)

    stats = model.service.stats()
    out = model.comm_stats() | {
        "mode": "ddc",
        "layout": args.layout,
        "ingest_batches": n_batches,
        "ingest_ms_per_batch": round(ingest_s / max(n_batches, 1) * 1e3, 2),
        "query_ms": round(query_s * 1e3, 2),
        "query_clustered_frac": round(float(np.mean(labels >= 0)), 3),
        "query_version": labels.version,
        "refreshes": stats["refreshes"],
        "retries": stats["retries"],
        "quarantined_shards": stats["quarantined_shards"],
        "quarantined_now": stats["quarantined_now"],
        "fenced_deltas": stats["fenced_deltas"],
        "degraded_queries": stats["degraded_queries"],
        "journal_entries": stats["journal_entries"],
    } | qps_out
    if args.fault_seed is not None:
        out["fault_seed"] = args.fault_seed
        out["recovered_shards"] = recovered
    print(json.dumps(out))
    return out


def serve_track(args):
    """The cluster-tracking driver (DESIGN.md §14): play a seeded
    trajectory stream through a ``track=True`` deployment — one tracked
    refresh per frame, sliding-window eviction — then print the live
    tracks (ID, velocity, heading, motion class) and the lifecycle
    event census as one JSON line."""
    from repro.data import spatial
    from repro.ddc import DDC, DDCConfig
    from repro.serve import tracking

    layout = args.layout
    if layout not in spatial.TRAJECTORY_LAYOUTS:
        if layout != "rings":      # the --mode ddc default, not a choice
            raise SystemExit(
                f"--mode track needs a TRAJECTORY_LAYOUTS name "
                f"{sorted(spatial.TRAJECTORY_LAYOUTS)}, got {layout!r}")
        layout = "drifting_blobs"
    spec = spatial.TRAJECTORY_LAYOUTS[layout]
    steps = args.steps or spec["steps"]
    traj = spec["make"](steps=steps, n_per_step=spec["n_per_step"])
    cap = spatial.trajectory_capacity(
        spec["n_per_step"], spec["window"], args.shards)
    cfg = DDCConfig(
        eps=spec["eps"], min_pts=spec["min_pts"], grid=spec["grid"],
        max_clusters=spec["max_clusters"], max_verts=spec["max_verts"],
        backend=args.backend, shards=args.shards, capacity=cap,
        max_batch=min(256, cap), track=True,
    ).validate()
    model = DDC(cfg)

    t0 = time.time()
    snap = tracking.play(model, traj.frames, window=spec["window"])
    wall_s = time.time() - t0

    tracker = model.service.tracker
    out = {
        "mode": "track",
        "layout": layout,
        "backend": args.backend,
        "shards": args.shards,
        "generations": snap.generation,
        "snapshot_version": snap.version,
        "births": snap.births,
        "deaths": snap.deaths,
        "merges": snap.merges,
        "splits": snap.splits,
        "continuations": snap.continuations,
        "match_ms_per_refresh": round(
            tracker.update_ms_total / max(snap.generation, 1), 3),
        "wall_ms_per_frame": round(wall_s / steps * 1e3, 2),
        "tracks": [{
            "id": t.track_id,
            "size": t.size,
            "centroid": [round(c, 4) for c in t.centroid],
            "speed": round(t.speed, 5),
            "heading_deg": round(t.heading_deg, 1),
            "motion": t.motion,
        } for t in snap.alive],
    }
    print(json.dumps(out))
    return out


def _request_loop(model, writes, args, rng):
    """The pipelined high-QPS loop (DESIGN.md §12): requests enter the
    bounded ``QueryTier`` queue with per-request deadlines and are
    answered in coalesced batched launches from the last published
    snapshot, while held-back ingest batches keep writing (and
    republishing new versions) underneath."""
    from repro.serve import QueueFull

    tier = model.query_tier
    writes = list(writes)
    deadline_s = args.deadline_ms / 1e3 if args.deadline_ms > 0 else None

    def one_request():
        return rng.uniform(0, 1, (args.request_points, 2)).astype(np.float32)

    tier.query(one_request())   # compile the bucketed kernel up front
    pending = []
    t0 = time.time()
    for r in range(args.qps_requests):
        cutoff = (time.monotonic() + deadline_s) if deadline_s else None
        try:
            pending.append(tier.submit(one_request(), deadline=cutoff))
        except QueueFull:
            tier.drain()
            pending.append(tier.submit(one_request(), deadline=cutoff))
        if writes and r % 4 == 1:
            # A write + republish lands under the readers: the next
            # drain serves the new version, never a torn intermediate.
            shard, chunk = writes.pop(0)
            model.partial_fit(shard, chunk)
            model.service.refresh()
        if r % 8 == 7:
            tier.drain()
    for shard, chunk in writes:   # drain any leftover held-back ingest
        model.partial_fit(shard, chunk)
        model.service.refresh()
    tier.drain()
    wall = time.time() - t0

    lat = np.array([p.result.latency_ms for p in pending])
    c = tier.counters()
    return {
        "qps_requests": len(pending),
        "qps": round(len(pending) / wall, 1),
        "p50_ms": round(float(np.percentile(lat, 50)), 3),
        "p99_ms": round(float(np.percentile(lat, 99)), 3),
        "versions_served": len({p.result.version for p in pending}),
        "query_launches": c["query_launches"],
        "coalesced_requests": c["coalesced_requests"],
        "deadline_misses": c["deadline_misses"],
        "queue_depth": tier.queue_depth,
    }


def serve_lm(args):
    from repro import configs
    from repro.launch import mesh as mesh_mod
    from repro.parallel import api as par
    from repro.serve import engine

    cfg = configs.get_config(args.arch)
    if args.tiny:
        cfg = cfg.tiny()
    n = args.mesh_devices or len(jax.devices())
    mesh = mesh_mod.make_host_mesh(n) if n > 1 else None
    pctx = par.ParallelCtx(mesh=mesh)

    key = jax.random.PRNGKey(args.seed)
    params = __import__("repro.models.transformer", fromlist=["x"]).init_params(cfg, key)
    scfg = engine.ServeConfig(max_len=args.prompt_len + args.gen + cfg.prefix_len)

    prompts = jax.random.randint(key, (args.requests, args.prompt_len), 0, cfg.vocab)
    kw = {}
    if cfg.frontend == "audio_stub":
        kw["frames"] = jax.random.normal(
            key, (args.requests, cfg.frontend_seq, cfg.d_model)) * 0.1
    if cfg.prefix_len:
        kw["prefix"] = jax.random.normal(
            key, (args.requests, cfg.prefix_len, cfg.d_model)) * 0.1

    t0 = time.time()
    out = engine.greedy_generate(
        cfg, params, prompts, args.gen, scfg, pctx,
        temperature=args.temperature, key=key if args.temperature > 0 else None,
        **kw,
    )
    dt = time.time() - t0
    toks = args.requests * args.gen
    print(json.dumps({
        "requests": args.requests,
        "generated_tokens": toks,
        "wall_s": round(dt, 3),
        "tok_per_s": round(toks / dt, 2),
        "sample_output": np.asarray(out[0][:8]).tolist(),
    }))
    return out


if __name__ == "__main__":
    main()

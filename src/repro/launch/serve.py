"""Serving driver: batched request loop over prefill + decode.

CPU-scale example:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --tiny \
      --requests 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro import configs
from repro.launch import mesh as mesh_mod
from repro.parallel import api as par
from repro.serve import engine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--mesh-devices", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get_config(args.arch)
    if args.tiny:
        cfg = cfg.tiny()
    n = args.mesh_devices or len(jax.devices())
    mesh = mesh_mod.make_host_mesh(n) if n > 1 else None
    pctx = par.ParallelCtx(mesh=mesh)

    key = jax.random.PRNGKey(args.seed)
    params = __import__("repro.models.transformer", fromlist=["x"]).init_params(cfg, key)
    scfg = engine.ServeConfig(max_len=args.prompt_len + args.gen + cfg.prefix_len)

    prompts = jax.random.randint(key, (args.requests, args.prompt_len), 0, cfg.vocab)
    kw = {}
    if cfg.frontend == "audio_stub":
        kw["frames"] = jax.random.normal(
            key, (args.requests, cfg.frontend_seq, cfg.d_model)) * 0.1
    if cfg.prefix_len:
        kw["prefix"] = jax.random.normal(
            key, (args.requests, cfg.prefix_len, cfg.d_model)) * 0.1

    t0 = time.time()
    out = engine.greedy_generate(
        cfg, params, prompts, args.gen, scfg, pctx,
        temperature=args.temperature, key=key if args.temperature > 0 else None,
        **kw,
    )
    dt = time.time() - t0
    toks = args.requests * args.gen
    print(json.dumps({
        "requests": args.requests,
        "generated_tokens": toks,
        "wall_s": round(dt, 3),
        "tok_per_s": round(toks / dt, 2),
        "sample_output": np.asarray(out[0][:8]).tolist(),
    }))
    return out


if __name__ == "__main__":
    main()

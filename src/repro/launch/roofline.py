"""Three-term roofline from a compiled dry-run artifact (TPU v5e target).

  compute term    = HLO_FLOPs / (chips * 197e12)        [bf16 peak]
  memory term     = HLO_bytes / (chips * 819e9)         [HBM BW]
  collective term = collective_bytes / (chips * 50e9)   [ICI per link]

``cost_analysis()`` supplies FLOPs / bytes.  Collective bytes are NOT in
cost_analysis — we parse the optimized HLO and sum the output-shape bytes
of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op (weighted by how many times scan bodies execute,
via the enclosing while-loop trip counts when derivable; XLA flattens
SPMD collectives into the per-device module, so sums are per device).
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string, incl. tuples '(bf16[2,3], f32[4])'."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum output bytes per collective kind from optimized HLO text."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # result = TYPE op-name(...)
        m = re.match(r"%?[\w.\-]+ = (\(?[\w\[\],\s]*\)?) ([\w\-]+)\(", s)
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        base = op.rstrip("-start").rstrip("-done") if op.endswith(("-start", "-done")) else op
        for kind in _COLLECTIVES:
            if op == kind or op == kind + "-start":
                out[kind] += _shape_bytes(type_str)
                counts[kind] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = counts
    return out


@dataclasses.dataclass
class Roofline:
    flops: float
    bytes_accessed: float
    coll_bytes: float
    chips: int
    # terms (seconds)
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    bottleneck: str = ""
    model_flops: float = 0.0
    useful_ratio: float = 0.0
    coll_detail: dict = dataclasses.field(default_factory=dict)

    def finalize(self):
        self.t_compute = self.flops / PEAK_FLOPS
        self.t_memory = self.bytes_accessed / HBM_BW
        self.t_collective = self.coll_bytes / LINK_BW
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        self.bottleneck = max(terms, key=terms.get)
        if self.flops > 0 and self.model_flops > 0:
            self.useful_ratio = self.model_flops / (self.flops * self.chips)
        return self

    def to_dict(self):
        return dataclasses.asdict(self)


def analyze(compiled, hlo_text: str, chips: int, model_flops: float = 0.0) -> Roofline:
    """Build the roofline from a compiled executable.

    cost_analysis() on an SPMD-partitioned module reports *per-device*
    flops/bytes (validated in tests/test_roofline.py), so terms need no
    further division by chips; collective bytes parsed from the
    per-device module likewise.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(hlo_text)
    r = Roofline(
        flops=flops,
        bytes_accessed=bytes_accessed,
        coll_bytes=float(coll["total"]),
        chips=chips,
        model_flops=model_flops,
        coll_detail=coll,
    )
    return r.finalize()


def memory_summary(compiled) -> dict:
    m = compiled.memory_analysis()
    keys = (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    )
    d = {k: getattr(m, k, 0) for k in keys}
    d["total_hbm_bytes"] = (
        d["argument_size_in_bytes"] + d["output_size_in_bytes"]
        + d["temp_size_in_bytes"] - d["alias_size_in_bytes"]
    )
    return d

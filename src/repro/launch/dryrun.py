import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede any jax-importing import: jax locks the device count on
# first backend init.  512 host devices model the 2-pod production mesh.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves, without hardware:
  * the sharding config is coherent (GSPMD partitions the step),
  * per-device memory fits (memory_analysis),
  * and extracts the roofline terms (hlo_cost + cost_analysis).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --all --single-pod-only
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.shapes import SHAPES, ShapeConfig, applicable
from repro.launch import hlo_cost, mesh as mesh_mod, roofline
from repro.models import transformer as T
from repro.parallel import api as par
from repro.parallel import sharding as shard_rules
from repro.serve import engine
from repro.train import optimizer as opt_mod
from repro.train import step as step_mod

# Per-arch training recipe: the 100B+ param configs use Adafactor+bf16
# (Adam state would exceed pod HBM — EXPERIMENTS.md §Dry-run).
BIG_ARCHS = {"kimi-k2-1t-a32b", "jamba-1.5-large-398b", "llama4-scout-17b-a16e"}


def train_recipe(arch: str, microbatches: int = 8) -> step_mod.TrainConfig:
    """Per-arch training recipe.  8 gradient-accumulation microbatches keep
    train_4k activations inside v5e HBM (EXPERIMENTS.md §Dry-run)."""
    if arch in BIG_ARCHS:
        return step_mod.TrainConfig(
            opt=opt_mod.OptConfig(name="adafactor", stochastic_rounding=True),
            param_dtype="bfloat16", microbatches=microbatches,
        )
    return step_mod.TrainConfig(
        opt=opt_mod.OptConfig(name="adamw"), param_dtype="bfloat16",
        microbatches=microbatches,
    )


def shape_cell_cfg(cfg, shape: ShapeConfig):
    """Arch tweaks for a given cell (long-context window for hybrids)."""
    window = "cfg"
    if shape.name == "long_500k" and cfg.long_window is not None:
        window = cfg.long_window
    return window


def batch_specs(cfg, shape: ShapeConfig, global_batch: int, seq: int):
    b = {"tokens": jax.ShapeDtypeStruct((global_batch, seq), jnp.int32)}
    if cfg.frontend == "audio_stub":
        b["frames"] = jax.ShapeDtypeStruct(
            (global_batch, cfg.frontend_seq, cfg.d_model), jnp.bfloat16
        )
    if cfg.prefix_len:
        b["prefix"] = jax.ShapeDtypeStruct(
            (global_batch, cfg.prefix_len, cfg.d_model), jnp.bfloat16
        )
    return b


def model_flops(cfg, shape: ShapeConfig) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (prefill) / per-token (decode),
    N = active params, + causal attention term."""
    pc = cfg.param_counts()
    n_active = pc["active"]
    b, s = shape.global_batch, shape.seq_len
    n_attn = sum(1 for k, _ in cfg.layer_kinds() if k == "attn") * cfg.n_groups
    n_attn += cfg.encoder_layers
    hd, h = cfg.head_dim, cfg.n_heads
    if shape.kind == "train":
        tokens = b * s
        attn = n_attn * 2.0 * b * h * s * s * hd / 2 * 3  # fwd+bwd(2x)
        return 6.0 * n_active * tokens + attn
    if shape.kind == "prefill":
        tokens = b * s
        attn = n_attn * 2.0 * b * h * s * s * hd / 2
        return 2.0 * n_active * tokens + attn
    # decode: one token against a seq_len cache
    attn = n_attn * 4.0 * b * h * min(s, 10**9 if cfg.window is None else cfg.window) * hd
    return 2.0 * n_active * b + attn


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               pctx_overrides: dict | None = None,
               tcfg: step_mod.TrainConfig | None = None,
               capacity_factor: float | None = None) -> dict:
    cfg = configs.get_config(arch)
    if capacity_factor is not None:
        cfg = dataclasses.replace(cfg, capacity_factor=capacity_factor)
    shape = SHAPES[shape_name]
    ok, why = applicable(cfg, shape)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
    }
    if not ok:
        return dict(rec, status="skipped", reason=why)

    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    overrides = dict(fsdp=True, remat="full")
    overrides.update(pctx_overrides or {})
    pctx = par.ParallelCtx(mesh=mesh, **overrides)
    window = shape_cell_cfg(cfg, shape)

    t0 = time.time()
    try:
        if shape.kind == "train":
            tcfg = tcfg or train_recipe(arch)
            step_fn = step_mod.build_train_step(cfg, tcfg, pctx)
            with par.use(pctx):
                state_sds = jax.eval_shape(
                    lambda: step_mod.make_train_state(cfg, tcfg)
                )
            state_sh = shard_rules.param_shardings(state_sds, pctx)
            batch_sds = batch_specs(cfg, shape, shape.global_batch, shape.seq_len)
            batch_sh = step_mod.batch_shardings(batch_sds, pctx)
            jf = jax.jit(step_fn, in_shardings=(state_sh, batch_sh),
                         donate_argnums=(0,))
            lowered = jf.lower(state_sds, batch_sds)
        elif shape.kind == "prefill":
            scfg = engine.ServeConfig(max_len=shape.seq_len, window=window)
            params_sds = jax.eval_shape(
                lambda: T.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.bfloat16)
            )
            params_sh = shard_rules.param_shardings(params_sds, pctx)
            batch_sds = batch_specs(cfg, shape, shape.global_batch, shape.seq_len)
            batch_sh = step_mod.batch_shardings(batch_sds, pctx)
            fn = engine.build_prefill(cfg, scfg, pctx)
            jf = jax.jit(
                lambda p, b: fn(p, b["tokens"], b.get("prefix"), b.get("frames")),
                in_shardings=(params_sh, batch_sh),
            )
            lowered = jf.lower(params_sds, batch_sds)
        else:  # decode
            scfg = engine.ServeConfig(max_len=shape.seq_len, window=window)
            params_sds = jax.eval_shape(
                lambda: T.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.bfloat16)
            )
            params_sh = shard_rules.param_shardings(params_sds, pctx)
            with par.use(pctx):
                cache_sds = jax.eval_shape(
                    lambda: T.init_cache(cfg, shape.global_batch, shape.seq_len,
                                         dtype=jnp.bfloat16, window=window)
                )
            cache_sh = shard_rules.cache_shardings(cfg, cache_sds, pctx)
            tok_sds = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
            tok_sh = step_mod.batch_shardings(tok_sds, pctx)
            pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
            fn = engine.build_decode(cfg, scfg, pctx)
            jf = jax.jit(fn, in_shardings=(params_sh, tok_sh, cache_sh, None),
                         donate_argnums=(2,))
            lowered = jf.lower(params_sds, tok_sds, cache_sds, pos_sds)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        hlo = compiled.as_text()
        my = hlo_cost.analyze_text(hlo)
        mf = model_flops(cfg, shape)
        r = roofline.Roofline(
            flops=my["flops"], bytes_accessed=my["bytes"],
            coll_bytes=my["collective_bytes"], chips=chips, model_flops=mf,
            coll_detail={k: v for k, v in my["collectives"].items()},
        ).finalize()
        xla_cost = compiled.cost_analysis()
        if isinstance(xla_cost, list):
            xla_cost = xla_cost[0]
        mem = roofline.memory_summary(compiled)
        rec.update(
            status="ok",
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            hbm_per_device_gb=round(mem["total_hbm_bytes"] / 2**30, 3),
            memory=mem,
            flops_per_dev=my["flops"], bytes_per_dev=my["bytes"],
            coll_bytes_per_dev=my["collective_bytes"],
            coll_detail=my["collectives"], coll_counts=my["collective_counts"],
            xla_flops=float(xla_cost.get("flops", -1.0)),
            t_compute=r.t_compute, t_memory=r.t_memory,
            t_collective=r.t_collective, bottleneck=r.bottleneck,
            model_flops=mf, useful_ratio=round(r.useful_ratio, 4),
            roofline_frac=round(
                max(r.useful_ratio, 0.0)
                * (r.t_compute / max(max(r.t_compute, r.t_memory, r.t_collective), 1e-30)),
                4,
            ),
        )
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--fsdp", type=int, default=1)
    ap.add_argument("--remat", default="full")
    ap.add_argument("--moe-impl", default="epsum")
    ap.add_argument("--a2a-int8", action="store_true")
    ap.add_argument("--capacity-factor", type=float, default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = configs.all_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = []
    if not args.multi_pod_only:
        meshes.append(False)
    if not args.single_pod_only:
        meshes.append(True)

    overrides = dict(fsdp=bool(args.fsdp), remat=args.remat,
                     moe_impl=args.moe_impl, a2a_int8=args.a2a_int8)
    records = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = lower_cell(arch, shape, mp, pctx_overrides=overrides,
                                 capacity_factor=args.capacity_factor)
                short = {k: rec.get(k) for k in (
                    "arch", "shape", "mesh", "status", "hbm_per_device_gb",
                    "t_compute", "t_memory", "t_collective", "bottleneck",
                    "useful_ratio", "compile_s", "reason", "error")}
                print(json.dumps(short), flush=True)
                records.append(rec)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out + ".json", "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {args.out}.json")


if __name__ == "__main__":
    main()

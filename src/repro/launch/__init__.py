"""Package."""

"""End-to-end training driver.

Wires together: config registry → mesh → sharded train state →
deterministic data pipeline (optionally DDC-curated) → jitted train step
→ checkpointing (async, atomic, elastic-restorable).

CPU-scale example (the examples/train_lm.py quickstart drives this):

  PYTHONPATH=src python -m repro.launch.train \
      --arch qwen3-8b --tiny --steps 50 --batch 8 --seq 128 --mesh-devices 1

Production shape (lowered by the dry-run; identical code path):

  python -m repro.launch.train --arch qwen3-8b --batch 256 --seq 4096 \
      --mesh production --multi-pod
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data import curation, pipeline
from repro.launch import mesh as mesh_mod
from repro.parallel import api as par
from repro.parallel import sharding as shard_rules
from repro.train import checkpoint as ckpt_mod
from repro.train import optimizer as opt_mod
from repro.train import step as step_mod


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--tiny", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--opt", default="adamw")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--mesh", default="host", choices=["host", "production"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mesh-devices", type=int, default=0)
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--remat", default="none")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--curate", action="store_true",
                    help="DDC-curated cluster-balanced sampling")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get_config(args.arch)
    if args.tiny:
        cfg = cfg.tiny()

    if args.mesh == "production":
        mesh = mesh_mod.make_production_mesh(multi_pod=args.multi_pod)
    else:
        n = args.mesh_devices or len(jax.devices())
        mesh = mesh_mod.make_host_mesh(n) if n > 1 else None

    pctx = par.ParallelCtx(
        mesh=mesh, fsdp=args.fsdp, remat=args.remat,
        compress_grads=args.compress_grads,
    )
    tcfg = step_mod.TrainConfig(
        opt=opt_mod.OptConfig(name=args.opt, lr=args.lr,
                              decay_steps=max(args.steps, 10)),
        microbatches=args.microbatches,
    )

    dcfg = pipeline.DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
        seed=args.seed, frontend=cfg.frontend, frontend_seq=cfg.frontend_seq,
        prefix_len=cfg.prefix_len, d_model=cfg.d_model,
    )
    if args.curate:
        emb, doc_clusters = pipeline.doc_embeddings(dcfg, n_docs=4096)
        res = curation.curate(emb, mesh=mesh if mesh else None)
        dcfg = curation.apply_to_data_config(dcfg, res, doc_clusters)
        print(f"[curate] DDC found {res.n_clusters} clusters; "
              f"exchanged {res.exchanged_fraction:.2%} of embedding bytes")

    with par.use(pctx):
        state = step_mod.make_train_state(cfg, tcfg)
    step_fn = step_mod.build_train_step(cfg, tcfg, pctx)

    if mesh is not None:
        state_sh = shard_rules.param_shardings(state, pctx)
        state = jax.device_put(state, state_sh)
        jit_step = jax.jit(step_fn, in_shardings=(state_sh, None),
                           out_shardings=(state_sh, None), donate_argnums=(0,))
    else:
        jit_step = jax.jit(step_fn, donate_argnums=(0,))

    mgr = None
    start_step = 0
    if args.ckpt_dir:
        mgr = ckpt_mod.CheckpointManager(args.ckpt_dir)
        if args.resume and mgr.latest_step() is not None:
            shardings = shard_rules.param_shardings(state, pctx) if mesh else None
            state, manifest = ckpt_mod.restore(args.ckpt_dir, state,
                                               shardings=shardings)
            start_step = int(manifest["step"])
            print(f"[ckpt] resumed at step {start_step}")

    it = pipeline.iterate(dcfg, start_step)
    t0 = time.time()
    losses = []
    for i in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        state, metrics = jit_step(state, batch)
        losses.append(float(metrics["loss"]))
        if (i + 1) % args.log_every == 0 or i == args.steps - 1:
            dt = time.time() - t0
            print(json.dumps({
                "step": i + 1,
                "loss": round(float(np.mean(losses[-args.log_every:])), 4),
                "gnorm": round(float(metrics["gnorm"]), 3),
                "lr": float(metrics["lr"]),
                "steps_per_s": round((i + 1 - start_step) / dt, 3),
            }), flush=True)
        if mgr and (i + 1) % args.ckpt_every == 0:
            mgr.save_async(state, i + 1)
    if mgr:
        mgr.save(state, args.steps)
        print(f"[ckpt] final checkpoint at step {args.steps}")
    return losses


if __name__ == "__main__":
    main()

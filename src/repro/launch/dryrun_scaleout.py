import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=2048 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Scale-out dry-run: the 1 T-param config at 8 pods (2048 chips).

The 2-pod dry-run proves kimi-k2's sharding is coherent but shows
47.6 GB/device — over v5e's 16 GB.  This lowers the same train step on
an (8, 16, 16) mesh to demonstrate the elastic-scaling claim: per-device
memory falls ~1/chips to a size that fits.

  PYTHONPATH=src python -m repro.launch.dryrun_scaleout
"""
import json
import time

import jax

from repro import configs
from repro.configs.shapes import SHAPES
from repro.launch import hlo_cost, mesh as mesh_mod, roofline
from repro.parallel import api as par
from repro.parallel import sharding as shard_rules
from repro.train import step as step_mod


def main():
    from repro.launch import dryrun as dr

    arch = "kimi-k2-1t-a32b"
    cfg = configs.get_config(arch)
    shape = SHAPES["train_4k"]
    mesh = mesh_mod.make_mesh((8, 16, 16), ("pod", "data", "model"))
    pctx = par.ParallelCtx(mesh=mesh, fsdp=True, remat="full",
                           moe_impl="a2a", a2a_int8=True)
    # 2 microbatches: the per-micro batch (128) must divide the 128 DP
    # lanes (8 pods x 16) — 8 microbatches would leave 32-per-micro,
    # silently replicated by the divisibility fallback.
    tcfg = dr.train_recipe(arch, microbatches=2)

    t0 = time.time()
    step_fn = step_mod.build_train_step(cfg, tcfg, pctx)
    with par.use(pctx):
        state_sds = jax.eval_shape(lambda: step_mod.make_train_state(cfg, tcfg))
    state_sh = shard_rules.param_shardings(state_sds, pctx)
    batch_sds = dr.batch_specs(cfg, shape, shape.global_batch, shape.seq_len)
    batch_sh = step_mod.batch_shardings(batch_sds, pctx)
    jf = jax.jit(step_fn, in_shardings=(state_sh, batch_sh), donate_argnums=(0,))
    compiled = jf.lower(state_sds, batch_sds).compile()
    mem = roofline.memory_summary(compiled)
    res = hlo_cost.analyze_text(compiled.as_text())
    rec = {
        "arch": arch, "shape": "train_4k", "mesh": "8x16x16 (2048 chips)",
        "hbm_per_device_gb": round(mem["total_hbm_bytes"] / 2**30, 2),
        "fits_v5e_16gb": mem["total_hbm_bytes"] / 2**30 <= 16.0,
        "t_compute": res["flops"] / roofline.PEAK_FLOPS,
        "t_memory": res["bytes"] / roofline.HBM_BW,
        "t_collective": res["collective_bytes"] / roofline.LINK_BW,
        "compile_s": round(time.time() - t0, 1),
    }
    print(json.dumps(rec))
    with open("results/dryrun_scaleout.json", "w") as f:
        json.dump([rec], f, indent=1)


if __name__ == "__main__":
    main()

"""While-loop-aware cost analysis of optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts every instruction ONCE — a
``lax.scan`` over 62 layers reports one layer's FLOPs (verified
empirically; see tests/test_roofline.py).  Since the whole model stack
scans over layer groups, dry-run rooflines would be off by ~n_layers.
This module re-derives costs from ``compiled.as_text()`` with loop trip
counts applied:

* computations are parsed into instruction tables;
* a call graph is built from ``calls=`` (fusions/calls) and
  ``condition=/body=`` (whiles); while bodies get weight x trip-count,
  where the trip count is recovered from the loop-bound constant in the
  condition computation (exact for lax.scan; an upper bound for dynamic
  ``while_loop``s);
* FLOPs: 2 * prod(result dims) * prod(contracting dims) per ``dot``
  (elementwise flops are negligible for these models and ignored);
* HBM bytes: per *top-level* instruction, operand bytes + result bytes —
  post-fusion, each top-level value is one HBM write plus reads by its
  consumers; fusion-internal instructions don't touch HBM and are
  excluded.  dynamic-slice/gather read only their result-sized window;
  dynamic-update-slice touches 2x its update operand;
* collective bytes: result bytes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute (+ ``-start``
  variants), trip-weighted, per kind.

All numbers are per-device (XLA emits the partitioned module).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SKIP_RESULT = {
    "parameter", "constant", "iota", "get-tuple-element", "tuple", "bitcast",
    "while", "call", "conditional", "after-all", "partition-id", "replica-id",
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_ASSIGN_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _split_instr(rhs: str):
    """Split '<type> <op>(<rest>' — type may be a tuple with nested parens
    and /*index=N*/ comments."""
    rhs = rhs.strip()
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    type_str = rhs[: i + 1]
                    rest = rhs[i + 1 :].strip()
                    break
        else:
            return None
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        type_str, rest = rhs[:sp], rhs[sp + 1 :].strip()
    m = re.match(r"([\w\-]+)\((.*)$", rest)
    if not m:
        return None
    return type_str, m.group(1), m.group(2)


def _shape_dims(type_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str
    operands: list


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    is_fusion_target: bool = False


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Computation | None = None
    fusion_targets: set[str] = set()
    for line in text.splitlines():
        if cur is None:
            m = _COMP_RE.match(line)
            if m:
                cur = Computation(m.group(1), [])
            continue
        if line.strip() == "}" or line.rstrip().endswith("} // " + cur.name):
            comps[cur.name] = cur
            cur = None
            continue
        m = _ASSIGN_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        parsed = _split_instr(rhs)
        if parsed is None:
            continue
        type_str, op, rest = parsed
        # operands: %names appearing before any attr like calls=/to_apply=
        arg_part = rest.split("), ")[0]
        operands = _OPERAND_RE.findall(arg_part)
        cur.instrs.append(Instr(name, type_str, op, rest, operands))
        for attr in ("calls=", ):
            for t in re.findall(r"calls=%?([\w.\-]+)", rest):
                fusion_targets.add(t)
    if cur is not None:
        comps[cur.name] = cur
    for t in fusion_targets:
        if t in comps:
            comps[t].is_fusion_target = True
    return comps


def _trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    """Loop bound heuristic: the max integer constant in the condition."""
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    best = 1
    for ins in cond.instrs:
        for m in re.finditer(r"constant\((\d+)\)", ins.rest):
            best = max(best, int(m.group(1)))
        for m in re.finditer(r"constant\((\d+)\)", ins.type_str):
            best = max(best, int(m.group(1)))
    return best


def _weights(comps: Dict[str, Computation]) -> Dict[str, float]:
    """Execution weight per computation (entry=1; while bodies x trips)."""
    entry = None
    called: set[str] = set()
    edges: Dict[str, list[tuple[str, float]]] = defaultdict(list)
    for c in comps.values():
        for ins in c.instrs:
            if ins.op == "while":
                m = re.search(r"condition=%?([\w.\-]+), body=%?([\w.\-]+)", ins.rest)
                if not m:
                    m = re.search(r"body=%?([\w.\-]+), condition=%?([\w.\-]+)", ins.rest)
                    cond, body = (m.group(2), m.group(1)) if m else (None, None)
                else:
                    cond, body = m.group(1), m.group(2)
                if body:
                    # XLA records exact trip counts when it can prove them.
                    kt = re.search(r'known_trip_count[^0-9]*(\d+)', ins.rest)
                    trips = int(kt.group(1)) if kt else _trip_count(comps, cond)
                    edges[c.name].append((body, float(trips)))
                    edges[c.name].append((cond, float(trips) + 1))
                    called.add(body)
                    called.add(cond)
            else:
                for t in re.findall(r"(?:calls|to_apply|branch_computations)=\{?%?([\w.\-,%\s]+)\}?", ins.rest):
                    for tn in re.findall(r"[\w.\-]+", t):
                        if tn in comps:
                            edges[c.name].append((tn, 1.0))
                            called.add(tn)
    roots = [n for n in comps if n not in called]
    weights: Dict[str, float] = defaultdict(float)

    def visit(name: str, w: float, depth=0):
        if depth > 50:
            return
        weights[name] += w
        for child, mult in edges.get(name, []):
            visit(child, w * mult, depth + 1)

    for r in roots:
        visit(r, 1.0)
    return weights


def _fusion_input_bytes(comp: Computation, operand_types: list[str]) -> float:
    """Effective HBM reads of a fusion: a parameter consumed only through
    dynamic-slice/gather reads just the slices, not the whole array
    (stacked-layer params in scan bodies would otherwise overcount by the
    full stack size per iteration)."""
    # param index -> instr name
    params: dict[str, int] = {}
    for ins in comp.instrs:
        if ins.op == "parameter":
            m = re.match(r"(\d+)", ins.rest)
            if m:
                params[ins.name] = int(m.group(1))
    total = 0.0
    for ins in comp.instrs:
        if ins.op != "parameter":
            continue
        idx = params.get(ins.name, None)
        full = _shape_bytes(
            operand_types[idx] if idx is not None and idx < len(operand_types)
            else ins.type_str
        )
        users = [u for u in comp.instrs if ins.name in u.operands]
        if users:
            sliced = 0.0
            all_slicing = True
            for u in users:
                if u.op in ("dynamic-slice", "gather"):
                    sliced += _shape_bytes(u.type_str)
                elif u.op in ("dynamic-update-slice",):
                    # reads only the update-sized window it overwrites
                    upd = u.operands[1] if len(u.operands) > 1 else None
                    sliced += _shape_bytes(
                        next((i.type_str for i in comp.instrs if i.name == upd), "")
                    )
                else:
                    all_slicing = False
                    break
            if all_slicing:
                total += min(full, sliced)
                continue
        total += full
    return total


_CALLEE_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")


def _comp_has_scope(comps, name, cache, _stack=()) -> bool:
    """A computation is scoped when any instruction carries the
    ``vmem_kernel`` metadata, directly or via a computation it calls —
    some backends (CPU parallel codegen) wrap scoped ops in metadata-free
    ``call``/``to_apply`` shells, so the scope must propagate through the
    call graph."""
    if name in cache:
        return cache[name]
    c = comps.get(name)
    if c is None or name in _stack:
        return False
    val = any("vmem_kernel" in i.rest for i in c.instrs if i.op != "parameter")
    if not val:
        for i in c.instrs:
            if any(_comp_has_scope(comps, t, cache, _stack + (name,))
                   for t in _CALLEE_RE.findall(i.rest)):
                val = True
                break
    cache[name] = val
    return val


def analyze_text(text: str) -> dict:
    comps = parse_module(text)
    weights = _weights(comps)
    scope_cache: dict = {}

    # Global instruction table for operand type lookup.
    types: Dict[str, str] = {}
    for c in comps.values():
        for ins in c.instrs:
            types[ins.name] = ins.type_str

    # Values produced inside a vmem_kernel scope live in VMEM: neither
    # their write nor any read of them counts as HBM traffic.
    scoped_names: set[str] = set()
    for c in comps.values():
        for ins in c.instrs:
            if "vmem_kernel" in ins.rest:
                scoped_names.add(ins.name)
            elif ins.op in ("fusion", "call", "reduce", "reduce-window"):
                if any(_comp_has_scope(comps, t, scope_cache)
                       for t in _CALLEE_RE.findall(ins.rest)):
                    scoped_names.add(ins.name)

    flops = 0.0
    bytes_hbm = 0.0
    coll = {k: 0.0 for k in _COLLECTIVES}
    coll_counts = {k: 0 for k in _COLLECTIVES}

    for c in comps.values():
        w = weights.get(c.name, 0.0)
        if w == 0.0:
            continue
        for ins in c.instrs:
            # ---- FLOPs (dots, counted everywhere incl. fusion bodies) ---
            if ins.op == "dot":
                m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
                if m and ins.operands:
                    lhs_type = types.get(ins.operands[0], "")
                    dims_info = _shape_dims(lhs_type)
                    res_info = _shape_dims(ins.type_str)
                    if dims_info and res_info:
                        lhs_dims = dims_info[0][1]
                        contract = 1
                        for i in [int(x) for x in m.group(1).split(",") if x]:
                            if i < len(lhs_dims):
                                contract *= lhs_dims[i]
                        res_elems = 1
                        for d in res_info[0][1]:
                            res_elems *= d
                        flops += w * 2.0 * res_elems * contract
            if c.is_fusion_target:
                continue  # no HBM traffic inside fusions
            if ins.name in scoped_names:
                # Stand-in for a Pallas kernel: these intermediates live in
                # VMEM on the TPU target (kernels/ops.py marks the scopes);
                # boundary tensors are still counted at producers/consumers
                # outside the scope.
                continue
            # ---- collectives ------------------------------------------
            base = ins.op[:-6] if ins.op.endswith("-start") else ins.op
            if base in _COLLECTIVES and not ins.op.endswith("-done"):
                b = _shape_bytes(ins.type_str)
                coll[base] += w * b
                coll_counts[base] += 1
                bytes_hbm += w * 2 * b
                continue
            # ---- HBM bytes --------------------------------------------
            if ins.op in _SKIP_RESULT:
                continue
            if ins.op in ("dynamic-slice", "gather"):
                bytes_hbm += w * 2 * _shape_bytes(ins.type_str)
                continue
            if ins.op in ("dynamic-update-slice",):
                upd = types.get(ins.operands[1], "") if len(ins.operands) > 1 else ""
                bytes_hbm += w * 2 * _shape_bytes(upd)
                continue
            out_b = _shape_bytes(ins.type_str)
            live_ops = [o for o in ins.operands if o not in scoped_names]
            if ins.op == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", ins.rest)
                target = comps.get(m.group(1)) if m else None
                if target is not None:
                    op_types = [
                        types.get(o, "") if o not in scoped_names else ""
                        for o in ins.operands
                    ]
                    in_b = _fusion_input_bytes(target, op_types)
                    bytes_hbm += w * (out_b + in_b)
                    continue
            in_b = sum(_shape_bytes(types.get(o, "")) for o in live_ops)
            bytes_hbm += w * (out_b + in_b)

    return {
        "flops": flops,
        "bytes": bytes_hbm,
        "collective_bytes": sum(coll.values()),
        "collectives": coll,
        "collective_counts": coll_counts,
        "n_computations": len(comps),
    }

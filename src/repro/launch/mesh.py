"""Production mesh builders.

A function (not a module constant) so importing never touches jax device
state.  Single pod: 256 chips as (data=16, model=16); multi-pod: 2 pods
= 512 chips as (pod=2, data=16, model=16) — 'pod' is the outer DP axis
(its collectives cross the inter-pod DCN/ICI links, which is what the
multi-pod dry-run exercises).
"""
from __future__ import annotations

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape, axes):
    return compat.make_mesh(tuple(shape), tuple(axes))


def make_host_mesh(n: int | None = None, axis: str = "data"):
    """Small CPU mesh over however many host devices exist (tests)."""
    n = n or len(jax.devices())
    return compat.make_mesh((n,), (axis,))

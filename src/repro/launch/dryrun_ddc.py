import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Dry-run of the paper's own workload at pod scale: distributed DDC over
256 (single-pod) and 512 (two-pod) lanes, sync vs async phase-2 schedules.

Proves the shard_map DDC lowers+compiles at production width and measures
the collective schedule — the paper's sync-vs-async claim expressed in
wire bytes: all-gather (K−1)·B vs butterfly log2(K)·B.

  PYTHONPATH=src python -m repro.launch.dryrun_ddc
"""
import argparse
import dataclasses
import json

import jax
import jax.numpy as jnp

from repro.ddc import DDC, DDCConfig
from repro.launch import hlo_cost, roofline


def run_cell(n_lanes: int, schedule: str, n_points: int, cfg: DDCConfig):
    cfg = dataclasses.replace(cfg, schedule=schedule, shards=n_lanes)
    model = DDC(cfg)
    run = model.backend.make_runner(n_points)
    pts = jax.ShapeDtypeStruct((n_points, 2), jnp.float32)
    mask = jax.ShapeDtypeStruct((n_points,), jnp.bool_)
    lowered = jax.jit(run.__wrapped__ if hasattr(run, "__wrapped__") else run
                      ).lower(pts, mask)
    compiled = lowered.compile()
    res = hlo_cost.analyze_text(compiled.as_text())
    mem = roofline.memory_summary(compiled)
    rec = {
        "cell": f"ddc_spatial_{n_lanes}lanes_{schedule}",
        "points": n_points,
        "hbm_per_device_gb": round(mem["total_hbm_bytes"] / 2**30, 4),
        "flops_per_dev": res["flops"],
        "coll_bytes_per_dev": res["collective_bytes"],
        "coll_detail": {k: v for k, v in res["collectives"].items() if v},
        "t_compute": res["flops"] / roofline.PEAK_FLOPS,
        "t_memory": res["bytes"] / roofline.HBM_BW,
        "t_collective": res["collective_bytes"] / roofline.LINK_BW,
        "wire_budget_bytes": cfg.core().buffer_bytes() * (
            (n_lanes - 1) if schedule == "sync" else max(n_lanes.bit_length() - 1, 1)),
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--points", type=int, default=1 << 20)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    cfg = DDCConfig(eps=0.01, min_pts=4, grid=256, max_clusters=64,
                    max_verts=128, backend="jit")
    recs = []
    for lanes in (256, 512):
        for sched in ("sync", "tree", "async"):
            rec = run_cell(lanes, sched, args.points, cfg)
            print(json.dumps(rec))
            recs.append(rec)
    s, a = recs[-3], recs[-1]
    print(f"# 512-lane phase-2 wire bytes: sync/async = "
          f"{s['coll_bytes_per_dev'] / max(a['coll_bytes_per_dev'],1):.1f}x "
          f"(theory (K-1)/log2(K) = {511/9:.1f}x)")
    if args.out:
        with open(args.out + ".json", "w") as f:
            json.dump(recs, f, indent=1)


if __name__ == "__main__":
    main()

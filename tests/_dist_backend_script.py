"""dist≡stream≡host equivalence checks for the device-resident backend,
run under an 8-device CPU override by tests/test_dist_backend.py (the
device count must be pinned before jax initialises, which pytest's
process already did with 1 device).

Modes (argv[1]):

* a ``PHASE2_LAYOUTS`` name (or ``all``) — for every shard count in
  {2, 4, 8}: stream the layout into the ``dist`` and ``stream`` backends
  with identical ingest schedules and assert (1) global labels are
  bit-identical between the two engines AND ``same_clustering`` against
  batch ``ddc_host`` on the live points, (2) the delta-maintained
  pair-d2 matrix is bit-identical to the stream engine's and to a
  from-scratch full re-merge, (3) the CommMeter counted EXACTLY
  |dirty|·B + K·C·4 axis-crossing bytes for a single-dirty-shard delta
  refresh and K·B + K·C·4 for a full re-merge, (4) routed queries agree
  label-for-label, and (5) snapshot → restore resumes bit-identically.
* ``orderings`` — hypothesis-driven shuffled ingest/evict interleavings:
  any order must land on the same clustering as batch ``ddc_host`` and
  bit-match the stream engine fed the same sequence.

Prints PASS lines; any exception fails.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np

from repro.core import ddc as core_ddc
from repro.data import spatial
from repro.ddc import CommMeter, DDC, DDCConfig, same_clustering

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from _hyp import HAVE_HYPOTHESIS, given, settings, st  # noqa: E402

N = 2048
SHARD_COUNTS = (2, 4, 8)


def build(layout: str, k: int, backend: str, meter=None,
          capacity: int | None = None, max_batch: int = 256):
    spec = spatial.PHASE2_LAYOUTS[layout]
    cap = capacity or spatial.shard_capacity(N, k)
    cfg = DDCConfig(
        eps=spec["eps"], min_pts=spec["min_pts"], grid=spec["grid"],
        max_clusters=spec["max_clusters"], max_verts=spec["max_verts"],
        backend=backend, shards=k, capacity=cap,
        max_batch=min(max_batch, cap)).validate()
    return DDC(cfg, meter=meter)


def stream_in(model, pts, k, order="round_robin", seed=None, batch=256):
    for shard, chunk in spatial.stream_batches(pts, k, batch, order=order,
                                               seed=seed):
        model.partial_fit(shard, chunk)
        model.service.refresh()


def assert_matches_host(svc, spec):
    live, parts, labels = svc.live()
    host, _, _ = core_ddc.ddc_host(live, len(parts), spec["eps"],
                                   spec["min_pts"], partition=parts,
                                   contour="grid")
    assert same_clustering(labels, host), "diverged from batch ddc_host"


def check_layout(layout: str):
    spec = spatial.PHASE2_LAYOUTS[layout]
    pts = spec["make"](N)
    for k in SHARD_COUNTS:
        meters = {b: CommMeter() for b in ("stream", "dist")}
        models = {b: build(layout, k, b, meter=meters[b])
                  for b in ("stream", "dist")}
        for b in ("stream", "dist"):
            stream_in(models[b], pts, k)
        svc_s = models["stream"].service
        svc_d = models["dist"].service

        # (1) labels: dist == stream bit-for-bit, both == host clustering
        assert np.array_equal(models["stream"].labels_,
                              models["dist"].labels_), "dist != stream labels"
        assert_matches_host(svc_d, spec)

        # (2) cached pair-d2: dist == stream == from-scratch, bit-for-bit
        d2 = np.asarray(svc_d.pair_d2)
        np.testing.assert_array_equal(d2, np.asarray(svc_s.pair_d2),
                                      err_msg="dist pair_d2 != stream")
        svc_d.remerge_full()
        np.testing.assert_array_equal(d2, np.asarray(svc_d.pair_d2),
                                      err_msg="delta != full rebuild")

        # (3) exact axis-crossing byte accounting
        b = models["dist"].config.core().buffer_bytes()
        c = models["dist"].config.max_clusters
        meters["dist"].reset()
        models["dist"].partial_fit(0, pts[:8])
        svc_d.refresh()
        assert meters["dist"].snapshot()["bytes_total"] == b + k * c * 4
        meters["dist"].reset()
        svc_d.remerge_full()
        assert meters["dist"].snapshot()["bytes_total"] == k * b + k * c * 4
        models["stream"].partial_fit(0, pts[:8])   # keep engines in lockstep
        svc_s.refresh()
        svc_s.remerge_full()

        # (4) routed queries agree label-for-label (ties included)
        rng = np.random.default_rng(k)
        q = np.concatenate([pts[rng.integers(0, N, 200)],
                            rng.uniform(0, 1, (100, 2)).astype(np.float32),
                            np.array([[5.0, 5.0]], np.float32)])
        np.testing.assert_array_equal(models["stream"].query(q),
                                      models["dist"].query(q))
        assert 0 < svc_d.query_shards_scanned \
            <= svc_d.query_chunks * k, svc_d.routing_stats()

        # (5) snapshot -> restore is bit-identical
        import tempfile
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "ckpt")
            models["dist"].save(path)
            restored = DDC.load(path)
            np.testing.assert_array_equal(restored.labels_,
                                          models["dist"].labels_)
            np.testing.assert_array_equal(
                np.asarray(restored.service.pair_d2),
                np.asarray(svc_d.pair_d2))
        print(f"PASS {layout} k={k}")


def _one_ordering(seed: int, k: int, batch: int, evict_step: int):
    """Shuffled ingest (+ optional interleaved evictions): dist must
    bit-match a stream engine fed the identical call sequence — labels
    AND cached pair-d2 — under ANY ordering.  With no evictions
    (``evict_step=0``) the tuned-layout streaming≡batch contract also
    applies, so the result is additionally checked against ``ddc_host``;
    aggressive mid-stream eviction can legitimately leave borderline
    inter-fragment gaps where the engine's contour-proximity predicate
    and the host oracle's grid-distance predicate disagree (the DESIGN
    §7 tuning covers the full layouts, not arbitrary evicted subsets),
    so the host comparison is scoped to the non-evicting draws."""
    layout = "linked_ovals"
    spec = spatial.PHASE2_LAYOUTS[layout]
    pts = spec["make"](N)
    models = {b: build(layout, k, b, max_batch=batch)
              for b in ("stream", "dist")}
    batches = spatial.stream_batches(pts, k, batch, order="shuffled",
                                     seed=seed)
    rng = np.random.default_rng(seed)
    victims = rng.integers(0, k, size=len(batches))
    for b in ("stream", "dist"):
        svc = models[b].service
        for i, (shard, chunk) in enumerate(batches):
            svc.ingest(shard, chunk, t=float(i))
            if evict_step and i % evict_step == evict_step - 1:
                # seed-deterministic evictions mid-stream, same
                # schedule for both engines
                svc.evict_oldest(int(victims[i]), int(batch // 4))
            svc.refresh()
        if evict_step:
            models[b].expire(t=1.0)       # TTL: drop the first batch
    assert np.array_equal(models["stream"].labels_,
                          models["dist"].labels_)
    np.testing.assert_array_equal(
        np.asarray(models["stream"].service.pair_d2),
        np.asarray(models["dist"].service.pair_d2))
    if not evict_step:
        assert_matches_host(models["dist"].service, spec)


def check_orderings():
    if HAVE_HYPOTHESIS:
        @settings(max_examples=4, deadline=None)
        @given(seed=st.integers(0, 2**31 - 1),
               k=st.sampled_from((2, 4)),
               batch=st.sampled_from((128, 256)),
               evict_step=st.sampled_from((0, 3, 4, 5, 6)))
        def run(seed, k, batch, evict_step):
            _one_ordering(seed, k, batch, evict_step)

        run()
    else:
        # Fixed fallback examples so the check still bites where the
        # dev extra is absent.
        for seed, k, batch, evict_step in ((0, 2, 256, 3), (3, 2, 256, 0),
                                           (7, 4, 128, 5)):
            _one_ordering(seed, k, batch, evict_step)
    print("PASS orderings")


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which == "orderings":
        check_orderings()
    else:
        names = list(spatial.PHASE2_LAYOUTS) if which == "all" else [which]
        for name in names:
            check_layout(name)
    print("ALL_OK")

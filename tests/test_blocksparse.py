"""Block-sparse phase-1 kernels + pointer-doubling DBSCAN.

Equivalence contract: bounding-box pruning is exact (every within-eps
point pair lives in an active tile pair), so the block-sparse kernels and
the block-sparse dbscan path must match the dense reference **bit-exactly**
— on random, clustered, and adversarial (all points in one cell) layouts.
Pallas kernels run in interpret mode (CPU container).
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import dbscan as db
from repro.data import spatial
from repro.kernels import ops, ref
from repro.kernels import pairwise_dist as pd

RNG = np.random.default_rng(7)
make_worm = spatial.make_worm


def make_layout(name: str, n: int) -> np.ndarray:
    if name == "random":
        return RNG.uniform(0, 1, (n, 2)).astype(np.float32)
    if name == "clustered":
        return spatial.make_clustered(n, seed=int(RNG.integers(1 << 20)))
    if name == "one_cell":  # adversarial: zero pruning possible
        return (0.5 + RNG.normal(0, 0.001, (n, 2))).astype(np.float32)
    raise ValueError(name)


def sorted_inputs(pts, mask, bt):
    """Morton-sort + pad exactly the way the block-sparse dbscan path does."""
    sp, sm, _ = db.spatial_sort(jnp.asarray(pts), jnp.asarray(mask), bt)
    return sp, sm


class TestTilePairs:
    @pytest.mark.parametrize("layout", ["random", "clustered", "one_cell"])
    def test_invariants(self, layout):
        x, m = sorted_inputs(make_layout(layout, 500), RNG.random(500) > 0.2, 64)
        pairs = ops.build_tile_pairs(x, m, 0.06, bt=64)
        t = x.shape[0] // 64
        rows, cols, flags = map(np.asarray, (pairs.rows, pairs.cols, pairs.flags))
        n_active = int(pairs.n_active)
        valid = (flags & pd.PAIR_VALID) != 0
        assert valid.sum() == n_active
        assert valid[:n_active].all() and not valid[n_active:].any()
        # rows sorted; every row tile appears (diagonal always active)
        assert (np.diff(rows[:n_active]) >= 0).all()
        assert set(rows[:n_active]) == set(range(t))
        # exactly one FIRST flag per row tile, on its first pair
        first = (flags & pd.PAIR_FIRST) != 0
        assert first.sum() == t
        # tail padding repeats the last active pair (no block switch)
        assert (rows[n_active:] == rows[n_active - 1]).all()
        assert (cols[n_active:] == cols[n_active - 1]).all()
        assert 0.0 < float(pairs.frac) <= 1.0

    def test_offset_data_still_prunes(self):
        """Morton-grid bounds must come from masked points only: data far
        from the origin (with zero padding rows in the buffer) previously
        collapsed the sort grid into one cell, silently degrading frac to
        ~1.0.  Translation must not change the active fraction at all."""
        base = spatial.make_clustered(500, seed=3)
        fracs = []
        for off in (0.0, 100.0):
            x, m = sorted_inputs(base + np.float32(off), np.ones(500, bool), 64)
            fracs.append(float(ops.build_tile_pairs(x, m, 0.02, bt=64).frac))
        assert fracs[0] == fracs[1], fracs
        # and clustering the offset data stays exact through the sparse path
        pts = base + np.float32(100.0)
        got = db.dbscan(jnp.asarray(pts), jnp.ones(500, bool), 0.05, 5,
                        block_sparse="always", bt=64)
        np.testing.assert_array_equal(np.asarray(got.labels),
                                      db.dbscan_ref(pts, 0.05, 5))

    def test_pruning_is_exact(self):
        """No within-eps point pair may fall in an inactive tile pair."""
        x, m = sorted_inputs(make_layout("clustered", 400), np.ones(400, bool), 64)
        eps = 0.05
        pairs = ops.build_tile_pairs(x, m, eps, bt=64)
        t = x.shape[0] // 64
        active = np.zeros((t, t), bool)
        rows, cols = np.asarray(pairs.rows), np.asarray(pairs.cols)
        active[rows[: int(pairs.n_active)], cols[: int(pairs.n_active)]] = True
        d2 = np.asarray(ref.pairwise_dist_sq(x, x))
        within = (d2 <= eps * eps) & np.asarray(m)[:, None] & np.asarray(m)[None, :]
        ti = np.arange(x.shape[0]) // 64
        for i, j in zip(*np.nonzero(within)):
            assert active[ti[i], ti[j]]


class TestKernelEquivalence:
    @pytest.mark.parametrize("layout", ["random", "clustered", "one_cell"])
    @pytest.mark.parametrize("eps", [0.03, 0.1])
    def test_neighbor_count(self, layout, eps):
        pts = make_layout(layout, 384)
        mask = RNG.random(384) > 0.15
        x, m = sorted_inputs(pts, mask, 64)
        pairs = ops.build_tile_pairs(x, m, eps, bt=64)
        want = np.asarray(ref.neighbor_count(x, m, eps))
        got = pd.neighbor_count_sparse(x, m, eps, pairs.rows, pairs.cols,
                                       pairs.flags, bt=64, interpret=True)
        np.testing.assert_array_equal(np.asarray(got), want)
        got_ref = ref.neighbor_count_sparse(x, m, eps, pairs.rows, pairs.cols,
                                            pairs.flags, 64)
        np.testing.assert_array_equal(np.asarray(got_ref), want)

    @pytest.mark.parametrize("layout", ["random", "clustered", "one_cell"])
    def test_min_label_sweep(self, layout):
        pts = make_layout(layout, 384)
        mask = RNG.random(384) > 0.15
        x, m = sorted_inputs(pts, mask, 64)
        eps = 0.06
        n = x.shape[0]
        labels = jnp.asarray(RNG.permutation(n), jnp.int32)
        core = jnp.asarray(RNG.random(n) > 0.4)
        pairs = ops.build_tile_pairs(x, m, eps, bt=64)
        want = np.asarray(ref.min_label_sweep(x, m, labels, core, eps))
        got = pd.min_label_sweep_sparse(x, m, labels, core, eps, pairs.rows,
                                        pairs.cols, pairs.flags, bt=64,
                                        interpret=True)
        np.testing.assert_array_equal(np.asarray(got), want)
        got_ref = ref.min_label_sweep_sparse(x, m, labels, core, eps,
                                             pairs.rows, pairs.cols,
                                             pairs.flags, 64)
        np.testing.assert_array_equal(np.asarray(got_ref), want)


class TestDBSCANBlockSparse:
    @pytest.mark.parametrize("layout", ["random", "clustered", "one_cell"])
    def test_matches_oracle(self, layout):
        pts = make_layout(layout, 420)
        eps, min_pts = (0.05, 5) if layout != "one_cell" else (0.002, 5)
        want = db.dbscan_ref(pts, eps, min_pts)
        got = db.dbscan(jnp.asarray(pts), jnp.ones(len(pts), bool), eps,
                        min_pts, block_sparse="always", bt=64)
        np.testing.assert_array_equal(np.asarray(got.labels), want)

    def test_sparse_equals_dense_path(self):
        pts, _ = spatial.make_blobs(700, 6, seed=11)
        mask = jnp.asarray(RNG.random(700) > 0.1)
        dense = db.dbscan(jnp.asarray(pts), mask, 0.05, 5, block_sparse="never")
        sparse = db.dbscan(jnp.asarray(pts), mask, 0.05, 5,
                           block_sparse="always", bt=64)
        np.testing.assert_array_equal(np.asarray(dense.labels),
                                      np.asarray(sparse.labels))
        np.testing.assert_array_equal(np.asarray(dense.core),
                                      np.asarray(sparse.core))
        assert int(dense.n_clusters) == int(sparse.n_clusters)

    def test_dense_fallback_threshold(self):
        """frac > dense_fallback_frac must route to the dense kernels and
        still give identical results (one_cell forces frac = 1)."""
        pts = make_layout("one_cell", 300)
        want = db.dbscan_ref(pts, 0.002, 4)
        got = db.dbscan(jnp.asarray(pts), jnp.ones(300, bool), 0.002, 4,
                        block_sparse="always", bt=64, dense_fallback_frac=0.1)
        np.testing.assert_array_equal(np.asarray(got.labels), want)

    def test_padding_mask(self):
        pts, _ = spatial.make_blobs(220, 3, seed=4)
        padded = np.concatenate([pts, np.zeros((120, 2), np.float32)])
        mask = jnp.asarray([True] * 220 + [False] * 120)
        res = db.dbscan(jnp.asarray(padded), mask, 0.05, 5,
                        block_sparse="always", bt=64)
        np.testing.assert_array_equal(np.asarray(res.labels)[:220],
                                      db.dbscan_ref(pts, 0.05, 5))
        assert (np.asarray(res.labels)[220:] == db.NOISE).all()


class TestPointerDoubling:
    def test_labels_identical(self):
        pts, _ = spatial.make_blobs(400, 5, seed=2)
        a = db.dbscan(jnp.asarray(pts), jnp.ones(400, bool), 0.05, 5,
                      pointer_doubling=False, block_sparse="never")
        b = db.dbscan(jnp.asarray(pts), jnp.ones(400, bool), 0.05, 5,
                      pointer_doubling=True, block_sparse="never")
        np.testing.assert_array_equal(np.asarray(a.labels), np.asarray(b.labels))

    def test_worm_sweep_reduction(self):
        """The tentpole claim: ≥3× fewer sweeps on a diameter-bound layout."""
        worm = make_worm(1024)
        kw = dict(block_sparse="never")
        plain = db.dbscan(jnp.asarray(worm), jnp.ones(1024, bool), 0.02, 5,
                          pointer_doubling=False, **kw)
        doubled = db.dbscan(jnp.asarray(worm), jnp.ones(1024, bool), 0.02, 5,
                            pointer_doubling=True, **kw)
        np.testing.assert_array_equal(np.asarray(plain.labels),
                                      np.asarray(doubled.labels))
        assert int(plain.n_sweeps) >= 3 * int(doubled.n_sweeps), (
            int(plain.n_sweeps), int(doubled.n_sweeps))

    def test_worm_oracle(self):
        worm = make_worm(800, seed=3)
        want = db.dbscan_ref(worm, 0.02, 5)
        got = db.dbscan(jnp.asarray(worm), jnp.ones(800, bool), 0.02, 5,
                        block_sparse="always", bt=128)
        np.testing.assert_array_equal(np.asarray(got.labels), want)

"""Tracking equivalence sweep (DESIGN.md §14), run under an 8-device
CPU override by tests/test_tracking.py.

For every trajectory layout × {2, 4, 8} shards, the same seeded frame
stream is played (one refresh per frame, sliding-window eviction)
through four deployments — stream×flat, stream×hier(2), dist×flat,
dist×hier(2) — plus a save→load→resume arm that snapshots the stream
model mid-run and resumes the copy.  The tracker's full serialised
state (track IDs, history rings, lifecycle events, counters, match
state) must be BIT-IDENTICAL across all five: tracking is a pure fold
over the per-generation (batch contours, slot maps, global sizes),
which are themselves bit-identical across engines and aggregator
topologies.

Modes (argv[1]): ``quick`` (one layout), ``all`` (every layout), or a
layout name.  Prints PASS lines; any exception fails.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np

from repro.data import spatial
from repro.ddc import DDC, DDCConfig

SHARD_COUNTS = (2, 4, 8)


def build(layout: str, k: int, backend: str, agg=None) -> DDC:
    spec = spatial.TRAJECTORY_LAYOUTS[layout]
    cap = spatial.trajectory_capacity(spec["n_per_step"], spec["window"], k)
    cfg = DDCConfig(
        eps=spec["eps"], min_pts=spec["min_pts"], grid=spec["grid"],
        max_clusters=spec["max_clusters"], max_verts=spec["max_verts"],
        backend=backend, shards=k, capacity=cap,
        max_batch=min(256, cap), agg_degree=agg, track=True).validate()
    return DDC(cfg)


def play_steps(model: DDC, frames, window: int, start: int = 0) -> None:
    k = model.config.shards
    for i, frame in enumerate(frames):
        step = start + i
        for shard, part in enumerate(np.array_split(frame, k)):
            if len(part):
                model.partial_fit(shard, part,
                                  t=float(step) * np.ones(len(part)))
        if step + 1 > window:
            model.expire(float(step - window + 1))
        model.service.refresh()


def assert_tracker_equal(ref: DDC, other: DDC, what: str) -> None:
    ra, rm = ref.service.tracker.state_dict()
    oa, om = other.service.tracker.state_dict()
    assert rm == om, f"{what}: tracker manifest diverged\n{rm}\nvs\n{om}"
    assert set(ra) == set(oa), f"{what}: tracker array keys diverged"
    for key in sorted(ra):
        np.testing.assert_array_equal(
            ra[key], oa[key], err_msg=f"{what}: tracker array {key!r}")


def sweep_one(layout: str, k: int, tmpdir: str) -> None:
    spec = spatial.TRAJECTORY_LAYOUTS[layout]
    traj = spec["make"](steps=spec["steps"], n_per_step=spec["n_per_step"])
    window = spec["window"]

    ref = build(layout, k, "stream")
    play_steps(ref, traj.frames, window)

    for backend, agg in (("stream", 2), ("dist", None), ("dist", 2)):
        model = build(layout, k, backend, agg=agg)
        play_steps(model, traj.frames, window)
        assert_tracker_equal(
            ref, model, f"{layout} k={k} {backend}"
            f"{' hier' if agg else ' flat'} vs stream flat")

    # save→load→resume mid-run must rejoin the uninterrupted history.
    half = len(traj.frames) // 2
    part1 = build(layout, k, "stream")
    play_steps(part1, traj.frames[:half], window)
    path = os.path.join(tmpdir, f"{layout}-{k}.snap")
    part1.save(path)
    resumed = DDC.load(path)
    play_steps(resumed, traj.frames[half:], window, start=half)
    assert_tracker_equal(ref, resumed, f"{layout} k={k} save/load/resume")

    snap = ref.tracks()
    print(f"PASS {layout} k={k} gen={snap.generation} "
          f"births={snap.births} deaths={snap.deaths} "
          f"merges={snap.merges} splits={snap.splits} "
          f"cont={snap.continuations}")


def sweep(layouts) -> None:
    import tempfile

    with tempfile.TemporaryDirectory() as tmpdir:
        for layout in layouts:
            for k in SHARD_COUNTS:
                sweep_one(layout, k, tmpdir)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "quick"
    if which == "quick":
        sweep(["drifting_blobs"])
    elif which == "all":
        sweep(sorted(spatial.TRAJECTORY_LAYOUTS))
    else:
        sweep([which])
    print("ALL_OK")

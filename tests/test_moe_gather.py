"""dispatch_gather kernel sweeps vs the jnp construction it replaces."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import moe_gather


def reference(x, idx):
    out = np.zeros((len(idx), x.shape[1]), np.float32)
    for i, r in enumerate(np.asarray(idx)):
        if r >= 0:
            out[i] = np.asarray(x)[r]
    return out


@pytest.mark.parametrize("t,d,s,bs", [
    (64, 16, 256, 64),
    (128, 32, 128, 32),
    (32, 8, 512, 128),
])
def test_exact_gather_sweep(t, d, s, bs):
    rng = np.random.default_rng(t + s)
    x = jnp.asarray(rng.normal(size=(t, d)), jnp.float32)
    idx = jnp.asarray(rng.integers(-1, t, size=(s,)), jnp.int32)
    buf, scales = moe_gather.dispatch_gather(x, idx, quant=False, bs=bs,
                                             interpret=True)
    np.testing.assert_allclose(np.asarray(buf), reference(x, idx), rtol=1e-6)
    valid = np.asarray(idx) >= 0
    np.testing.assert_array_equal(np.asarray(scales)[~valid], 0.0)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_int8_quantised_roundtrip(dtype):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(64, 16)) * 3, dtype)
    idx = jnp.asarray(rng.integers(-1, 64, size=(128,)), jnp.int32)
    buf, scales = moe_gather.dispatch_gather(x, idx, quant=True, bs=64,
                                             interpret=True)
    assert buf.dtype == jnp.int8
    deq = np.asarray(buf, np.float32) * np.asarray(scales)[:, None]
    want = reference(np.asarray(x, np.float32), idx)
    # per-row absmax int8: worst-case relative error 1/127 of the row max
    err = np.abs(deq - want).max()
    assert err <= np.abs(want).max() / 127 * 1.01 + 1e-6


def test_empty_slots_zero():
    x = jnp.ones((8, 4), jnp.float32)
    idx = jnp.full((32,), -1, jnp.int32)
    buf, scales = moe_gather.dispatch_gather(x, idx, quant=True, bs=32,
                                             interpret=True)
    assert np.asarray(buf).sum() == 0
    assert np.asarray(scales).sum() == 0

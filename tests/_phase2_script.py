"""Merge-schedule equivalence property check, run under a 16-device CPU
override by tests/test_phase2_schedules.py.

For one layout (argv[1]) and every shard count in {2, 4, 8, 16}:
``merge_sync``, ``merge_async``, and ``merge_tree`` must produce the
IDENTICAL global clustering (same noise set, label bijection) as each
other and as the host oracle ``ddc_host`` on the same block partition.
Prints PASS lines; any exception fails.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"

import numpy as np
import jax.numpy as jnp

from repro.core import ddc
from repro.data import spatial
from repro.launch import mesh as mesh_mod

SHARD_COUNTS = (2, 4, 8, 16)

# Per-layout DDC parameters (eps, min_pts, grid, max_verts, max_clusters):
# tuned so no local OR merged contour overflows its vertex budget at any
# shard count and inter-cluster gaps clear both merge predicates with
# margin — see DESIGN.md §7.  The phase-2 benchmark layouts come from
# the shared spatial.PHASE2_LAYOUTS table (same tuning as
# benchmarks/phase2.py); the remaining data/spatial.py generators get
# their own tuples here.
CASES = {
    "blobs": (lambda: spatial.make_blobs(1024, 5, seed=0, spread=0.015)[0],
              0.05, 5, 96, 48, 12),
    "clustered": (lambda: spatial.make_clustered(1024, 8, seed=0),
                  0.02, 5, 96, 64, 12),
    "d1": (lambda: spatial.make_d1(2048, seed=0), 0.02, 4, 64, 144, 16),
    "d2": (lambda: spatial.make_d2(2048, seed=1), 0.03, 4, 36, 104, 12),
    "worm_default": (lambda: spatial.make_worm(1024), 0.015, 5, 16, 96, 12),
}
CASES |= {
    name: (lambda spec=spec: spec["make"](2048), spec["eps"], spec["min_pts"],
           spec["grid"], spec["max_verts"], spec["max_clusters"])
    for name, spec in spatial.PHASE2_LAYOUTS.items()
}

same_partition = ddc.same_clustering


def check_layout(name: str):
    make, eps, min_pts, grid, max_verts, max_clusters = CASES[name]
    pts = make()
    x = jnp.asarray(pts)
    msk = jnp.ones(len(pts), bool)
    for k in SHARD_COUNTS:
        host_labels, _, _ = ddc.ddc_host(pts, k, eps, min_pts, contour="grid")
        mesh = mesh_mod.make_host_mesh(k)
        labels = {}
        for schedule in ("sync", "async", "tree"):
            cfg = ddc.DDCConfig(
                eps=eps, min_pts=min_pts, grid=grid, max_verts=max_verts,
                max_clusters=max_clusters, schedule=schedule,
            )
            run = ddc.make_ddc_fn(mesh, "data", cfg)
            glabels, gcs, _ = run(x, msk)
            assert not bool(np.asarray(gcs.overflow)), (
                f"{name} k={k} {schedule}: cluster budget overflow")
            labels[schedule] = np.asarray(glabels)
            assert same_partition(labels[schedule], host_labels), (
                f"{name} k={k}: {schedule} diverged from ddc_host")
        assert same_partition(labels["sync"], labels["async"])
        assert same_partition(labels["sync"], labels["tree"])
        print(f"PASS {name} k={k} "
              f"clusters={len(set(host_labels[host_labels >= 0]))}")


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    names = list(CASES) if which == "all" else [which]
    for n in names:
        check_layout(n)
    print("ALL_OK")

"""Checkpointing: roundtrip, atomicity, retention, manifest metadata."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.train import checkpoint as ck


def make_state(scale=1.0):
    return {
        "params": {"w": jnp.arange(12.0).reshape(3, 4) * scale,
                   "blocks": {"l0": {"w1": jnp.ones((2, 5)) * scale}}},
        "step": jnp.asarray(7, jnp.int32),
    }


def test_roundtrip(tmp_path):
    state = make_state()
    ck.save(str(tmp_path), state, step=7)
    restored, manifest = ck.restore(str(tmp_path), jax.eval_shape(lambda: state))
    assert manifest["step"] == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_selected(tmp_path):
    ck.save(str(tmp_path), make_state(1.0), step=1)
    ck.save(str(tmp_path), make_state(2.0), step=2)
    restored, manifest = ck.restore(str(tmp_path), jax.eval_shape(make_state))
    assert manifest["step"] == 2
    assert float(restored["params"]["w"][0, 1]) == 2.0


def test_restore_specific_step(tmp_path):
    ck.save(str(tmp_path), make_state(1.0), step=1)
    ck.save(str(tmp_path), make_state(2.0), step=2)
    restored, manifest = ck.restore(str(tmp_path), jax.eval_shape(make_state), step=1)
    assert manifest["step"] == 1


def test_no_tmp_dirs_left(tmp_path):
    ck.save(str(tmp_path), make_state(), step=3)
    assert not [d for d in os.listdir(tmp_path) if ".tmp" in d]


def test_manager_retention_and_async(tmp_path):
    mgr = ck.CheckpointManager(str(tmp_path), keep=2)
    for s in (10, 20, 30, 40):
        mgr.save_async(make_state(float(s)), s)
    mgr.wait()
    steps = ck.list_steps(str(tmp_path))
    assert steps == [30, 40]
    assert mgr.latest_step() == 40


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ck.restore(str(tmp_path / "nope"), jax.eval_shape(make_state))


def test_extra_metadata(tmp_path):
    ck.save(str(tmp_path), make_state(), step=5, extra={"loss": 1.25})
    _, manifest = ck.restore(str(tmp_path), jax.eval_shape(make_state))
    assert manifest["extra"]["loss"] == 1.25

"""Chaos harness: seeded random FaultPlans against both serve engines.

The sweep needs ``len(jax.devices()) >= 8`` for the dist lanes, so it
runs in a subprocess with the 8-device CPU override
(tests/_chaos_script.py), mirroring the dist-backend suite's pattern.
The quick tier keeps tier-1 blocking time low; the full layout × shard
× seed sweep (plus hypothesis-drawn plans where the dev extra is
installed) is marked ``slow`` and runs in the dedicated chaos CI job.
"""
import os
import subprocess
import sys

import pytest

SCRIPT = os.path.join(os.path.dirname(__file__), "_chaos_script.py")
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_script(arg: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(SRC)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, SCRIPT, arg],
        capture_output=True, text=True, timeout=1800, env=env)
    assert proc.returncode == 0, (
        f"{arg} failed:\n{proc.stdout}\n{proc.stderr}")
    return proc.stdout


def test_chaos_quick():
    """One layout, both engines, flat + hierarchical aggregator, 2/4/8
    shards, fixed seed: no plan corrupts the pair-d2 cache (or a tree
    node cache); recovery is bit-exact vs the twin."""
    out = run_script("quick")
    assert "ALL_OK" in out and out.count("PASS") == 12


@pytest.mark.slow
def test_chaos_full_sweep():
    """Every layout × 2/4/8 shards × both engines × multiple seeds,
    plus hypothesis-drawn plans when available."""
    out = run_script("all")
    assert "ALL_OK" in out

"""DDC system tests: local phase, merge, host oracle, comm volume.

The distributed shard_map path (8 devices) lives in test_distributed.py.
"""
import numpy as np
import jax.numpy as jnp
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.core import dbscan as db
from repro.core import ddc
from repro.data import spatial


CFG = ddc.DDCConfig(eps=0.05, min_pts=5, max_clusters=16, max_verts=64, grid=96)


def co(labels):
    l = np.asarray(labels)
    return (l[:, None] == l[None, :]) & (l >= 0)[:, None] & (l >= 0)[None, :]


class TestLocalPhase:
    def test_contours_within_budget(self):
        pts, _ = spatial.make_blobs(300, 4, seed=0)
        dense, cs = ddc.local_phase(jnp.asarray(pts), jnp.ones(len(pts), bool), CFG)
        assert int(cs.valid.sum()) == 4
        assert (np.asarray(cs.counts) <= CFG.max_verts).all()
        assert not bool(cs.overflow)

    def test_reduction_ratio(self):
        """The paper's headline: representatives are 1-2% of the data."""
        pts = spatial.make_d1(10_000, seed=0)
        dense, cs = ddc.local_phase(
            jnp.asarray(pts), jnp.ones(len(pts), bool),
            ddc.DDCConfig(eps=0.02, min_pts=4, max_clusters=32, max_verts=196, grid=128),
        )
        sent = int(np.asarray(cs.counts).sum())
        frac = sent / len(pts)
        assert frac < 0.25, frac  # grid contours; hull path is ~1-2%

    def test_cluster_sizes_accounted(self):
        pts, _ = spatial.make_blobs(200, 3, seed=1)
        dense, cs = ddc.local_phase(jnp.asarray(pts), jnp.ones(len(pts), bool), CFG)
        labeled = int((np.asarray(dense) >= 0).sum())
        assert int(np.asarray(cs.sizes).sum()) == labeled


class TestMergePair:
    def test_identity_merge(self):
        """Merging a ClusterSet with an empty one preserves clusters."""
        pts, _ = spatial.make_blobs(200, 3, seed=2)
        _, cs = ddc.local_phase(jnp.asarray(pts), jnp.ones(len(pts), bool), CFG)
        merged, map_a, map_b = ddc.merge_pair(cs, ddc.empty_clusterset(CFG), CFG)
        assert int(merged.valid.sum()) == int(cs.valid.sum())
        assert (np.asarray(map_b) == -1).all()

    def test_split_then_merge_recovers(self):
        pts, _ = spatial.make_blobs(400, 5, seed=3)
        full_labels = db.dbscan_ref(pts, CFG.eps, CFG.min_pts)
        n_true = len(set(full_labels[full_labels >= 0]))
        m1 = jnp.arange(len(pts)) % 2 == 0
        _, cs1 = ddc.local_phase(jnp.asarray(pts), m1, CFG)
        _, cs2 = ddc.local_phase(jnp.asarray(pts), ~m1, CFG)
        merged, _, _ = ddc.merge_pair(cs1, cs2, CFG)
        assert int(merged.valid.sum()) == n_true

    def test_commutative_cluster_count(self):
        pts, _ = spatial.make_blobs(300, 4, seed=4)
        m = jnp.arange(len(pts)) < 150
        _, a = ddc.local_phase(jnp.asarray(pts), m, CFG)
        _, b = ddc.local_phase(jnp.asarray(pts), ~m, CFG)
        ab, _, _ = ddc.merge_pair(a, b, CFG)
        ba, _, _ = ddc.merge_pair(b, a, CFG)
        assert int(ab.valid.sum()) == int(ba.valid.sum())
        np.testing.assert_allclose(
            np.sort(np.asarray(ab.sizes)), np.sort(np.asarray(ba.sizes))
        )

    def test_sizes_conserved(self):
        pts, _ = spatial.make_blobs(300, 4, seed=5)
        m = jnp.arange(len(pts)) < 150
        _, a = ddc.local_phase(jnp.asarray(pts), m, CFG)
        _, b = ddc.local_phase(jnp.asarray(pts), ~m, CFG)
        merged, _, _ = ddc.merge_pair(a, b, CFG)
        assert int(np.asarray(merged.sizes).sum()) == (
            int(np.asarray(a.sizes).sum()) + int(np.asarray(b.sizes).sum())
        )


class TestHostDDC:
    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 50), parts=st.sampled_from([2, 4, 8]))
    def test_matches_sequential_dbscan_on_blobs(self, seed, parts):
        """Paper claim: DDC(partitioned) == sequential clustering (here on
        well-separated data, where the equivalence is exact)."""
        pts, _ = spatial.make_blobs(240, 4, seed=seed, spread=0.015)
        seq = db.dbscan_ref(pts, 0.05, 5)
        glab, polys, _ = ddc.ddc_host(pts, parts, eps=0.05, min_pts=5)
        both = (seq >= 0) & (glab >= 0)
        np.testing.assert_array_equal(co(seq)[both][:, both], co(glab)[both][:, both])

    def test_comm_volume_on_d1(self):
        """1-2% exchange claim on the paper's D1-scale dataset (hulls)."""
        pts = spatial.make_d1(10_000, seed=0)
        _, polys, exchanged = ddc.ddc_host(pts, 8, eps=0.03, min_pts=5)
        assert exchanged / len(pts) < 0.05, exchanged / len(pts)

    def test_d2_structure(self):
        pts = spatial.make_d2(6_000, seed=1, noise_frac=0.0)
        glab, polys, _ = ddc.ddc_host(pts, 4, eps=0.035, min_pts=4)
        n = len(set(glab[glab >= 0]))
        assert 3 <= n <= 6, n  # big circle, 2 small circles, linked ovals


class TestConfig:
    def test_buffer_bytes_budget(self):
        cfg = ddc.DDCConfig(max_clusters=32, max_verts=128)
        # the ClusterSet wire format must stay tiny vs any real shard
        assert cfg.buffer_bytes() < 64 * 1024 * 2

    def test_merge_radius_grows_with_grid_cell(self):
        a = ddc.DDCConfig(grid=64).merge_radius
        b = ddc.DDCConfig(grid=256).merge_radius
        assert a > b

"""DBSCAN: TPU-native JAX implementation vs the NumPy oracle."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.core import dbscan as db
from repro.data import spatial


def co_membership(labels: np.ndarray) -> np.ndarray:
    """Partition-invariant representation: (n, n) same-cluster matrix."""
    l = labels[:, None]
    return (l == l.T) & (labels >= 0)[:, None] & (labels >= 0)[None, :]


class TestAgainstOracle:
    @pytest.mark.parametrize("seed,k", [(0, 3), (1, 5), (2, 8)])
    def test_blobs_exact(self, seed, k):
        pts, _ = spatial.make_blobs(200, k, seed=seed)
        ref = db.dbscan_ref(pts, 0.05, 5)
        res = db.dbscan(jnp.asarray(pts), jnp.ones(len(pts), bool), 0.05, 5)
        np.testing.assert_array_equal(np.asarray(res.labels), ref)

    @settings(max_examples=15, deadline=None)
    @given(
        seed=st.integers(0, 1000),
        eps=st.floats(0.02, 0.15),
        min_pts=st.integers(2, 8),
    )
    def test_random_uniform_exact(self, seed, eps, min_pts):
        rng = np.random.default_rng(seed)
        pts = rng.uniform(0, 1, (120, 2)).astype(np.float32)
        ref = db.dbscan_ref(pts, eps, min_pts)
        res = db.dbscan(jnp.asarray(pts), jnp.ones(len(pts), bool), eps, min_pts)
        np.testing.assert_array_equal(np.asarray(res.labels), ref)

    def test_padding_mask(self):
        pts, _ = spatial.make_blobs(100, 3, seed=4)
        padded = np.concatenate([pts, np.zeros((28, 2), np.float32)])
        mask = jnp.asarray([True] * 100 + [False] * 28)
        res = db.dbscan(jnp.asarray(padded), mask, 0.05, 5)
        ref = db.dbscan_ref(pts, 0.05, 5)
        np.testing.assert_array_equal(np.asarray(res.labels)[:100], ref)
        assert (np.asarray(res.labels)[100:] == db.NOISE).all()

    def test_noise_detection(self):
        pts, _ = spatial.make_blobs(150, 2, seed=5)
        pts = np.concatenate([pts, np.array([[0.01, 0.99]], np.float32)])
        res = db.dbscan(jnp.asarray(pts), jnp.ones(len(pts), bool), 0.04, 5)
        assert np.asarray(res.labels)[-1] == db.NOISE


class TestInvariants:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 100))
    def test_permutation_invariance(self, seed):
        """Cluster structure must not depend on point order."""
        pts, _ = spatial.make_blobs(100, 4, seed=seed)
        rng = np.random.default_rng(seed)
        perm = rng.permutation(len(pts))
        a = db.dbscan_ref(pts, 0.05, 5)
        b = db.dbscan_ref(pts[perm], 0.05, 5)
        co_a = co_membership(a)[np.ix_(perm, perm)]
        co_b = co_membership(b)
        np.testing.assert_array_equal(co_a, co_b)

    def test_labels_are_min_core_index(self):
        pts, _ = spatial.make_blobs(80, 2, seed=9)
        res = db.dbscan(jnp.asarray(pts), jnp.ones(len(pts), bool), 0.06, 4)
        labels = np.asarray(res.labels)
        core = np.asarray(res.core)
        for c in set(labels[labels >= 0]):
            members = np.nonzero(core & (labels == c))[0]
            assert members.min() == c

    def test_relabel_dense(self):
        labels = jnp.asarray([5, 5, -1, 9, 9, 9, 5])
        # roots: 5 and 9 -> but relabel_dense expects min-index labels
        # (label == own index at roots): construct consistent input
        labels = jnp.asarray([0, 0, -1, 3, 3, 3, 0])
        dense = np.asarray(db.relabel_dense(labels, 8))
        assert dense.tolist() == [0, 0, -1, 1, 1, 1, 0]
        capped = np.asarray(db.relabel_dense(labels, 1))
        assert capped.tolist() == [0, 0, -1, -1, -1, -1, 0]

"""Multi-device (8 host CPU devices) integration tests via subprocess —
the XLA device count must be set before jax initialises, which pytest's
process already did with 1 device."""
import os
import subprocess
import sys

import pytest

SCRIPT = os.path.join(os.path.dirname(__file__), "_dist_script.py")
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_check(name: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(SRC)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, SCRIPT, name],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert proc.returncode == 0, f"{name} failed:\n{proc.stdout}\n{proc.stderr}"
    return proc.stdout


@pytest.mark.parametrize("name", ["ddc", "coll", "train", "moe", "int8", "elastic"])
def test_distributed(name):
    out = run_check(name)
    assert "PASS" in out

"""Phase-2 batched merge engine unit tests: merge_many, comm meters,
and the empty-shard short-circuit regression.

The distributed shard_map schedules are covered by
tests/test_phase2_schedules.py (subprocess, 16 CPU devices).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ddc
from repro.data import spatial
from repro.parallel import compress

CFG = ddc.DDCConfig(eps=0.05, min_pts=5, max_clusters=16, max_verts=64, grid=96)


def local_sets(pts, n_shards, cfg=CFG):
    parts = np.array_split(np.arange(len(pts)), n_shards)
    out = []
    for idx in parts:
        dense, cs = ddc.local_phase(
            jnp.asarray(pts[idx]), jnp.ones(len(idx), bool), cfg)
        out.append((np.asarray(dense), cs))
    return parts, out


def stack_sets(sets):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *[cs for _, cs in sets])


class TestMergeMany:
    def test_matches_pairwise_on_separated_blobs(self):
        """On well-separated clusters a batched K-way merge and a pairwise
        fold are the same clustering (components never interact)."""
        pts, _ = spatial.make_blobs(600, 5, seed=7, spread=0.012)
        parts, sets = local_sets(pts, 4)
        merged, maps = ddc.merge_many(stack_sets(sets), CFG)
        assert int(merged.valid.sum()) == 5
        acc = sets[0][1]
        for _, cs in sets[1:]:
            acc, _, _ = ddc.merge_pair(acc, cs, CFG)
        assert int(acc.valid.sum()) == 5
        np.testing.assert_allclose(
            np.sort(np.asarray(merged.sizes)), np.sort(np.asarray(acc.sizes)))

    def test_sizes_conserved(self):
        pts, _ = spatial.make_blobs(500, 4, seed=8)
        _, sets = local_sets(pts, 8)
        merged, _ = ddc.merge_many(stack_sets(sets), CFG)
        total = sum(int(np.asarray(cs.sizes).sum()) for _, cs in sets)
        assert int(np.asarray(merged.sizes).sum()) == total

    def test_maps_route_every_valid_slot(self):
        pts, _ = spatial.make_blobs(500, 4, seed=9)
        _, sets = local_sets(pts, 4)
        batch = stack_sets(sets)
        merged, maps = ddc.merge_many(batch, CFG)
        maps = np.asarray(maps)
        valid = np.asarray(batch.valid)
        assert (maps[valid] >= 0).all()
        assert (maps[~valid] == -1).all()
        # Routed sizes must land on the slot that accumulated them.
        msizes = np.zeros(CFG.max_clusters, np.int64)
        sizes = np.asarray(batch.sizes)
        for k in range(maps.shape[0]):
            for c in range(maps.shape[1]):
                if maps[k, c] >= 0:
                    msizes[maps[k, c]] += sizes[k, c]
        np.testing.assert_array_equal(msizes, np.asarray(merged.sizes))

    def test_order_equivariant(self):
        """Permuting the batch permutes maps rows, same clustering."""
        pts, _ = spatial.make_blobs(400, 3, seed=10)
        _, sets = local_sets(pts, 4)
        batch = stack_sets(sets)
        m1, maps1 = ddc.merge_many(batch, CFG)
        perm = [2, 0, 3, 1]
        batch2 = jax.tree.map(lambda x: x[jnp.asarray(perm)], batch)
        m2, maps2 = ddc.merge_many(batch2, CFG)
        np.testing.assert_array_equal(np.asarray(m1.valid), np.asarray(m2.valid))
        np.testing.assert_array_equal(np.asarray(m1.sizes), np.asarray(m2.sizes))
        np.testing.assert_array_equal(
            np.asarray(maps1)[perm], np.asarray(maps2))

    def test_transitive_chain_closes_in_one_shot(self):
        """A cluster chained across many shards closes transitively even
        when no two contour sets are mutually complete."""
        pts = spatial.make_worm(512, waves=1, amp=0.1)
        cfg = ddc.DDCConfig(eps=0.015, min_pts=5, max_clusters=8,
                            max_verts=96, grid=32)
        _, sets = local_sets(pts, 8, cfg)
        merged, maps = ddc.merge_many(stack_sets(sets), cfg)
        assert int(merged.valid.sum()) == 1
        maps = np.asarray(maps)
        assert set(maps[maps >= 0].tolist()) == {0}


class TestEmptyShardPath:
    def test_empty_clusterset_is_cached(self):
        a = ddc.empty_clusterset(CFG)
        b = ddc.empty_clusterset(CFG)
        assert a.contours is b.contours  # no per-call rebuild
        other = ddc.DDCConfig(max_clusters=8, max_verts=32)
        c = ddc.empty_clusterset(other)
        assert c.contours.shape == (8, 32, 2)

    def test_match_to_global_empty_short_circuits(self):
        empty = ddc.empty_clusterset(CFG)
        pts, _ = spatial.make_blobs(300, 3, seed=1)
        _, gcs = ddc.local_phase(jnp.asarray(pts), jnp.ones(len(pts), bool), CFG)
        out = np.asarray(ddc.match_to_global(empty, gcs, CFG))
        np.testing.assert_array_equal(out, -1)
        out = np.asarray(ddc.match_to_global(gcs, empty, CFG))
        np.testing.assert_array_equal(out, -1)
        # The expensive per-slot scan must sit behind a runtime branch.
        jaxpr = str(jax.make_jaxpr(
            lambda c, g: ddc.match_to_global(c, g, CFG))(empty, gcs))
        assert "cond" in jaxpr

    def test_merge_with_empty_preserves(self):
        pts, _ = spatial.make_blobs(200, 3, seed=2)
        _, cs = ddc.local_phase(jnp.asarray(pts), jnp.ones(len(pts), bool), CFG)
        empty = ddc.empty_clusterset(CFG)
        batch = jax.tree.map(lambda *xs: jnp.stack(xs), empty, cs, empty)
        merged, maps = ddc.merge_many(batch, CFG)
        assert int(merged.valid.sum()) == int(cs.valid.sum())
        maps = np.asarray(maps)
        assert (maps[0] == -1).all() and (maps[2] == -1).all()

    def test_all_empty_batch(self):
        empty = ddc.empty_clusterset(CFG)
        batch = jax.tree.map(lambda *xs: jnp.stack(xs), empty, empty)
        merged, maps = ddc.merge_many(batch, CFG)
        assert int(merged.valid.sum()) == 0
        assert (np.asarray(maps) == -1).all()


class TestCommMeter:
    def test_wire_bytes_matches_config_budget(self):
        cs = ddc.empty_clusterset(CFG)
        assert compress.pytree_wire_bytes(cs) == CFG.buffer_bytes()

    def test_counters(self):
        m = ddc.CommMeter()
        m.add_collective(links=6, nbytes=100)
        m.add_collective(links=2, nbytes=50)
        m.add_merge(batch=4, slots=16)
        snap = m.snapshot()
        assert snap == {"bytes_total": 700, "collectives": 2,
                        "merge_steps": 1, "merge_slots": 64}
        m.reset()
        assert m.snapshot()["bytes_total"] == 0

    def test_schedule_accounting(self):
        """Static comm counts for the three schedules at K=8 (filled at
        trace time — no devices needed beyond eval_shape's abstract run)."""
        cfg = ddc.DDCConfig(max_clusters=8, max_verts=32, schedule="sync")
        b = cfg.buffer_bytes()
        cs = ddc.empty_clusterset(cfg)

        meters = {}
        for sched in ("sync", "async", "tree"):
            meter = ddc.CommMeter()
            fn = {"sync": ddc.merge_sync, "async": ddc.merge_async,
                  "tree": ddc.merge_tree}[sched]
            # Trace over an abstract 8-lane axis without running.
            jax.eval_shape(
                lambda c: _with_axis(fn, c, cfg, meter), cs)
            meters[sched] = meter.snapshot()

        assert meters["sync"]["bytes_total"] == 8 * 7 * b
        assert meters["sync"]["merge_steps"] == 1
        assert meters["async"]["bytes_total"] == 3 * 8 * b   # log2(8) rounds
        assert meters["async"]["merge_steps"] == 3
        # Tree(d=2): 4+4+4 up-sends + 1+2+4 broadcast hops = 19 links.
        assert meters["tree"]["bytes_total"] == 19 * b
        assert meters["tree"]["merge_steps"] == 3
        assert meters["tree"]["bytes_total"] < meters["async"]["bytes_total"]
        assert meters["async"]["bytes_total"] < meters["sync"]["bytes_total"]


def _with_axis(fn, cs, cfg, meter):
    """Run a schedule under an abstract 8-lane mesh (shape-only trace)."""
    mesh = _abstract_mesh8()
    from jax.sharding import PartitionSpec as P
    from repro import compat

    wrapped = compat.shard_map(
        lambda c: fn(c, cfg, "data", meter),
        mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(), cs),),
        out_specs=(jax.tree.map(lambda _: P(), cs), P()),
        check_vma=False,
    )
    return wrapped(cs)


def _abstract_mesh8():
    from repro import compat
    return compat.abstract_mesh((8,), ("data",))

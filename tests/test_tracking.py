"""Cluster tracking subsystem (DESIGN.md §14): stable IDs, lifecycle
events, motion analytics, TTL interaction, and the exactness contract.

The in-process tier runs on the stream backend (no device override
needed); the full stream-vs-dist × flat-vs-hier × save/load equivalence
sweep needs 8 devices for the dist lanes, so it runs in a subprocess
with the CPU device-count override (tests/_tracking_script.py),
mirroring the chaos harness pattern.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.data import spatial
from repro.ddc import DDC, ConfigError, DDCConfig
from repro.serve import tracking

SCRIPT = os.path.join(os.path.dirname(__file__), "_tracking_script.py")
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_script(arg: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(SRC)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, SCRIPT, arg],
        capture_output=True, text=True, timeout=1800, env=env)
    assert proc.returncode == 0, (
        f"{arg} failed:\n{proc.stdout}\n{proc.stderr}")
    return proc.stdout


def build(layout: str, k: int = 4, agg=None, **over) -> DDC:
    spec = spatial.TRAJECTORY_LAYOUTS[layout]
    cap = spatial.trajectory_capacity(spec["n_per_step"], spec["window"], k)
    kw = dict(
        eps=spec["eps"], min_pts=spec["min_pts"], grid=spec["grid"],
        max_clusters=spec["max_clusters"], max_verts=spec["max_verts"],
        backend="stream", shards=k, capacity=cap,
        max_batch=min(256, cap), agg_degree=agg, track=True)
    kw.update(over)
    return DDC(DDCConfig(**kw).validate())


def play(layout: str, model: DDC, **gen_over):
    spec = spatial.TRAJECTORY_LAYOUTS[layout]
    kw = dict(steps=spec["steps"], n_per_step=spec["n_per_step"])
    kw.update(gen_over)
    traj = spec["make"](**kw)
    snap = tracking.play(model, traj.frames, window=spec["window"])
    return traj, snap


def tracker_state(model: DDC):
    return model.service.tracker.state_dict()


def assert_states_equal(a, b):
    (aa, am), (ba, bm) = a, b
    assert am == bm
    assert set(aa) == set(ba)
    for key in sorted(aa):
        np.testing.assert_array_equal(aa[key], ba[key], err_msg=key)


# -- trajectory generators --------------------------------------------------


def test_trajectory_generators_deterministic():
    for name, spec in spatial.TRAJECTORY_LAYOUTS.items():
        t1 = spec["make"](steps=spec["steps"], n_per_step=spec["n_per_step"])
        t2 = spec["make"](steps=spec["steps"], n_per_step=spec["n_per_step"])
        assert len(t1.frames) == spec["steps"], name
        assert t1.centers.shape == t1.velocities.shape
        assert t1.centers.shape[0] == spec["steps"]
        for f1, f2 in zip(t1.frames, t2.frames):
            assert f1.dtype == np.float32
            assert f1.shape == (spec["n_per_step"], 2)
            assert (f1 >= 0).all() and (f1 <= 1).all()
            np.testing.assert_array_equal(f1, f2)
        np.testing.assert_array_equal(t1.centers, t2.centers)
        np.testing.assert_array_equal(t1.velocities, t2.velocities)


# -- stable identity + motion analytics -------------------------------------


def test_drifting_blobs_ids_stable():
    """The ID-stability layout: separated lanes ⇒ after the first
    generation every transition is a continuation, and the initial
    track IDs survive the whole run."""
    model = build("drifting_blobs")
    spec = spatial.TRAJECTORY_LAYOUTS["drifting_blobs"]
    _, snap = play("drifting_blobs", model)
    assert snap.generation == spec["steps"]
    assert snap.births == 3 and snap.deaths == 0
    assert snap.merges == 0 and snap.splits == 0
    assert snap.continuations == 3 * (spec["steps"] - 1)
    alive = snap.alive
    assert sorted(t.track_id for t in alive) == [0, 1, 2]
    assert all(t.born_gen == 1 and t.last_gen == snap.generation
               for t in alive)


def test_velocity_and_heading_match_ground_truth():
    """Tracker velocity = centroid displacement per generation over the
    history ring; compare against the generator's true centre path over
    the same window (robust to wall bounces)."""
    model = build("drifting_blobs")
    traj, snap = play("drifting_blobs", model)
    for t in snap.alive:
        # Map track -> blob by final-centre proximity (gen g = step g-1).
        b = int(np.argmin(
            ((traj.centers[t.last_gen - 1] - t.centroid) ** 2).sum(1)))
        g1, g0 = t.last_gen, t.last_gen - (t.hits - 1)
        true_v = (traj.centers[g1 - 1, b] - traj.centers[g0 - 1, b]) \
            / (g1 - g0)
        assert abs(t.velocity[0] - true_v[0]) < 5e-3, (t.track_id, true_v)
        assert abs(t.velocity[1] - true_v[1]) < 5e-3
        if t.speed > 2 * model.service.tracker.speed_floor:
            assert t.motion == tracking.MOTION_MOVING
            true_heading = np.degrees(np.arctan2(true_v[1], true_v[0]))
            spread = abs((t.heading_deg - true_heading + 180) % 360 - 180)
            assert spread < 30.0


def test_merging_crowds_merge_then_split():
    """Two approaching crowds fuse (merge event: the smaller track dies
    into the survivor) and separate again (split event: a new child
    track of the survivor); the stationary bystander keeps its ID."""
    model = build("merging_crowds")
    traj, snap = play("merging_crowds", model)
    assert snap.merges >= 1 and snap.splits >= 1
    merge = next(e for e in snap.events if e.kind == "merge")
    split = next(e for e in snap.events if e.kind == "split")
    assert merge.gen < split.gen
    assert merge.partner != merge.track        # absorbed into the survivor
    assert split.track >= 3                    # child gets a brand-new ID
    # Bystander at (0.5, 0.88): alive from generation 1 to the end.
    by = min(snap.alive,
             key=lambda t: (t.centroid[0] - 0.5) ** 2
             + (t.centroid[1] - 0.88) ** 2)
    assert by.born_gen == 1 and by.last_gen == snap.generation
    assert by.motion == tracking.MOTION_STATIONARY


def test_convoys_common_heading():
    model = build("convoys")
    traj, snap = play("convoys", model)
    assert snap.births == 4 and snap.merges == 0 and snap.splits == 0
    east = [t for t in snap.alive if t.centroid[1] < 0.5]
    west = [t for t in snap.alive if t.centroid[1] >= 0.5]
    assert len(east) == 2 and len(west) == 2
    for t in east:
        assert t.motion == tracking.MOTION_MOVING
        assert abs(t.heading_deg) < 30          # eastbound ≈ 0°
    for t in west:
        assert t.motion == tracking.MOTION_MOVING
        assert abs(abs(t.heading_deg) - 180) < 30   # westbound ≈ ±180°


# -- TTL eviction × tracking (satellite: death events, no ID reuse) ---------


def _two_blob_frame(seed, left=True, right=True, n=64):
    rng = np.random.default_rng(seed)
    parts = []
    if left:
        parts.append(spatial._disc(rng, n, 0.25, 0.5, 0.05))
    if right:
        parts.append(spatial._disc(rng, n, 0.75, 0.5, 0.05))
    return np.clip(np.concatenate(parts), 0, 1).astype(np.float32)


def _ingest(model, frame, t):
    for shard, part in enumerate(
            np.array_split(frame, model.config.shards)):
        if len(part):
            model.partial_fit(shard, part, t=float(t) * np.ones(len(part)))


def test_ttl_eviction_death_and_no_id_reuse():
    """Full eviction of a cluster via evict_older_than ⇒ death event;
    track IDs are never reused: re-ingesting the same location after
    eviction births a NEW track ID."""
    cfg = DDCConfig(eps=0.02, min_pts=3, grid=48, max_verts=96,
                    max_clusters=8, backend="stream", shards=2,
                    capacity=256, max_batch=128, track=True).validate()
    model = DDC(cfg)
    _ingest(model, _two_blob_frame(0), t=0)
    model.service.refresh()
    snap = model.tracks()
    assert snap.births == 2
    right0 = max(snap.alive, key=lambda t: t.centroid[0])
    left0 = min(snap.alive, key=lambda t: t.centroid[0])

    # Keep the left blob alive with fresh points; the right one ages out.
    _ingest(model, _two_blob_frame(1, right=False), t=1)
    model.expire(1.0)          # evicts every t=0 point (all of right blob)
    model.service.refresh()
    snap = model.tracks()
    assert snap.deaths == 1
    death = next(e for e in snap.events if e.kind == "death")
    assert death.track == right0.track_id
    assert not snap.track(right0.track_id).alive
    assert snap.track(left0.track_id).alive

    # Re-ingesting the evicted location births a NEW ID — never a reuse.
    _ingest(model, _two_blob_frame(2, left=False), t=2)
    model.service.refresh()
    snap = model.tracks()
    reborn = max(snap.alive, key=lambda t: t.centroid[0])
    assert reborn.track_id not in (left0.track_id, right0.track_id)
    assert reborn.track_id == snap.next_track_id - 1
    assert snap.births == 3
    ids = [t.track_id for t in snap.tracks]
    assert ids == sorted(set(ids))             # monotone, no reuse


# -- window-age gauges (satellite: oldest_ts/newest_ts) ---------------------


def test_window_age_gauges():
    cfg = DDCConfig(eps=0.02, min_pts=3, grid=48, max_verts=96,
                    max_clusters=8, backend="stream", shards=2,
                    capacity=256, max_batch=128).validate()
    model = DDC(cfg)
    st = model.stats()
    assert st.gauges.oldest_ts is None and st.gauges.newest_ts is None

    _ingest(model, _two_blob_frame(0), t=5)
    _ingest(model, _two_blob_frame(1), t=7)
    st = model.stats()
    assert st.gauges.oldest_ts == 5.0 and st.gauges.newest_ts == 7.0
    d = st.as_dict()
    assert d["oldest_ts"] == 5.0 and d["newest_ts"] == 7.0

    model.expire(6.0)
    st = model.stats()
    assert st.gauges.oldest_ts == 7.0 and st.gauges.newest_ts == 7.0

    model.expire(100.0)        # window empty again
    st = model.stats()
    assert st.gauges.oldest_ts is None and st.gauges.newest_ts is None


def test_window_age_gauges_batch_backends_default_none():
    cfg = DDCConfig(backend="host", shards=2).validate()
    model = DDC(cfg)
    model.fit(_two_blob_frame(0))
    st = model.stats()
    assert st.gauges.oldest_ts is None and st.gauges.newest_ts is None
    assert "oldest_ts" in st.as_dict()


# -- config plumbing / per-call override ------------------------------------


def test_tracking_config_validation():
    with pytest.raises(ConfigError):
        DDCConfig(backend="host", track=True).validate()
    with pytest.raises(ConfigError):
        DDCConfig(backend="stream", track=True, track_history=1).validate()
    with pytest.raises(ConfigError):
        DDCConfig(backend="stream", match_min_overlap=1.0).validate()
    with pytest.raises(ConfigError):
        DDCConfig(backend="stream", match_min_overlap=-0.1).validate()


def test_tracks_requires_tracking_enabled():
    model = build("drifting_blobs", track=False)
    with pytest.raises(ConfigError):
        model.tracks()
    host = DDC(DDCConfig(backend="host").validate())
    with pytest.raises(ConfigError):
        host.tracks()


def test_per_call_track_override():
    model = build("drifting_blobs", k=2)
    _ingest(model, _two_blob_frame(0), t=0)
    model.service.refresh(track=False)      # fold skipped for this call
    assert model.service.tracker.generation == 0
    model.service.refresh(force=True, track=True)
    assert model.service.tracker.generation == 1
    _ingest(model, _two_blob_frame(1), t=1)
    model.service.refresh()                 # default: tracked (healthy)
    assert model.service.tracker.generation == 2


def test_track_snapshot_version_matches_labels_snapshot():
    model = build("drifting_blobs", k=2)
    _ingest(model, _two_blob_frame(0), t=0)
    model.service.refresh()
    snap = model.tracks()
    read = model.service.snapshot()
    assert snap.version == read.version
    assert snap.epoch == read.epoch


# -- exactness: flat vs hier + save/load in-process (stream) ----------------


def test_flat_vs_hier_and_save_load_resume(tmp_path):
    layout = "merging_crowds"
    spec = spatial.TRAJECTORY_LAYOUTS[layout]
    traj = spec["make"](steps=spec["steps"], n_per_step=spec["n_per_step"])
    flat = build(layout)
    hier = build(layout, agg=2)
    tracking.play(flat, traj.frames, window=spec["window"])
    tracking.play(hier, traj.frames, window=spec["window"])
    assert_states_equal(tracker_state(flat), tracker_state(hier))

    half = len(traj.frames) // 2
    part1 = build(layout)
    tracking.play(part1, traj.frames[:half], window=spec["window"])
    part1.save(str(tmp_path / "snap"))
    resumed = DDC.load(str(tmp_path / "snap"))
    for m in (part1, resumed):
        for i, frame in enumerate(traj.frames[half:]):
            step = half + i
            for shard, part in enumerate(
                    np.array_split(frame, m.config.shards)):
                if len(part):
                    m.partial_fit(shard, part,
                                  t=float(step) * np.ones(len(part)))
            if step + 1 > spec["window"]:
                m.expire(float(step - spec["window"] + 1))
            m.service.refresh()
    assert_states_equal(tracker_state(part1), tracker_state(resumed))
    assert_states_equal(tracker_state(flat), tracker_state(resumed))


# -- the full engine × topology × restore sweep (subprocess, 8 devices) -----


def test_tracking_equivalence_quick():
    """Drifting blobs × {2,4,8} shards: stream flat ≡ stream hier ≡
    dist flat ≡ dist hier ≡ save→load→resume, bit-identical tracker
    state (IDs, events, histories)."""
    out = run_script("quick")
    assert "ALL_OK" in out and out.count("PASS") == 3


@pytest.mark.slow
def test_tracking_equivalence_full_sweep():
    """Every trajectory layout × {2,4,8} shards."""
    out = run_script("all")
    assert "ALL_OK" in out and out.count("PASS") == 9

"""Geometry unit + property tests (hulls, contours, overlap)."""
import numpy as np
import jax.numpy as jnp
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.core import geometry as G

pts_strategy = st.lists(
    st.tuples(st.floats(0, 1, allow_nan=False, width=32),
              st.floats(0, 1, allow_nan=False, width=32)),
    min_size=4, max_size=64,
).map(lambda l: np.array(l, dtype=np.float64))


class TestConvexHullNp:
    def test_square(self):
        pts = np.array([[0, 0], [1, 0], [1, 1], [0, 1], [0.5, 0.5]])
        hull = G.convex_hull_np(pts)
        assert len(hull) == 4
        assert {tuple(p) for p in hull} == {(0, 0), (1, 0), (1, 1), (0, 1)}

    def test_degenerate(self):
        assert len(G.convex_hull_np(np.array([[0.0, 0.0]]))) == 1
        assert len(G.convex_hull_np(np.array([[0, 0], [1, 1.0]]))) == 2
        collinear = np.array([[0, 0], [1, 1], [2, 2.0], [3, 3]])
        hull = G.convex_hull_np(collinear)
        assert len(hull) == 2  # endpoints only

    @settings(max_examples=30, deadline=None)
    @given(pts_strategy)
    def test_hull_contains_all_points(self, pts):
        hull = G.convex_hull_np(pts)
        if len(hull) < 3:
            return
        # every point inside or on the hull (inflate slightly for boundary)
        centroid = hull.mean(0)
        big = centroid + (hull - centroid) * (1 + 1e-6) + 1e-9
        inside = G.point_in_polygon_np(pts, big)
        assert inside.all()

    @settings(max_examples=30, deadline=None)
    @given(pts_strategy)
    def test_jax_hull_matches_np(self, pts):
        # Quantise to a coarse grid: collinearity decisions are then exact
        # in BOTH the f64 oracle and the f32 Jarvis march (hypothesis
        # otherwise finds sub-f32 near-collinear vertices on which the two
        # precisions legitimately disagree).
        pts = np.round(pts.astype(np.float64), 2)
        hull_np = G.convex_hull_np(pts)
        hull_j, cnt = G.convex_hull_jax(
            jnp.asarray(pts, jnp.float32), jnp.ones(len(pts), bool), max_verts=70
        )
        got = {(round(float(x), 3), round(float(y), 3))
               for x, y in np.asarray(hull_j)[: int(cnt)]}
        want = {(round(float(x), 3), round(float(y), 3)) for x, y in hull_np}
        # Jarvis includes collinear-farthest only; vertex SETS must match
        assert len(want - got) == 0, (want, got)


class TestPolygonOverlap:
    def test_disjoint(self):
        a = np.array([[0, 0], [0.2, 0], [0.2, 0.2], [0, 0.2]])
        b = a + 0.5
        assert not G.polygons_overlap_np(a, b)

    def test_contained(self):
        outer = np.array([[0, 0], [1, 0], [1, 1], [0, 1]])
        inner = outer * 0.2 + 0.4
        assert G.polygons_overlap_np(outer, inner)
        assert G.polygons_overlap_np(inner, outer)

    def test_edge_crossing(self):
        a = np.array([[0, 0], [1, 0], [1, 1], [0, 1.0]])
        b = a + np.array([0.5, 0.5])
        assert G.polygons_overlap_np(a, b)


class TestGridContour:
    def test_ring_boundary_excludes_interior(self):
        rng = np.random.default_rng(0)
        pts = rng.uniform(0.3, 0.7, (4000, 2)).astype(np.float32)
        contour, cnt = G.extract_contour(
            jnp.asarray(pts), jnp.ones(len(pts), bool), (0, 0, 1, 1), 32, 256
        )
        cnt = int(cnt)
        assert cnt > 8
        c = np.asarray(contour)[:cnt]
        # boundary cells only: none deep inside the square
        interior = (c[:, 0] > 0.38) & (c[:, 0] < 0.62) & (c[:, 1] > 0.38) & (c[:, 1] < 0.62)
        assert interior.mean() < 0.2

    def test_matches_np_oracle(self):
        rng = np.random.default_rng(1)
        pts = rng.uniform(0.2, 0.5, (500, 2)).astype(np.float32)
        occ_np = G.grid_contour_np(pts, (0, 0, 1, 1), 32)
        contour, cnt = G.extract_contour(
            jnp.asarray(pts), jnp.ones(len(pts), bool), (0, 0, 1, 1), 32, 512
        )
        assert int(cnt) == len(occ_np)

    def test_mask_respected(self):
        pts = np.array([[0.1, 0.1], [0.9, 0.9]], np.float32)
        mask = jnp.array([True, False])
        contour, cnt = G.extract_contour(jnp.asarray(pts), mask, (0, 0, 1, 1), 16, 8)
        assert int(cnt) == 1


class TestSubsample:
    def test_farthest_point_keeps_extremes(self):
        pts = np.zeros((50, 2), np.float32)
        pts[0] = [0, 0]
        pts[1] = [1, 1]
        pts[2:] = 0.5
        sub, cnt = G.farthest_point_subsample(
            jnp.asarray(pts), jnp.ones(50, bool), 4
        )
        s = {tuple(np.round(p, 3)) for p in np.asarray(sub)[: int(cnt)]}
        assert (0, 0) in s and (1, 1) in s

    def test_count_caps_at_valid(self):
        pts = np.random.default_rng(0).uniform(size=(10, 2)).astype(np.float32)
        mask = jnp.asarray([True] * 3 + [False] * 7)
        sub, cnt = G.farthest_point_subsample(jnp.asarray(pts), mask, 8)
        assert int(cnt) == 3

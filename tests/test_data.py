"""Data pipeline + DDC curation tests."""
import numpy as np

from repro.data import curation, pipeline


def dcfg(**kw):
    base = dict(vocab=512, seq_len=32, global_batch=4, seed=3,
                n_latent_clusters=8)
    base.update(kw)
    return pipeline.DataConfig(**base)


class TestPipeline:
    def test_deterministic(self):
        a = pipeline.batch_at(dcfg(), 7)
        b = pipeline.batch_at(dcfg(), 7)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_distinct_batches(self):
        a = pipeline.batch_at(dcfg(), 1)
        b = pipeline.batch_at(dcfg(), 2)
        assert not np.array_equal(a["tokens"], b["tokens"])

    def test_restart_exact(self):
        """Fault tolerance: restarting at step k reproduces the stream."""
        it = pipeline.iterate(dcfg(), 0)
        seq1 = [next(it)["tokens"] for _ in range(5)]
        it2 = pipeline.iterate(dcfg(), 3)
        np.testing.assert_array_equal(seq1[3], next(it2)["tokens"])

    def test_frontend_stubs(self):
        cfg = dcfg(frontend="audio_stub", frontend_seq=10, d_model=16)
        b = pipeline.batch_at(cfg, 0)
        assert b["frames"].shape == (4, 10, 16)
        cfg = dcfg(prefix_len=6, d_model=16)
        assert pipeline.batch_at(cfg, 0)["prefix"].shape == (4, 6, 16)

    def test_token_range(self):
        b = pipeline.batch_at(dcfg(), 0)
        assert b["tokens"].min() >= 0 and b["tokens"].max() < 512


class TestCuration:
    def test_finds_cluster_structure(self):
        cfg = dcfg(n_latent_clusters=6)
        emb, ids = pipeline.doc_embeddings(cfg, 1200)
        res = curation.curate(emb)
        assert 4 <= res.n_clusters <= 8, res.n_clusters
        # cluster labels should align with latent ids (purity)
        pure = 0
        for c in range(res.n_clusters):
            members = ids[res.labels == c]
            if len(members):
                pure += (members == np.bincount(members).argmax()).sum()
        assert pure / (res.labels >= 0).sum() > 0.9

    def test_weights_normalised_and_balanced(self):
        cfg = dcfg(n_latent_clusters=4)
        emb, ids = pipeline.doc_embeddings(cfg, 800)
        # skew: keep only a quarter of cluster-0 docs (still dense enough
        # for per-partition DBSCAN to find the cluster)
        keep = (ids != 0) | (np.arange(800) % 4 == 0)
        res = curation.curate(emb[keep])
        assert abs(res.sample_weights.sum() - 1.0) < 1e-9
        assert res.n_clusters == 4
        # the rare cluster must be upweighted
        assert res.sample_weights.max() / res.sample_weights.min() > 1.3

    def test_apply_to_data_config(self):
        cfg = dcfg(n_latent_clusters=4)
        emb, ids = pipeline.doc_embeddings(cfg, 400)
        res = curation.curate(emb)
        new = curation.apply_to_data_config(cfg, res, ids)
        assert new.curation_weights is not None
        assert abs(new.curation_weights.sum() - 1.0) < 1e-9
        b = pipeline.batch_at(new, 0)
        assert b["tokens"].shape == (4, 32)

"""Optional-hypothesis shim for the property-based tests.

``hypothesis`` is a dev-only dependency; when it is missing the
property-based tests must *skip* instead of erroring the whole collection
(requirements-dev.txt installs it for full coverage).  Importing
``given``/``settings``/``st`` from here gives real hypothesis when
available and skip-marking stand-ins otherwise, so the non-property tests
in the same modules keep running either way.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        return lambda f: pytest.mark.skip(reason="hypothesis not installed")(f)

    def settings(*_a, **_k):
        if _a and callable(_a[0]):  # bare @settings usage
            return _a[0]
        return lambda f: f

    class _Strategy:
        """Chainable stand-in: any attribute access or call (``st.lists(...)
        .map(...)`` etc.) yields another stand-in; values are never drawn."""

        def __getattr__(self, _name):
            return self

        def __call__(self, *_a, **_k):
            return self

    st = _Strategy()

"""Facade suite: `repro.ddc.DDC` over pluggable backends.

The API contract under test:

* ``DDCConfig.validate()`` rejects every backend/schedule mismatch and
  (with a sample) DESIGN §7 sizing violations at construction time;
* ``host`` / ``jit`` / ``stream`` produce the identical global
  clustering through the one ``fit``/``partial_fit`` surface (the jit
  backend needs a multi-device override, so that sweep runs in a
  subprocess — tests/_api_script.py);
* ``save`` → ``load`` → resume is bit-identical to an uninterrupted
  streaming run (labels AND the cached pair-d2 matrix);
* TTL eviction (``partial_fit(..., t=...)`` + ``expire``) drops exactly
  the stamped points and the survivors still match batch ``ddc_host``;
* a query against a fresh service returns all-noise without compiling
  or refreshing anything.

Big sweeps are marked ``slow`` (non-blocking CI job).
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import ddc as core_ddc
from repro.data import spatial
from repro.ddc import (
    BACKENDS, DDC, ConfigError, DDCConfig, same_clustering,
)

N = 2048
SCRIPT = os.path.join(os.path.dirname(__file__), "_api_script.py")
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def layout_config(layout: str, **kw) -> DDCConfig:
    spec = spatial.PHASE2_LAYOUTS[layout]
    return DDCConfig(
        eps=spec["eps"], min_pts=spec["min_pts"], grid=spec["grid"],
        max_clusters=spec["max_clusters"], max_verts=spec["max_verts"], **kw)


def layout_points(layout: str, n: int = N) -> np.ndarray:
    return spatial.PHASE2_LAYOUTS[layout]["make"](n)


class TestConfigValidate:
    def test_all_backends_registered(self):
        assert set(BACKENDS) == {"host", "jit", "stream", "dist"}

    @pytest.mark.parametrize("kw", [
        dict(eps=-1.0),
        dict(min_pts=0),
        dict(grid=1),
        dict(bounds=(0.0, 0.0, 0.0, 1.0)),
        dict(backend="no-such-backend"),
        dict(schedule="ring-allreduce"),
        dict(local_algo="optics"),
        dict(merge_refine="chaikin"),
        dict(merge_mode="approx"),
        dict(tree_degree=1),
        dict(shards=0),
        dict(backend="jit", schedule="async", shards=6),
        dict(backend="stream", capacity=8, max_batch=64),
    ])
    def test_rejects_broken_configs(self, kw):
        with pytest.raises(ConfigError):
            DDCConfig(**kw).validate()

    def test_async_non_pow2_is_fine_off_the_jit_backend(self):
        # The butterfly constraint is a jit-backend property: the host
        # oracle and the stream engine never run the schedule.
        DDCConfig(backend="host", schedule="async", shards=6).validate()
        DDCConfig(backend="stream", schedule="async", shards=6).validate()

    def test_validate_returns_self(self):
        cfg = layout_config("rings")
        assert cfg.validate() is cfg

    def test_sizing_probe_rejects_overflowing_merged_contour(self):
        # The §7 failure mode: the worm's *global* outline at a fine
        # raster exceeds a small vertex budget even though every
        # per-shard segment would fit.
        spec = spatial.PHASE2_LAYOUTS["worm"]
        pts = layout_points("worm")
        with pytest.raises(ConfigError, match="merged contour"):
            DDCConfig(eps=spec["eps"], min_pts=spec["min_pts"],
                      grid=128, max_verts=32, max_clusters=8,
                      ).validate(sample=pts)

    def test_sizing_probe_rejects_cluster_budget_overflow(self):
        pts = layout_points("noise_heavy")
        spec = spatial.PHASE2_LAYOUTS["noise_heavy"]
        with pytest.raises(ConfigError, match="max_clusters"):
            DDCConfig(eps=spec["eps"], min_pts=spec["min_pts"],
                      grid=spec["grid"], max_verts=spec["max_verts"],
                      max_clusters=2).validate(sample=pts)

    @pytest.mark.parametrize("layout", ("rings", "worm"))
    def test_sizing_probe_accepts_tuned_layouts(self, layout):
        layout_config(layout).validate(sample=layout_points(layout))


class TestFacade:
    def test_host_equals_stream_through_fit(self):
        pts = layout_points("rings")
        labels = {}
        for backend in ("host", "stream"):
            model = DDC(layout_config("rings", backend=backend, shards=2))
            labels[backend] = model.fit(pts).labels_
        assert same_clustering(labels["host"], labels["stream"])

    def test_partial_fit_equals_fit(self):
        pts = layout_points("linked_ovals")
        cfg = layout_config("linked_ovals", backend="host", shards=2)
        whole = DDC(cfg).fit(pts)
        piecewise = DDC(cfg)
        for shard, idx in enumerate(np.array_split(np.arange(len(pts)), 2)):
            for off in range(0, len(idx), 300):
                piecewise.partial_fit(shard, pts[idx[off:off + 300]])
        assert np.array_equal(whole.labels_, piecewise.labels_)
        assert np.array_equal(whole.points_, piecewise.points_)

    def test_query_returns_own_labels(self):
        pts = layout_points("rings")
        model = DDC(layout_config("rings", backend="host", shards=2)).fit(pts)
        labels = model.labels_
        got = model.query(pts[:256])
        clustered = labels[:256] >= 0
        np.testing.assert_array_equal(got[clustered], labels[:256][clustered])
        assert (model.query(np.array([[7.0, 7.0]])) == -1).all()

    def test_comm_stats_records_backend(self):
        pts = layout_points("rings", 512)
        model = DDC(layout_config("rings", backend="host", shards=2)).fit(pts)
        stats = model.comm_stats()
        assert stats["backend"] == "host"
        assert stats["bytes_total"] > 0

    def test_expire_requires_stream_backend(self):
        model = DDC(layout_config("rings", backend="host", shards=2))
        with pytest.raises(ConfigError, match="stream"):
            model.expire(0.0)

    def test_save_load_host_backend(self, tmp_path):
        pts = layout_points("rings")
        model = DDC(layout_config("rings", backend="host", shards=2)).fit(pts)
        model.save(str(tmp_path / "ckpt"))
        restored = DDC.load(str(tmp_path / "ckpt"))
        assert restored.config == model.config
        assert np.array_equal(restored.labels_, model.labels_)
        assert np.array_equal(restored.points_, model.points_)


class TestQueryBeforeRefresh:
    def test_fresh_service_queries_all_noise_without_refresh(self):
        """Regression: a query before any refresh (no global set yet)
        must return all-noise labels, not fail — and must not compile
        or run the merge pipeline for an empty service."""
        model = DDC(layout_config("rings", backend="stream", shards=2,
                                  capacity=64, max_batch=64))
        out = model.query(np.array([[0.5, 0.5], [0.1, 0.9]]))
        np.testing.assert_array_equal(out, [-1, -1])
        assert model.service.refreshes == 0

    def test_first_ingest_then_query_refreshes(self):
        pts = layout_points("rings", 512)
        model = DDC(layout_config("rings", backend="stream", shards=2,
                                  capacity=512))
        model.partial_fit(0, pts[:256])
        got = model.query(pts[:8])
        assert model.service.refreshes == 1
        assert got.shape == (8,)


class TestBackendEquivalence:
    """All three backends through one front door == one clustering."""

    def run_script(self, layout: str) -> str:
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.abspath(SRC)
        env.pop("XLA_FLAGS", None)
        proc = subprocess.run(
            [sys.executable, SCRIPT, layout],
            capture_output=True, text=True, timeout=900, env=env)
        assert proc.returncode == 0, (
            f"{layout} failed:\n{proc.stdout}\n{proc.stderr}")
        return proc.stdout

    def test_backends_agree_quick(self):
        out = self.run_script("linked_ovals")
        assert "ALL_OK" in out and out.count("PASS") == 3

    @pytest.mark.slow
    @pytest.mark.parametrize("layout", sorted(spatial.PHASE2_LAYOUTS))
    def test_backends_agree_sweep(self, layout):
        out = self.run_script(layout)
        assert "ALL_OK" in out and out.count("PASS") == 3


def stream_halves(layout: str, k: int, capacity: int | None = None):
    pts = layout_points(layout)
    cfg = layout_config(
        layout, backend="stream", shards=k,
        capacity=capacity or spatial.shard_capacity(len(pts), k),
        max_batch=128)
    batches = spatial.stream_batches(pts, k, 128)
    return cfg, batches, len(batches) // 2


def assert_resume_bit_identical(layout: str, k: int, tmp_path):
    """Stream N batches, save, load, stream M more: labels and the
    cached pair-d2 matrix must equal an uninterrupted run bit-for-bit."""
    cfg, batches, half = stream_halves(layout, k)

    uninterrupted = DDC(cfg)
    for shard, chunk in batches:
        uninterrupted.partial_fit(shard, chunk)
    ref_labels = uninterrupted.labels_

    interrupted = DDC(cfg)
    for shard, chunk in batches[:half]:
        interrupted.partial_fit(shard, chunk)
    interrupted.labels_                      # refresh mid-stream
    path = str(tmp_path / f"ckpt-{layout}-{k}")
    interrupted.save(path)
    resumed = DDC.load(path)
    for shard, chunk in batches[half:]:
        resumed.partial_fit(shard, chunk)

    np.testing.assert_array_equal(ref_labels, resumed.labels_)
    np.testing.assert_array_equal(
        np.asarray(uninterrupted.service.pair_d2),
        np.asarray(resumed.service.pair_d2))


class TestSnapshotRestore:
    def test_resume_bit_identical_quick(self, tmp_path):
        assert_resume_bit_identical("rings", 2, tmp_path)

    def test_restore_preserves_engine_counters_and_state(self, tmp_path):
        cfg, batches, half = stream_halves("rings", 2)
        model = DDC(cfg)
        for shard, chunk in batches[:half]:
            model.partial_fit(shard, chunk)
        model.labels_
        path = str(tmp_path / "ckpt")
        model.save(path)
        restored = DDC.load(path)
        svc, rsvc = model.service, restored.service
        assert rsvc.refreshes == svc.refreshes
        assert rsvc.n_live() == svc.n_live()
        assert rsvc._head == svc._head and rsvc._count == svc._count
        np.testing.assert_array_equal(
            np.asarray(svc.pair_d2), np.asarray(rsvc.pair_d2))
        # No pending work: the restored service answers reads directly.
        before = rsvc.refreshes
        np.testing.assert_array_equal(restored.labels_, model.labels_)
        assert rsvc.refreshes == before

    @pytest.mark.slow
    @pytest.mark.parametrize("layout", sorted(spatial.PHASE2_LAYOUTS))
    def test_resume_bit_identical_sweep(self, layout, tmp_path):
        for k in (2, 4, 8):
            assert_resume_bit_identical(layout, k, tmp_path)


class TestTTLEviction:
    def assert_matches_host(self, model):
        pts, parts, labels = model.service.live()
        spec = spatial.PHASE2_LAYOUTS["rings"]
        host, _, _ = core_ddc.ddc_host(
            pts, len(parts), spec["eps"], spec["min_pts"],
            partition=parts, contour="grid")
        assert same_clustering(labels, host)

    def test_expire_drops_exactly_the_stamped_window(self):
        pts = layout_points("rings")
        model = DDC(layout_config("rings", backend="stream", shards=2,
                                  capacity=1024))
        for i, (shard, chunk) in enumerate(
                spatial.stream_batches(pts, 2, 256)):
            model.partial_fit(shard, chunk, t=float(i))
        assert len(model.labels_) == len(pts)
        evicted = model.expire(t=4.0)        # drop batches stamped 0..3
        assert evicted == 4 * 256
        assert len(model.labels_) == len(pts) - evicted
        self.assert_matches_host(model)

    def test_default_timestamps_are_ingest_sequence(self):
        pts = layout_points("rings", 512)
        model = DDC(layout_config("rings", backend="stream", shards=2,
                                  capacity=512))
        model.partial_fit(0, pts[:200])
        model.partial_fit(1, pts[200:400])
        assert model.expire(t=100.0) == 100   # first 100 ingested points
        assert len(model.labels_) == 300

    def test_ttl_holes_then_ring_overwrite_stays_consistent(self):
        """Punch TTL holes mid-ring, then ingest past capacity: the
        append wrap must keep the live set exact (holes are legal)."""
        pts = layout_points("rings", 1024)
        model = DDC(layout_config("rings", backend="stream", shards=2,
                                  capacity=256, max_batch=128))
        svc = model.service
        for i, (shard, chunk) in enumerate(
                spatial.stream_batches(pts[:512], 2, 128)):
            model.partial_fit(shard, chunk, t=float(i))
        assert svc.evict_older_than(0, 2.0) > 0
        # Overfill both rings: wraps over dead and oldest-live slots.
        for shard, chunk in spatial.stream_batches(pts[512:], 2, 128):
            model.partial_fit(shard, chunk, t=99.0)
        live_pts, parts, labels = svc.live()
        assert len(live_pts) == sum(len(p) for p in parts)
        assert svc.n_live() == len(live_pts)
        self.assert_matches_host(model)

    def test_fit_timestamp_joins_wall_clock_expiry(self):
        """Regression: the facade lifecycle fit(pts, t=t0) →
        partial_fit(..., t=now) → expire(cutoff) must age out only what
        the cutoff names — fit-ingested data must not be treated as
        infinitely old (the default sequence stamps would be)."""
        pts = layout_points("rings", 512)
        t0 = 1_700_000_000.0
        model = DDC(layout_config("rings", backend="stream", shards=2,
                                  capacity=512))
        model.fit(pts, t=t0)
        model.partial_fit(0, pts[:16], t=t0 + 60.0)
        assert model.expire(t0 - 3600.0) == 0     # nothing is older
        assert len(model.labels_) == 512 + 16
        assert model.expire(t0 + 30.0) == 512     # only the fitted batch
        assert len(model.labels_) == 16

    def test_append_refills_ttl_holes_before_touching_live(self):
        """Regression: TTL holes *behind* the ring head must be refilled
        by the next append — live (newer) points are only overwritten
        when the buffer is genuinely full."""
        model = DDC(layout_config("rings", backend="stream", shards=1,
                                  capacity=8, max_batch=8))
        svc = model.service
        rng = np.random.default_rng(1)
        a = rng.uniform(0, 1, (4, 2)).astype(np.float32)
        b = rng.uniform(0, 1, (4, 2)).astype(np.float32)
        c = rng.uniform(0, 1, (4, 2)).astype(np.float32)
        model.partial_fit(0, a, t=100.0)      # slots 0-3 (new data)
        model.partial_fit(0, b, t=1.0)        # slots 4-7 (old data)
        assert svc.evict_older_than(0, 50.0) == 4   # holes at 4-7
        model.partial_fit(0, c, t=200.0)      # must land in the holes
        assert svc.n_live() == 8
        live = np.asarray(svc._pts[0])[np.asarray(svc._mask[0])]
        survivors = {tuple(p) for p in live.tolist()}
        for p in np.concatenate([a, c]).tolist():
            assert tuple(p) in survivors      # nothing live was lost

    def test_evict_oldest_follows_sequence_across_holes(self):
        model = DDC(layout_config("rings", backend="stream", shards=1,
                                  capacity=64, max_batch=64))
        svc = model.service
        rng = np.random.default_rng(0)
        model.partial_fit(0, rng.uniform(0, 1, (30, 2)), t=0.0)
        model.partial_fit(0, rng.uniform(0, 1, (20, 2)), t=1.0)
        svc.evict_older_than(0, 0.5)          # kill the first 30 -> hole
        assert svc.n_live() == 20
        assert svc.evict_oldest(0, 5) == 5    # oldest survivors, by seq
        assert svc.n_live() == 15
        assert svc.evict_oldest(0, 99) == 15  # clamped to live count
        assert svc.n_live() == 0

"""Failure-model suite for the serve stack (DESIGN.md §11).

Covers the fault-injection seam end to end on the host-driven engine:
the validation gate must reject poisoned/corrupt deltas BEFORE the
cached pair-d2 matrix is touched, transient drops heal through the
retry loop with no state divergence, duplicates are epoch-fenced
(exactly-once merge), a killed lane quarantines and healthy shards keep
serving (with the staleness flag raised), and journal-replay recovery
lands bit-exactly on the fault-free twin — labels AND the cached
pair-d2 matrix.  Plus the snapshot-robustness satellites: every way a
snapshot directory can be damaged must raise ``SnapshotError`` from
``DDC.load`` without disturbing a live model.

The multi-backend chaos sweep (random seeded plans, 2/4/8 shards,
stream AND dist) lives in tests/_chaos_script.py / test_chaos.py; this
file is the fast in-process tier.
"""
import json
import os

import numpy as np
import pytest

from repro.core import ddc
from repro.data import spatial
from repro.serve import (
    ClusterService,
    FaultEvent,
    FaultPlan,
    StreamConfig,
)

N = 640
K = 4
CAP = None  # spatial.shard_capacity(N, K), resolved in build()


def build(layout="rings", k=K, faults=None, journal_limit=1024,
          max_retries=2):
    spec = spatial.PHASE2_LAYOUTS[layout]
    pts = spec["make"](N)
    cap = spatial.shard_capacity(N, k)
    scfg = StreamConfig(
        shards=k, capacity=cap, max_batch=min(160, cap),
        max_retries=max_retries, journal_limit=journal_limit,
        ddc=ddc.DDCConfig(
            eps=spec["eps"], min_pts=spec["min_pts"], grid=spec["grid"],
            max_clusters=spec["max_clusters"], max_verts=spec["max_verts"]))
    return ClusterService(scfg, faults=faults), pts, spec


def stream_in(svc, pts, k, batch=160):
    for shard, chunk in spatial.stream_batches(pts, k, batch):
        svc.ingest(shard, chunk)
        svc.refresh()


def assert_bitexact(faulted, twin):
    """Post-recovery contract: labels AND the cached pair-d2 matrix of
    the faulted service are bit-identical to the uninterrupted twin."""
    pa, pb = faulted.pair_d2, twin.pair_d2
    assert pa is not None and pb is not None
    assert np.array_equal(np.asarray(pa), np.asarray(pb)), \
        "cached pair-d2 diverged from the fault-free twin"
    fp, _, fl = faulted.live()
    tp, _, tl = twin.live()
    assert np.array_equal(fp, tp)
    assert np.array_equal(fl, tl), \
        "global labels diverged from the fault-free twin"


def twins(faults, **kw):
    """A faulted service and its fault-free twin, fed identically."""
    svc_f, pts, spec = build(faults=faults, **kw)
    svc_t, _, _ = build(**kw)
    stream_in(svc_f, pts, K)
    stream_in(svc_t, pts, K)
    return svc_f, svc_t, pts, spec


class TestValidationGate:
    @pytest.mark.parametrize("kind", ["poison", "corrupt"])
    def test_bad_delta_rejected_before_pair_d2(self, kind):
        """A mangled delta must quarantine its shard and leave the
        cached pair-d2 matrix bit-untouched — the gate runs BEFORE any
        aggregator state."""
        svc, pts, spec = build()
        stream_in(svc, pts, K)
        before = np.asarray(svc.pair_d2)
        svc.faults = FaultPlan(events=(FaultEvent(kind, shard=1),), seed=3)
        svc.ingest(1, pts[:16])
        svc.refresh()
        assert 1 in svc.quarantined
        assert "rejected" in svc.quarantined[1]
        assert np.array_equal(before, np.asarray(svc.pair_d2)), \
            f"{kind} delta reached the pair-d2 cache"

    def test_healthy_shards_keep_serving_degraded(self):
        """During quarantine the service answers from healthy shards and
        flags the answer stale exactly when the lost shard mattered."""
        svc, pts, spec = build()
        stream_in(svc, pts, K)
        svc.faults = FaultPlan(events=(FaultEvent("poison", shard=1),))
        svc.ingest(1, pts[:16])
        svc.refresh()
        labels, stale = svc.query(pts[:64], return_stale=True)
        assert labels.shape == (64,)        # healthy shards answered
        assert stale                        # round-robin: shard 1 mattered
        assert svc.last_query_degraded
        assert svc.degraded_queries == 1
        assert svc.stats()["quarantined_now"] == [1]


class TestRetryAndFencing:
    def test_transient_drop_heals_by_retry(self):
        plan = FaultPlan(events=(
            FaultEvent("drop", shard=0, delivery=None, attempts=1),))
        svc_f, svc_t, pts, _ = twins(None)
        svc_f.faults = plan
        for svc in (svc_f, svc_t):
            svc.ingest(0, pts[:32])
            svc.refresh()
        assert svc_f.retries >= 1
        assert not svc_f.quarantined
        assert_bitexact(svc_f, svc_t)

    def test_exhausted_drop_quarantines(self):
        plan = FaultPlan(events=(
            FaultEvent("drop", shard=2, delivery=None, attempts=5),))
        svc, pts, _ = build(faults=None, max_retries=2)
        stream_in(svc, pts, K)
        svc.faults = plan
        svc.ingest(2, pts[:32])
        svc.refresh()
        assert 2 in svc.quarantined
        assert "dropped" in svc.quarantined[2]
        assert svc.retries >= 2

    def test_duplicate_delivery_is_fenced(self):
        """A late duplicate of an already-merged delta must be discarded
        by the epoch fence (exactly-once), not re-merged."""
        plan = FaultPlan(events=(FaultEvent("dup", shard=3),))
        svc_f, svc_t, pts, _ = twins(None)
        svc_f.faults = plan
        for svc in (svc_f, svc_t):
            svc.ingest(3, pts[:32])
            svc.refresh()
        assert svc_f.fenced_deltas == 1
        assert not svc_f.quarantined
        assert_bitexact(svc_f, svc_t)


class TestKillAndRecovery:
    def test_kill_recover_bitexact(self):
        """The tentpole contract: lane killed mid-refresh -> quarantine
        (healthy shards keep serving) -> journal-replay recovery ->
        state bit-identical to the uninterrupted twin."""
        plan = FaultPlan(events=(FaultEvent("kill", shard=1),))
        svc_f, svc_t, pts, _ = twins(None)
        svc_f.faults = plan
        for svc in (svc_f, svc_t):
            svc.ingest(1, pts[:32])
            svc.refresh()                 # faulted: lane 1 dies here
        assert 1 in svc_f.quarantined
        # Writes keep landing during the outage: journaled + mirrored,
        # device lane untouched until recovery.
        for svc in (svc_f, svc_t):
            svc.ingest(1, pts[32:64])
            svc.ingest(0, pts[64:96])
            svc.refresh()
        assert 1 in svc_f.quarantined     # still out
        assert svc_f.recover(1)
        svc_f.refresh()
        assert not svc_f.quarantined
        assert_bitexact(svc_f, svc_t)
        # idempotent: recovering a healthy shard is a no-op
        assert not svc_f.recover(1)

    def test_recovery_with_journal_compaction(self):
        """A tiny journal_limit forces compactions mid-stream; replay
        from the compacted base must still land bit-exactly."""
        plan = FaultPlan(events=(FaultEvent("kill", shard=0),))
        svc_f, _, _ = build(faults=plan, journal_limit=2)
        svc_t, pts, _ = build(journal_limit=2)
        stream_in(svc_f, pts, K, batch=40)
        stream_in(svc_t, pts, K, batch=40)
        assert svc_f._journal.compactions > 0
        for svc in (svc_f, svc_t):
            svc.evict_oldest(0, 8)        # kill entries journal too
            svc.ingest(0, pts[:32])
            svc.refresh()
        assert 0 in svc_f.quarantined
        assert svc_f.recover(0)
        svc_f.refresh()
        assert_bitexact(svc_f, svc_t)

    def test_quarantine_survives_snapshot(self):
        """state_dict/from_state round-trips the quarantine set, epochs,
        and counters; recovery still works on the restored service."""
        plan = FaultPlan(events=(FaultEvent("kill", shard=2),))
        svc_f, svc_t, pts, _ = twins(None)
        svc_f.faults = plan
        for svc in (svc_f, svc_t):
            svc.ingest(2, pts[:32])
            svc.refresh()
        assert 2 in svc_f.quarantined
        arrays, manifest = svc_f.state_dict()
        svc_r = ClusterService.from_state(svc_f.scfg, arrays, manifest)
        assert 2 in svc_r.quarantined
        assert svc_r.quarantine_events == svc_f.quarantine_events
        assert svc_r.recover(2)
        svc_r.refresh()
        assert_bitexact(svc_r, svc_t)


class TestCounters:
    def test_stats_expose_failure_counters(self):
        svc, pts, _ = build()
        stream_in(svc, pts, K)
        st = svc.stats()
        for key in ("refreshes", "retries", "quarantined_shards",
                    "quarantined_now", "fenced_deltas", "degraded_queries",
                    "journal_entries"):
            assert key in st, key
        assert st["refreshes"] > 0
        assert st["journal_entries"] > 0
        assert st["retries"] == 0 and st["quarantined_shards"] == 0

    def test_facade_comm_stats_expose_counters(self):
        from repro.ddc import DDC, DDCConfig

        spec = spatial.PHASE2_LAYOUTS["rings"]
        pts = spec["make"](N)
        cfg = DDCConfig(
            eps=spec["eps"], min_pts=spec["min_pts"], grid=spec["grid"],
            max_clusters=spec["max_clusters"], max_verts=spec["max_verts"],
            backend="stream", shards=K,
            capacity=spatial.shard_capacity(N, K), max_batch=160)
        model = DDC(cfg).fit(pts)
        cs = model.comm_stats()
        for key in ("refreshes", "retries", "quarantined_shards",
                    "journal_entries"):
            assert key in cs, key


class TestSnapshotRobustness:
    def _fit_model(self):
        from repro.ddc import DDC, DDCConfig

        spec = spatial.PHASE2_LAYOUTS["rings"]
        pts = spec["make"](N)
        cfg = DDCConfig(
            eps=spec["eps"], min_pts=spec["min_pts"], grid=spec["grid"],
            max_clusters=spec["max_clusters"], max_verts=spec["max_verts"],
            backend="stream", shards=K,
            capacity=spatial.shard_capacity(N, K), max_batch=160)
        return DDC(cfg).fit(pts), pts

    def test_truncated_npz_raises_snapshot_error(self, tmp_path):
        from repro.ddc import DDC, SnapshotError

        model, pts = self._fit_model()
        path = str(tmp_path / "snap")
        model.save(path)
        labels_before = model.labels_.copy()
        target = os.path.join(path, "state.npz")
        size = os.path.getsize(target)
        with open(target, "r+b") as f:
            f.truncate(size // 2)
        with pytest.raises(SnapshotError):
            DDC.load(path)
        # the failed load never touches the live model
        assert np.array_equal(model.labels_, labels_before)

    def test_corrupt_manifest_raises_snapshot_error(self, tmp_path):
        from repro.ddc import DDC, SnapshotError

        model, _ = self._fit_model()
        path = str(tmp_path / "snap")
        model.save(path)
        with open(os.path.join(path, "manifest.json"), "w") as f:
            f.write('{"format": "repro-ddc/v1", "config": {')
        with pytest.raises(SnapshotError):
            DDC.load(path)

    def test_wrong_format_tag_raises_snapshot_error(self, tmp_path):
        from repro.ddc import DDC, SnapshotError

        model, _ = self._fit_model()
        path = str(tmp_path / "snap")
        model.save(path)
        mf = os.path.join(path, "manifest.json")
        with open(mf) as f:
            doc = json.load(f)
        doc["format"] = "repro-ddc/v999"
        with open(mf, "w") as f:
            json.dump(doc, f)
        with pytest.raises(SnapshotError):
            DDC.load(path)

    def test_missing_dir_raises_snapshot_error(self, tmp_path):
        from repro.ddc import DDC, SnapshotError

        with pytest.raises(SnapshotError):
            DDC.load(str(tmp_path / "nope"))

    def test_torn_snapshot_fault_is_detected(self, tmp_path):
        """FaultPlan(torn_snapshot=True) byte-tears exactly one save;
        loading it must fail loudly, and the next save is whole again."""
        from repro.ddc import DDC, DDCConfig, SnapshotError

        spec = spatial.PHASE2_LAYOUTS["rings"]
        pts = spec["make"](N)
        cfg = DDCConfig(
            eps=spec["eps"], min_pts=spec["min_pts"], grid=spec["grid"],
            max_clusters=spec["max_clusters"], max_verts=spec["max_verts"],
            backend="stream", shards=K,
            capacity=spatial.shard_capacity(N, K), max_batch=160)
        model = DDC(cfg, faults=FaultPlan(torn_snapshot=True)).fit(pts)
        torn = str(tmp_path / "torn")
        model.save(torn)
        with pytest.raises(SnapshotError):
            DDC.load(torn)
        whole = str(tmp_path / "whole")
        model.save(whole)                 # the tear is one-shot
        restored = DDC.load(whole)
        assert np.array_equal(restored.labels_, model.labels_)

"""Streaming≡batch equivalence suite for the DDC serve engine.

The contract under test (DESIGN.md §8): any sequence of ingest batches,
refreshed incrementally (dirty-shard phase 1 + delta-merge), yields the
IDENTICAL global clustering as batch ``ddc_host`` on the union of live
points with the same per-shard membership — bit-exact in the
``same_clustering`` sense (same noise set, label bijection).  Plus the
delta-merge internals (cached matrix == from-scratch matrix), the comm
accounting of delta vs full re-merge, and the eviction regressions
(emptied shard -> cached ``empty_clusterset`` path; ring overwrite).

Big sweeps are marked ``slow`` (separate non-blocking CI job); the
unmarked subset keeps the blocking tier-1 run light.
"""
import numpy as np
import pytest

from repro.core import ddc
from repro.data import spatial
from repro.serve import ClusterService, StreamConfig

from _hyp import given, settings, st  # optional-hypothesis shim

N = 2048


def layout_cfg(spec) -> ddc.DDCConfig:
    return ddc.DDCConfig(
        eps=spec["eps"], min_pts=spec["min_pts"], grid=spec["grid"],
        max_clusters=spec["max_clusters"], max_verts=spec["max_verts"])


def build_service(layout: str, k: int, meter=None, capacity=None,
                  max_batch=256):
    spec = spatial.PHASE2_LAYOUTS[layout]
    pts = spec["make"](N)
    cap = capacity or spatial.shard_capacity(N, k)
    scfg = StreamConfig(shards=k, capacity=cap, max_batch=max_batch,
                        ddc=layout_cfg(spec))
    return ClusterService(scfg, meter=meter), pts, spec


def stream(svc, pts, k, order="round_robin", seed=None, batch=256,
           refresh_every=1):
    batches = spatial.stream_batches(pts, k, batch, order=order, seed=seed)
    for i, (shard, chunk) in enumerate(batches):
        svc.ingest(shard, chunk)
        if refresh_every and (i + 1) % refresh_every == 0:
            svc.refresh()
    svc.refresh()


def assert_matches_host(svc, spec):
    pts, parts, labels = svc.live()
    host, _, _ = ddc.ddc_host(pts, len(parts), spec["eps"], spec["min_pts"],
                              partition=parts, contour="grid")
    assert ddc.same_clustering(labels, host), (
        "streaming clustering diverged from batch ddc_host")
    return labels


class TestStreamEqualsBatch:
    @pytest.mark.parametrize("layout,k", [
        ("rings", 2), ("linked_ovals", 4), ("noise_heavy", 2)])
    def test_incremental_stream_matches_host(self, layout, k):
        svc, pts, spec = build_service(layout, k)
        stream(svc, pts, k)
        assert_matches_host(svc, spec)

    @pytest.mark.slow
    @pytest.mark.parametrize("layout", sorted(spatial.PHASE2_LAYOUTS))
    def test_stream_matches_host_sweep(self, layout):
        """Every layout × 2/4/8 shards, refresh after every batch."""
        for k in (2, 4, 8):
            svc, pts, spec = build_service(layout, k)
            stream(svc, pts, k)
            assert_matches_host(svc, spec)

    def test_refresh_cadence_invariant(self):
        """Refreshing after every batch vs once at the end is the same
        clustering (delta folds commute with batching)."""
        ref = None
        for every in (1, 3, 0):
            svc, pts, spec = build_service("rings", 4)
            stream(svc, pts, 4, refresh_every=every)
            labels = assert_matches_host(svc, spec)
            if ref is None:
                ref = labels
            else:
                assert ddc.same_clustering(labels, ref)

    def test_delta_state_equals_full_remerge(self):
        """The incrementally maintained distance matrix and global labels
        are bit-identical to a from-scratch re-merge."""
        svc, pts, spec = build_service("linked_ovals", 4)
        stream(svc, pts, 4)
        d2_delta = np.asarray(svc.pair_d2)
        _, _, labels_delta = svc.live()
        svc.remerge_full()
        np.testing.assert_array_equal(d2_delta, np.asarray(svc.pair_d2))
        _, _, labels_full = svc.live()
        np.testing.assert_array_equal(labels_delta, labels_full)


class TestIngestOrderings:
    """Hypothesis-driven ingest orderings: the final clustering must not
    depend on the order batches arrived or where refreshes landed."""

    @settings(max_examples=5, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           batch=st.sampled_from((128, 256)),
           refresh_every=st.integers(1, 4))
    def test_shuffled_order_matches_host(self, seed, batch, refresh_every):
        svc, pts, spec = build_service("linked_ovals", 2)
        stream(svc, pts, 2, order="shuffled", seed=seed, batch=batch,
               refresh_every=refresh_every)
        assert_matches_host(svc, spec)

    @pytest.mark.slow
    @pytest.mark.parametrize("layout", sorted(spatial.PHASE2_LAYOUTS))
    @settings(max_examples=2, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), k=st.sampled_from((2, 4, 8)))
    def test_shuffled_order_sweep(self, layout, seed, k):
        svc, pts, spec = build_service(layout, k)
        stream(svc, pts, k, order="shuffled", seed=seed)
        assert_matches_host(svc, spec)


class TestEviction:
    def test_cleared_shard_takes_cached_empty_path(self):
        """Evicting every point from a shard must reduce it to the cached
        empty_clusterset (the PR 2 empty-shard fix, streaming edition) and
        keep the global state equal to batch on the remaining points."""
        svc, pts, spec = build_service("noise_heavy", 4)
        stream(svc, pts, 4)
        assert svc.clear(1) > 0
        svc.refresh()
        empty = ddc.empty_clusterset(svc.cfg)
        assert svc.local_set(1).contours is empty.contours  # cached, not rebuilt
        _, parts, _ = svc.live()
        assert len(parts[1]) == 0
        assert_matches_host(svc, spec)

    def test_clear_all_shards_goes_global_empty(self):
        svc, pts, spec = build_service("rings", 2)
        stream(svc, pts, 2)
        for s in range(2):
            svc.clear(s)
        svc.refresh()
        assert svc.n_live() == 0
        assert int(np.asarray(svc.global_set.valid).sum()) == 0
        assert (svc.query(pts[:16]) == -1).all()

    def test_ring_overwrite_evicts_oldest(self):
        """Ingesting past capacity overwrites the oldest points in place;
        the result must equal batch on exactly the surviving window."""
        cfg = ddc.DDCConfig(eps=0.05, min_pts=5, max_clusters=16,
                            max_verts=64, grid=96)
        svc = ClusterService(StreamConfig(shards=2, capacity=512,
                                          max_batch=128, ddc=cfg))
        pts, _ = spatial.make_blobs(1400, 4, seed=3)
        for shard, chunk in spatial.stream_batches(pts, 2, 128):
            svc.ingest(shard, chunk)
        svc.refresh()
        live_pts, parts, labels = svc.live()
        assert len(live_pts) == 2 * 512
        host, _, _ = ddc.ddc_host(live_pts, 2, cfg.eps, cfg.min_pts,
                                  partition=parts, contour="grid")
        assert ddc.same_clustering(labels, host)

    def test_evict_then_reingest_is_idempotent(self):
        svc, pts, spec = build_service("rings", 2)
        stream(svc, pts, 2)
        ref = assert_matches_host(svc, spec)
        part0 = np.array_split(pts, 2)[0]
        svc.clear(0)
        svc.refresh()
        svc.ingest(0, part0)
        svc.refresh()
        labels = assert_matches_host(svc, spec)
        assert ddc.same_clustering(labels, ref)


class TestCommAccounting:
    def test_delta_moves_fewer_bytes_than_full(self):
        """Steady-state single-shard ingest: delta ships one ClusterSet
        up (+ map rows down); a full re-merge ships all K.  The exact
        counter values are static, so assert them, not just the order."""
        k = 8
        meter = ddc.CommMeter()
        svc, pts, spec = build_service("rings", k, meter=meter)
        stream(svc, pts, k)
        b = svc.cfg.buffer_bytes()
        c = svc.cfg.max_clusters

        meter.reset()
        svc.ingest(0, pts[:8])          # one dirty shard
        svc.refresh()
        delta_bytes = meter.snapshot()["bytes_total"]
        assert delta_bytes == 1 * b + k * c * 4

        meter.reset()
        svc.remerge_full()
        full_bytes = meter.snapshot()["bytes_total"]
        assert full_bytes == k * b + k * c * 4
        assert delta_bytes < full_bytes

    def test_noop_refresh_is_free(self):
        meter = ddc.CommMeter()
        svc, pts, _ = build_service("rings", 2, meter=meter)
        stream(svc, pts, 2)
        before = meter.snapshot()
        svc.refresh()                    # nothing dirty
        assert meter.snapshot() == before


class TestQuery:
    def test_query_live_points_and_noise(self):
        svc, pts, spec = build_service("rings", 4)
        stream(svc, pts, 4)
        live_pts, _, labels = svc.live()
        got = svc.query(live_pts[:400])
        clustered = labels[:400] >= 0
        np.testing.assert_array_equal(got[clustered], labels[:400][clustered])
        # A clustered point queries back to its own cluster; a far-away
        # probe is noise.
        assert (svc.query(np.array([[5.0, 5.0], [-3.0, 7.0]])) == -1).all()

    def test_query_autorefreshes_pending_writes(self):
        svc, pts, spec = build_service("rings", 2)
        stream(svc, pts, 2)
        svc.ingest(0, pts[:32])          # leave shard dirty
        before = svc.refreshes
        svc.query(pts[:8])
        assert svc.refreshes == before + 1

"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs jnp oracles."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import contour_dist as cd
from repro.kernels import flash_attention as fa
from repro.kernels import ops
from repro.kernels import pairwise_dist as pd
from repro.kernels import ref
from repro.kernels import ssd_scan as ssd

RNG = np.random.default_rng(0)


def randn(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.normal(size=shape), dtype)


class TestPairwiseDist:
    @pytest.mark.parametrize("n,m,d,bn,bm", [
        (128, 128, 2, 64, 64),
        (256, 128, 8, 64, 128),
        (512, 512, 3, 128, 256),
        (64, 64, 16, 64, 64),
    ])
    def test_dist_sweep(self, n, m, d, bn, bm):
        x, y = randn((n, d)), randn((m, d))
        out = pd.pairwise_dist_sq(x, y, bn=bn, bm=bm, interpret=True)
        np.testing.assert_allclose(out, ref.pairwise_dist_sq(x, y),
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        x = randn((128, 2), dtype)
        out = pd.pairwise_dist_sq(x, x, bn=64, bm=64, interpret=True)
        expect = ref.pairwise_dist_sq(x, x)
        np.testing.assert_allclose(out, expect, rtol=2e-2, atol=2e-2)

    @pytest.mark.parametrize("eps", [0.1, 0.5, 2.0])
    def test_neighbor_count(self, eps):
        x = randn((256, 2))
        mask = jnp.asarray(RNG.random(256) > 0.3)
        got = pd.neighbor_count(x, mask, eps, bn=64, bm=64, interpret=True)
        np.testing.assert_array_equal(got, ref.neighbor_count(x, mask, eps))

    def test_min_label_sweep(self):
        x = randn((128, 2))
        mask = jnp.ones(128, bool)
        labels = jnp.arange(128, dtype=jnp.int32)
        core = jnp.asarray(RNG.random(128) > 0.5)
        got = pd.min_label_sweep(x, mask, labels, core, 0.4, bn=64, bm=64,
                                 interpret=True)
        d2 = np.asarray(ref.pairwise_dist_sq(x, x))
        ok = (d2 <= 0.16) & np.asarray(core)[None, :]
        want = np.where(ok, np.arange(128)[None, :], 2**30).min(1)
        np.testing.assert_array_equal(got, want)


class TestContourMinD2:
    @pytest.mark.parametrize("m,v,bi,bj", [
        (16, 32, 8, 8),
        (32, 64, 8, 8),
        (8, 16, 4, 8),
        (24, 8, 8, 4),
    ])
    def test_sweep(self, m, v, bi, bj):
        contours = jnp.asarray(RNG.uniform(0, 1, (m, v, 2)), jnp.float32)
        counts = jnp.asarray(RNG.integers(0, v + 1, m), jnp.int32)
        valid = jnp.asarray(RNG.random(m) > 0.25)
        vert_valid = (jnp.arange(v)[None, :] < counts[:, None]) & valid[:, None]
        got = cd.contour_min_d2(
            contours.reshape(m * v, 2), vert_valid.reshape(m * v).astype(jnp.int32),
            v, bi=bi, bj=bj, interpret=True)
        want = ref.contour_min_d2(contours, counts, valid)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)

    def test_ops_dispatch_pads_odd_slot_counts(self):
        m, v = 11, 16
        contours = jnp.asarray(RNG.uniform(0, 1, (m, v, 2)), jnp.float32)
        counts = jnp.asarray(RNG.integers(1, v + 1, m), jnp.int32)
        valid = jnp.ones(m, bool)
        want = ref.contour_min_d2(contours, counts, valid)
        prev, ops.FORCE = ops.FORCE, "interpret"
        try:
            got = ops.contour_min_d2(contours, counts, valid)
        finally:
            ops.FORCE = prev
        assert got.shape == (m, m)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)

    def test_centred_offset_data(self):
        """The kernel's MXU expansion must survive a large coordinate
        offset (the centring step, DESIGN.md §4 item 6)."""
        m, v = 16, 32
        base = jnp.asarray(RNG.uniform(0, 1, (m, v, 2)), jnp.float32)
        counts = jnp.full((m,), v, jnp.int32)
        valid = jnp.ones(m, bool)
        want = ref.contour_min_d2(base, counts, valid)
        prev, ops.FORCE = ops.FORCE, "interpret"
        try:
            got = ops.contour_min_d2(base + 100.0, counts, valid)
        finally:
            ops.FORCE = prev
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-3, atol=1e-4)

    def test_empty_slots_get_big(self):
        m, v = 8, 16
        contours = jnp.zeros((m, v, 2), jnp.float32)
        counts = jnp.zeros((m,), jnp.int32)
        valid = jnp.zeros((m,), bool)
        out = np.asarray(ref.contour_min_d2(contours, counts, valid))
        assert (out >= 1e29).all()


class TestFlashAttention:
    @pytest.mark.parametrize("b,h,hkv,sq,skv,d,bq,bk", [
        (1, 4, 4, 128, 128, 32, 64, 64),     # MHA square
        (2, 8, 2, 128, 256, 64, 64, 128),    # GQA, decode-style kv > q
        (1, 4, 1, 256, 256, 32, 128, 64),    # MQA
        (2, 2, 2, 64, 64, 128, 64, 64),      # large head dim
    ])
    def test_causal_sweep(self, b, h, hkv, sq, skv, d, bq, bk):
        q, k, v = randn((b, h, sq, d)), randn((b, hkv, skv, d)), randn((b, hkv, skv, d))
        got = fa.flash_attention(q, k, v, causal=True, bq=bq, bk=bk, interpret=True)
        want = ref.flash_attention(q, k, v, causal=True)
        np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)

    def test_non_causal(self):
        q, k, v = randn((1, 2, 128, 32)), randn((1, 2, 128, 32)), randn((1, 2, 128, 32))
        got = fa.flash_attention(q, k, v, causal=False, bq=64, bk=64, interpret=True)
        np.testing.assert_allclose(got, ref.flash_attention(q, k, v, causal=False),
                                   rtol=3e-4, atol=3e-4)

    @pytest.mark.parametrize("window", [32, 100])
    def test_windowed(self, window):
        q, k, v = randn((1, 2, 192, 32)), randn((1, 2, 192, 32)), randn((1, 2, 192, 32))
        got = fa.flash_attention(q, k, v, causal=True, window=window,
                                 bq=64, bk=64, interpret=True)
        want = ref.flash_attention(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)

    def test_bf16(self):
        q = randn((1, 2, 128, 32), jnp.bfloat16)
        k = randn((1, 2, 128, 32), jnp.bfloat16)
        v = randn((1, 2, 128, 32), jnp.bfloat16)
        got = fa.flash_attention(q, k, v, causal=True, bq=64, bk=64, interpret=True)
        want = ref.flash_attention(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), rtol=0.05, atol=0.05)

    def test_chunked_ref_matches_exact(self):
        q, k, v = randn((2, 4, 300, 32)), randn((2, 2, 520, 32)), randn((2, 2, 520, 32))
        for causal in (True, False):
            got = ref.flash_attention_chunked(q, k, v, causal=causal, bq=128, bk=128)
            want = ref.flash_attention(q, k, v, causal=causal)
            np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_chunked_ref_grad(self):
        q, k, v = randn((1, 2, 256, 16)), randn((1, 2, 256, 16)), randn((1, 2, 256, 16))
        g1 = jax.grad(lambda q: ref.flash_attention(q, k, v).sum())(q)
        g2 = jax.grad(lambda q: ref.flash_attention_chunked(q, k, v, bq=64, bk=64).sum())(q)
        np.testing.assert_allclose(g1, g2, rtol=1e-4, atol=1e-4)


class TestSSDScan:
    @pytest.mark.parametrize("b,l,h,dh,ds,chunk", [
        (1, 64, 2, 16, 8, 16),
        (2, 128, 3, 16, 8, 32),
        (1, 256, 1, 32, 16, 64),
        (2, 96, 4, 8, 4, 32),
    ])
    def test_sweep(self, b, l, h, dh, ds, chunk):
        x = randn((b, l, h, dh))
        a = jnp.asarray(-np.abs(RNG.normal(size=(b, l, h))) * 0.1, jnp.float32)
        bb = randn((b, l, h, ds))
        c = randn((b, l, h, ds))
        got = ssd.ssd_scan(x, a, bb, c, chunk=chunk, interpret=True)
        want = ref.ssd_scan(x, a, bb, c)
        np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)

    def test_chunked_ref(self):
        x = randn((2, 100, 3, 16))
        a = jnp.asarray(-np.abs(RNG.normal(size=(2, 100, 3))) * 0.1, jnp.float32)
        bb = randn((2, 100, 3, 8))
        c = randn((2, 100, 3, 8))
        got = ref.ssd_scan_chunked(x, a, bb, c, chunk=32)
        want = ref.ssd_scan(x, a, bb, c)
        np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)

    def test_decay_semantics(self):
        """Strong decay ⇒ output ≈ instantaneous c·b x (no history)."""
        b, l, h, dh, ds = 1, 32, 1, 4, 4
        x = randn((b, l, h, dh))
        a = jnp.full((b, l, h), -50.0)
        bb = randn((b, l, h, ds))
        c = randn((b, l, h, ds))
        y = ref.ssd_scan(x, a, bb, c)
        want = jnp.einsum("blhs,blhs->blh", c, bb)[..., None] * x
        np.testing.assert_allclose(y, want, rtol=1e-3, atol=1e-3)

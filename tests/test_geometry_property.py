"""Contour/geometry property tests (DESIGN.md §3/§7 made executable).

Invariants of the grid-contour extraction that the phase-2 merge and the
streaming serve engine lean on:

* **Translation / scale equivariance** — translating (or scaling) points
  AND bounds together translates (scales) the contour exactly.  Points
  live on a dyadic lattice and grids are 2^k+1 (so the raster pitch is a
  power of two): every intermediate float op is exact, hence the
  assertions are bit-level, not approximate.
* **Vertex budget** — the contour never exceeds ``max_verts``, padding
  rows are zeroed, the reported count equals the true boundary-cell count
  clipped to the budget, and every emitted vertex is a boundary-cell
  centre of the NumPy oracle (``grid_contour_np``).
* **Merged-contour containment** — ``merge_many`` re-extracts merged
  contours from the union of member contour vertices on the same global
  raster; rasterising a cell centre is idempotent, so every merged vertex
  must be one of the input vertices and the merged count can never exceed
  the sum of the inputs (the §7 sizing rule: if the union fits the
  budget, nothing is silently dropped).

Each property runs both hypothesis-driven (when installed) and over a
fixed deterministic sweep, so the module asserts real work either way.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ddc, geometry

from _hyp import given, settings, st  # optional-hypothesis shim

BOUNDS = (0.0, 0.0, 1.0, 1.0)
GRIDS = (17, 33, 65)                      # pitch 1/(grid-1) is a power of two
DYADIC_SHIFTS = (-2.0, -0.5, 0.25, 0.5, 1.0, 3.5)
POW2_SCALES = (0.5, 2.0, 4.0)

lattice_pts = st.lists(
    st.tuples(st.integers(0, 255), st.integers(0, 255)),
    min_size=1, max_size=300).map(
        lambda ij: np.asarray(ij, np.float32) / 256.0)


def _contour(pts: np.ndarray, bounds, grid: int, max_verts: int):
    out, cnt = geometry.extract_contour(
        jnp.asarray(pts, jnp.float32), jnp.ones(len(pts), bool),
        bounds, grid, max_verts)
    return np.asarray(out), int(cnt)


def _rng_pts(seed: int, n: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return (rng.integers(0, 256, (n, 2)) / 256.0).astype(np.float32)


# -- translation equivariance ----------------------------------------------


def check_translation(pts, grid, tx, ty):
    base, n = _contour(pts, BOUNDS, grid, 64)
    moved_bounds = (BOUNDS[0] + tx, BOUNDS[1] + ty,
                    BOUNDS[2] + tx, BOUNDS[3] + ty)
    t = np.asarray([tx, ty], np.float32)
    moved, m = _contour(pts + t, moved_bounds, grid, 64)
    assert m == n
    np.testing.assert_array_equal(moved[:m], base[:n] + t)
    np.testing.assert_array_equal(moved[m:], 0.0)


@settings(max_examples=30, deadline=None)
@given(pts=lattice_pts, grid=st.sampled_from(GRIDS),
       tx=st.sampled_from(DYADIC_SHIFTS), ty=st.sampled_from(DYADIC_SHIFTS))
def test_translation_equivariant_hyp(pts, grid, tx, ty):
    check_translation(pts, grid, tx, ty)


@pytest.mark.parametrize("seed", range(4))
def test_translation_equivariant(seed):
    pts = _rng_pts(seed, 200)
    for grid in GRIDS:
        for tx, ty in zip(DYADIC_SHIFTS, reversed(DYADIC_SHIFTS)):
            check_translation(pts, grid, tx, ty)


# -- scale equivariance ----------------------------------------------------


def check_scale(pts, grid, s):
    base, n = _contour(pts, BOUNDS, grid, 64)
    scaled, m = _contour(pts * np.float32(s),
                         (0.0, 0.0, s * BOUNDS[2], s * BOUNDS[3]), grid, 64)
    assert m == n
    np.testing.assert_array_equal(scaled[:m], base[:n] * np.float32(s))


@settings(max_examples=30, deadline=None)
@given(pts=lattice_pts, grid=st.sampled_from(GRIDS),
       s=st.sampled_from(POW2_SCALES))
def test_scale_equivariant_hyp(pts, grid, s):
    check_scale(pts, grid, s)


@pytest.mark.parametrize("seed", range(4))
def test_scale_equivariant(seed):
    pts = _rng_pts(seed + 10, 150)
    for grid in GRIDS:
        for s in POW2_SCALES:
            check_scale(pts, grid, s)


# -- vertex budget ---------------------------------------------------------


def check_budget(pts, grid, max_verts):
    out, cnt = _contour(pts, BOUNDS, grid, max_verts)
    oracle = geometry.grid_contour_np(pts.astype(np.float64), BOUNDS, grid)
    assert cnt == min(len(oracle), max_verts)
    assert out.shape == (max_verts, 2)
    np.testing.assert_array_equal(out[cnt:], 0.0)
    oracle_set = {(round(float(x), 6), round(float(y), 6)) for x, y in oracle}
    got = {(round(float(x), 6), round(float(y), 6)) for x, y in out[:cnt]}
    assert len(got) == cnt, "contour emitted duplicate vertices"
    assert got <= oracle_set, "contour vertex is not a boundary-cell centre"
    if len(oracle) <= max_verts:
        assert got == oracle_set, "budget not exhausted yet cells dropped"


@settings(max_examples=30, deadline=None)
@given(pts=lattice_pts, grid=st.sampled_from(GRIDS),
       max_verts=st.sampled_from((8, 32, 128)))
def test_vertex_budget_hyp(pts, grid, max_verts):
    check_budget(pts, grid, max_verts)


@pytest.mark.parametrize("seed", range(4))
def test_vertex_budget(seed):
    pts = _rng_pts(seed + 20, 250)
    for grid in GRIDS:
        for max_verts in (8, 32, 128):
            check_budget(pts, grid, max_verts)


# -- merged-contour containment (§7 sizing rule) ---------------------------


def _two_set_batch(pts_a, pts_b, cfg):
    def one(pts):
        contour, cnt = geometry.extract_contour(
            jnp.asarray(pts, jnp.float32), jnp.ones(len(pts), bool),
            cfg.bounds, cfg.grid, cfg.max_verts)
        c = cfg.max_clusters
        return ddc.ClusterSet(
            contours=jnp.zeros((c, cfg.max_verts, 2)).at[0].set(contour),
            counts=jnp.zeros((c,), jnp.int32).at[0].set(cnt),
            sizes=jnp.zeros((c,), jnp.int32).at[0].set(len(pts)),
            valid=jnp.zeros((c,), bool).at[0].set(True),
            overflow=jnp.asarray(False))
    return jax.tree.map(lambda x, y: jnp.stack([x, y]),
                        one(pts_a), one(pts_b))


def check_containment(pts_a, pts_b, grid):
    cfg = ddc.DDCConfig(eps=0.05, min_pts=2, grid=grid,
                        max_clusters=4, max_verts=192, bounds=BOUNDS)
    batch = _two_set_batch(pts_a, pts_b, cfg)
    merged, _ = ddc.merge_many(batch, cfg)
    counts = np.asarray(batch.counts)
    mcnt = np.asarray(merged.counts)
    mvalid = np.asarray(merged.valid)
    assert mcnt[mvalid].sum() <= counts.sum()
    assert int(np.asarray(merged.sizes).sum()) == len(pts_a) + len(pts_b)
    inputs = {
        (round(float(x), 6), round(float(y), 6))
        for k in range(2)
        for x, y in np.asarray(batch.contours[k, 0])[:counts[k, 0]]
    }
    for slot in np.nonzero(mvalid)[0]:
        verts = np.asarray(merged.contours[slot])[:mcnt[slot]]
        got = {(round(float(x), 6), round(float(y), 6)) for x, y in verts}
        assert got <= inputs, (
            "merged contour left the union of member contour vertices")


@settings(max_examples=20, deadline=None)
@given(a=lattice_pts, b=lattice_pts, grid=st.sampled_from((33, 65)))
def test_merged_contour_containment_hyp(a, b, grid):
    check_containment(a, b, grid)


@pytest.mark.parametrize("seed", range(4))
def test_merged_contour_containment(seed):
    rng = np.random.default_rng(seed + 30)
    a = _rng_pts(seed + 40, 120)
    # b: a shifted-by-dyadic copy plus fresh lattice points, so the merge
    # sometimes connects and sometimes doesn't.
    b = np.concatenate([
        np.clip(a[: len(a) // 2] + np.float32(0.25), 0, 255 / 256),
        _rng_pts(seed + 50, 60),
    ])
    check_containment(a, b, int(rng.choice((33, 65))))

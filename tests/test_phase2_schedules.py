"""Merge-schedule equivalence property suite (subprocess — the XLA device
count must be set before jax initialises, which pytest's process already
did with 1 device).

For every layout data/spatial.py can generate, at 2/4/8/16 shards, all
three phase-2 schedules must reproduce ``ddc_host``'s global clustering
bit-exactly.  The per-layout parameters live in _phase2_script.py.
"""
import os
import subprocess
import sys

import pytest

SCRIPT = os.path.join(os.path.dirname(__file__), "_phase2_script.py")
SRC = os.path.join(os.path.dirname(__file__), "..", "src")

LAYOUTS = ["blobs", "clustered", "d1", "d2", "worm_default",
           "rings", "linked_ovals", "worm", "noise_heavy"]


def run_check(name: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(SRC)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, SCRIPT, name],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert proc.returncode == 0, f"{name} failed:\n{proc.stdout}\n{proc.stderr}"
    return proc.stdout


@pytest.mark.parametrize("layout", LAYOUTS)
def test_schedules_match_host(layout):
    out = run_check(layout)
    assert "ALL_OK" in out
    assert out.count("PASS") == 4  # one per shard count

"""Property-based kernel tests (hypothesis): invariants that must hold
for any shape/content, complementing the fixed-shape sweeps."""
import numpy as np
import jax.numpy as jnp
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.kernels import ref


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 2), h=st.integers(1, 3),
    sq=st.integers(4, 48), skv=st.integers(4, 48),
    d=st.sampled_from([4, 8, 16]), seed=st.integers(0, 100),
)
def test_attention_rows_are_convex_combinations(b, h, sq, skv, d, seed):
    """Non-causal attention output rows lie in the convex hull of V rows:
    min(V) <= out <= max(V) per feature."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, h, sq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, h, skv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, h, skv, d)), jnp.float32)
    out = np.asarray(ref.flash_attention(q, k, v, causal=False))
    vmin = np.asarray(v).min(axis=2, keepdims=True)
    vmax = np.asarray(v).max(axis=2, keepdims=True)
    assert (out >= vmin - 1e-4).all() and (out <= vmax + 1e-4).all()


@settings(max_examples=20, deadline=None)
@given(
    sq=st.integers(8, 64), skv=st.integers(8, 64),
    bq=st.sampled_from([8, 16, 32]), bk=st.sampled_from([8, 16, 32]),
    causal=st.booleans(), seed=st.integers(0, 50),
)
def test_chunked_attention_block_size_invariance(sq, skv, bq, bk, causal, seed):
    """The chunked implementation's result must not depend on block size."""
    if causal and skv < sq:
        # Right-aligned causal with skv < sq leaves leading query rows
        # with an empty key set — mathematically undefined (NaN in the
        # exact ref, 0 in the chunked one); not a meaningful input.
        skv = sq
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(1, 2, sq, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, skv, 8)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, skv, 8)), jnp.float32)
    a = ref.flash_attention_chunked(q, k, v, causal=causal, bq=bq, bk=bk)
    b_ = ref.flash_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(
    l=st.integers(4, 80), chunk=st.sampled_from([4, 16, 32]),
    h=st.integers(1, 2), seed=st.integers(0, 50),
)
def test_ssd_chunk_invariance(l, chunk, h, seed):
    """SSD chunked == sequential for any chunking."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(1, l, h, 8)), jnp.float32)
    a = jnp.asarray(-np.abs(rng.normal(size=(1, l, h))) * 0.2, jnp.float32)
    b = jnp.asarray(rng.normal(size=(1, l, h, 4)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(1, l, h, 4)), jnp.float32)
    y1 = ref.ssd_scan(x, a, b, c)
    y2 = ref.ssd_scan_chunked(x, a, b, c, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-3, atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 100), n=st.integers(4, 64),
       eps=st.floats(0.05, 1.0))
def test_neighbor_count_symmetry_and_self(seed, n, eps):
    """Counts include self; pairwise relation is symmetric in aggregate
    (sum of counts == number of within-eps ordered pairs)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, 2)), jnp.float32)
    mask = jnp.ones(n, bool)
    counts = np.asarray(ref.neighbor_count(x, mask, eps))
    assert (counts >= 1).all()
    d2 = np.asarray(ref.pairwise_dist_sq(x, x))
    pairs = (d2 <= eps * eps).sum()
    assert counts.sum() == pairs


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 100))
def test_ssd_matches_attention_free_decay_limit(seed):
    """With a == 0 (no decay), SSD reduces to cumulative (c_i . b_j) x_j —
    linear attention.  Checks the duality algebra."""
    rng = np.random.default_rng(seed)
    l, ds, dh = 12, 4, 4
    x = jnp.asarray(rng.normal(size=(1, l, 1, dh)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(1, l, 1, ds)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(1, l, 1, ds)), jnp.float32)
    a = jnp.zeros((1, l, 1))
    y = np.asarray(ref.ssd_scan(x, a, b, c))[0, :, 0]
    want = np.zeros((l, dh))
    for i in range(l):
        for j in range(i + 1):
            want[i] += float(np.asarray(c)[0, i, 0] @ np.asarray(b)[0, j, 0]) \
                * np.asarray(x)[0, j, 0]
    np.testing.assert_allclose(y, want, rtol=1e-3, atol=1e-3)

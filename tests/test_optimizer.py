"""Optimizer unit tests: convergence on a quadratic, schedules, clipping,
adafactor memory shape, stochastic rounding."""
import jax
import jax.numpy as jnp
import pytest

from repro.train import optimizer as opt


def quad_loss(p):
    return 0.5 * jnp.sum((p["w"] - 3.0) ** 2) + 0.5 * jnp.sum((p["b"] + 1.0) ** 2)


@pytest.mark.parametrize("name", ["adamw", "adafactor", "sgdm"])
def test_converges_on_quadratic(name):
    cfg = opt.OptConfig(name=name, lr=0.1, warmup=0, schedule="const",
                        weight_decay=0.0, clip_norm=100.0)
    params = {"w": jnp.zeros((4, 8)), "b": jnp.zeros((8,))}
    state = opt.init_state(cfg, params)
    for step in range(200):
        grads = jax.grad(quad_loss)(params)
        params, state, _ = opt.apply_updates(cfg, grads, state, params, step)
    assert float(quad_loss(params)) < 0.05


def test_lr_schedule_shapes():
    cfg = opt.OptConfig(lr=1.0, warmup=10, decay_steps=100, schedule="cosine",
                        min_lr_frac=0.1)
    assert float(opt.lr_at(cfg, 0)) == 0.0
    assert abs(float(opt.lr_at(cfg, 10)) - 1.0) < 1e-6
    assert abs(float(opt.lr_at(cfg, 100)) - 0.1) < 1e-3
    mid = float(opt.lr_at(cfg, 55))
    assert 0.1 < mid < 1.0


def test_grad_clipping():
    tree = {"a": jnp.full((10,), 100.0)}
    clipped, gnorm = opt.clip_by_norm(tree, 1.0)
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5
    assert float(gnorm) > 100


def test_adafactor_state_is_factored():
    cfg = opt.OptConfig(name="adafactor")
    params = {"big": jnp.zeros((64, 32)), "vec": jnp.zeros((16,))}
    state = opt.init_state(cfg, params)
    assert state["f"]["big"]["vr"].shape == (64,)
    assert state["f"]["big"]["vc"].shape == (32,)
    assert state["f"]["vec"]["v"].shape == (16,)
    # factored state is ~(m+n) instead of m*n
    n_state = sum(x.size for x in jax.tree.leaves(state))
    assert n_state < 64 * 32


def test_adafactor_bf16_stochastic_rounding_moves_params():
    cfg = opt.OptConfig(name="adafactor", lr=1e-3, warmup=0, schedule="const",
                        stochastic_rounding=True, weight_decay=0.0)
    params = {"w": jnp.ones((32, 32), jnp.bfloat16)}
    state = opt.init_state(cfg, params)
    grads = {"w": jnp.full((32, 32), 0.5)}
    moved = 0
    p = params
    for step in range(20):
        p, state, _ = opt.apply_updates(cfg, grads, state, p, step,
                                        key=jax.random.PRNGKey(step))
    # lr*update ~1e-3 is below bf16 ulp at 1.0 (~0.0078): deterministic
    # rounding would freeze params; stochastic rounding must move them.
    assert float(jnp.mean(jnp.abs(p["w"].astype(jnp.float32) - 1.0))) > 1e-4
    assert p["w"].dtype == jnp.bfloat16


def test_adamw_weight_decay_shrinks():
    cfg = opt.OptConfig(name="adamw", lr=0.1, warmup=0, schedule="const",
                        weight_decay=0.5)
    params = {"w": jnp.full((8,), 10.0)}
    state = opt.init_state(cfg, params)
    zeros = {"w": jnp.zeros((8,))}
    for step in range(10):
        params, state, _ = opt.apply_updates(cfg, zeros, state, params, step)
    assert float(params["w"][0]) < 10.0

"""Per-arch smoke tests: reduced configs, one forward/train step on CPU,
output shapes + no NaNs; decode-vs-forward consistency."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import transformer as T

ARCHS = configs.all_archs()
KEY = jax.random.PRNGKey(0)


def make_batch(cfg, b=2, s=16):
    batch = {"tokens": jax.random.randint(KEY, (b, s), 0, cfg.vocab)}
    if cfg.frontend == "audio_stub":
        batch["frames"] = jax.random.normal(KEY, (b, cfg.frontend_seq, cfg.d_model)) * 0.1
    if cfg.prefix_len:
        batch["prefix"] = jax.random.normal(KEY, (b, cfg.prefix_len, cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("arch", ARCHS)
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg = configs.get_config(arch).tiny()
        params = T.init_params(cfg, KEY)
        batch = make_batch(cfg)
        logits, aux = T.forward(cfg, params, batch["tokens"],
                                prefix=batch.get("prefix"),
                                frames=batch.get("frames"))
        assert logits.shape == (2, 16, cfg.padded_vocab)
        assert bool(jnp.isfinite(logits).all())
        assert bool(jnp.isfinite(aux))

    def test_train_step_finite_grads(self, arch):
        cfg = configs.get_config(arch).tiny()
        params = T.init_params(cfg, KEY)
        batch = make_batch(cfg)
        loss, grads = jax.value_and_grad(lambda p: T.loss_fn(cfg, p, batch))(params)
        assert bool(jnp.isfinite(loss))
        leaves = jax.tree.leaves(grads)
        assert all(bool(jnp.isfinite(g).all()) for g in leaves)
        # loss near ln(vocab) at init
        assert 0.5 * np.log(cfg.vocab) < float(loss) < 2.0 * np.log(cfg.vocab)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    """prefill + decode_step must reproduce teacher-forced logits."""
    cfg = configs.get_config(arch).tiny()
    params = T.init_params(cfg, KEY)
    b, s = 2, 12
    batch = make_batch(cfg, b, s)
    tokens = batch["tokens"]
    logits_tf, _ = T.forward(cfg, params, tokens,
                             prefix=batch.get("prefix"), frames=batch.get("frames"))
    half = s // 2
    ml = s + (cfg.prefix_len if cfg.prefix_len else 0)
    lg, cache, pos = T.prefill(cfg, params, tokens[:, :half], max_len=ml,
                               prefix=batch.get("prefix"),
                               frames=batch.get("frames"))
    errs = [float(jnp.max(jnp.abs(lg - logits_tf[:, half - 1])))]
    for t in range(half, s - 1):
        lg, cache = T.decode_step(cfg, params, tokens[:, t:t + 1], cache,
                                  jnp.asarray(pos))
        pos += 1
        errs.append(float(jnp.max(jnp.abs(lg - logits_tf[:, t]))))
    assert max(errs) < 5e-4, errs


def test_windowed_ring_decode_matches_full():
    """Ring-buffer windowed decode == full-cache windowed attention."""
    import dataclasses
    cfg = configs.get_config("qwen3-8b").tiny()
    cfg = dataclasses.replace(cfg, window=8)
    params = T.init_params(cfg, KEY)
    b, s = 1, 24
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    logits_tf, _ = T.forward(cfg, params, tokens)
    half = 8
    # ring cache: max_len > window so cache length == window == 8
    lg, cache, pos = T.prefill(cfg, params, tokens[:, :half], max_len=s)
    errs = []
    for t in range(half, s - 1):
        lg, cache = T.decode_step(cfg, params, tokens[:, t:t + 1], cache,
                                  jnp.asarray(pos))
        pos += 1
        errs.append(float(jnp.max(jnp.abs(lg - logits_tf[:, t]))))
    assert max(errs) < 5e-4, errs


def test_param_counts_match_published():
    expect = {
        "whisper-small": (0.2, 0.3),
        "deepseek-coder-33b": (31, 35),
        "minicpm3-4b": (3.5, 4.8),
        "qwen3-8b": (7.5, 8.8),
        "granite-20b": (18, 22),
        "kimi-k2-1t-a32b": (950, 1100),
        "llama4-scout-17b-a16e": (100, 115),
        "internvl2-26b": (18, 22),   # LM backbone (ViT stubbed)
        "mamba2-1.3b": (1.2, 1.5),
    }
    for arch, (lo, hi) in expect.items():
        total = configs.get_config(arch).param_counts()["total"] / 1e9
        assert lo <= total <= hi, (arch, total)


def test_moe_active_params():
    pc = configs.get_config("kimi-k2-1t-a32b").param_counts()
    assert pc["active"] / 1e9 < 40  # ~32B active
    assert pc["total"] / pc["active"] > 25

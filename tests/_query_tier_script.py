"""Snapshot-versioned read exactness sweep, run under an 8-device CPU
override by tests/test_query_tier.py (the device count must be pinned
before jax initialises, which pytest's process already did with 1
device).

The contract (DESIGN.md §12): a query answered from snapshot version V
is BIT-IDENTICAL to a synchronous query against a service frozen at V —
per PHASE2 layout × shard count {2, 4, 8} × both serve engines.  Per
cell:

1. **Frozen twin** — stream a prefix into the subject and an identical
   twin; tier reads (``max_staleness=inf``, pure snapshot path, pow2
   bucketing, coalescing) off the subject must bit-match the twin's
   synchronous ``query`` on the same state.
2. **Racing refresh** — requests submitted BEFORE held-back writes +
   refreshes land are drained AFTER: every answer must bit-match the
   twin fed the same writes (the new version in full — never a torn
   mix), versions stay monotonic, and result arrays captured before the
   race are byte-identical after it (snapshot immutability under the
   engines' donated-buffer writes).
3. **Stale-quarantine degraded reads** — a shard quarantined AFTER the
   publish still serves its last-good snapshot rows, flagged
   ``degraded=True``, labels unchanged.

Prints PASS lines; any exception fails.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np

from repro.data import spatial
from repro.ddc import DDC, DDCConfig
from repro.serve import query_tier as qt

N = 2048
SHARD_COUNTS = (2, 4, 8)
BACKENDS = ("stream", "dist")


def build(layout: str, k: int, backend: str):
    spec = spatial.PHASE2_LAYOUTS[layout]
    cap = spatial.shard_capacity(N, k)
    cfg = DDCConfig(
        eps=spec["eps"], min_pts=spec["min_pts"], grid=spec["grid"],
        max_clusters=spec["max_clusters"], max_verts=spec["max_verts"],
        backend=backend, shards=k, capacity=cap,
        max_batch=min(256, cap)).validate()
    return DDC(cfg)


def probes(svc, seed: int) -> np.ndarray:
    live, _, _ = svc.live()
    rng = np.random.default_rng(seed)
    return np.concatenate([
        live[rng.integers(0, len(live), 120)],
        rng.uniform(0, 1, (60, 2)).astype(np.float32),
        np.array([[6.0, 6.0], [-3.0, 0.5]], np.float32),
    ])


def check_cell(layout: str, k: int, backend: str):
    spec = spatial.PHASE2_LAYOUTS[layout]
    pts = spec["make"](N)
    subject, twin = build(layout, k, backend), build(layout, k, backend)

    batches = spatial.stream_batches(pts, k, 256)
    prefix, held = batches[:-2], batches[-2:]
    for model in (subject, twin):
        for shard, chunk in prefix:
            model.partial_fit(shard, chunk)
            model.service.refresh()
    svc = subject.service
    v0 = svc.snapshot().version
    assert v0 >= 1, "refresh did not publish"

    # (1) frozen twin: tier snapshot reads == twin's synchronous query,
    # bit for bit, through coalescing and pow2 bucketing.
    tier = qt.QueryTier(svc, max_staleness=float("inf"))
    q = probes(svc, seed=k)
    handles = [tier.submit(q[off:off + 48]) for off in range(0, len(q), 48)]
    tier.drain()
    for h, off in zip(handles, range(0, len(q), 48)):
        assert h.result.version == v0, (h.result.version, v0)
        np.testing.assert_array_equal(
            np.asarray(h.result),
            twin.service.query(q[off:off + 48], legacy=True),
            err_msg=f"snapshot read != frozen twin at V={v0}")
    frozen_copies = [np.array(h.result.labels) for h in handles]

    # (2) racing refresh: submit first, write+refresh under the queue,
    # drain after — every answer is the NEW version in full.
    racers = [tier.submit(q[off:off + 64]) for off in range(0, len(q), 64)]
    for shard, chunk in held:
        subject.partial_fit(shard, chunk)
        svc.refresh()
        twin.partial_fit(shard, chunk)
        twin.service.refresh()
    v1 = svc.snapshot().version
    assert v1 > v0, "held-back refreshes did not advance the version"
    tier.drain()
    for h, off in zip(racers, range(0, len(q), 64)):
        assert h.result.version == v1, (h.result.version, v1)
        np.testing.assert_array_equal(
            np.asarray(h.result),
            twin.service.query(q[off:off + 64], legacy=True),
            err_msg=f"racing read != twin frozen at V={v1}")
    for h, copy in zip(handles, frozen_copies):
        np.testing.assert_array_equal(
            np.asarray(h.result), copy,
            err_msg="published-snapshot answer mutated by later writes")

    # (3) stale-quarantine: quarantined AFTER publish -> last-good rows
    # still served, flagged degraded.
    scanned = [s for h in racers for s in h.result.scanned_shards]
    if scanned:
        before = [np.array(h.result.labels) for h in racers]
        svc._quarantine(scanned[0], "chaos drill")
        stale = [tier.query(q[off:off + 64])
                 for off in range(0, len(q), 64)]
        for res, ref in zip(stale, before):
            np.testing.assert_array_equal(
                np.asarray(res), ref,
                err_msg="stale-quarantine read changed the labels")
        assert any(r.degraded for r in stale
                   if scanned[0] in r.scanned_shards), \
            "stale-quarantined shard served without the degraded flag"
    print(f"PASS {layout} {backend} k={k} v={v0}->{v1}")


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    names = list(spatial.PHASE2_LAYOUTS) if which == "all" else [which]
    for name in names:
        for k in SHARD_COUNTS:
            for backend in BACKENDS:
                check_cell(name, k, backend)
    print("ALL_OK")

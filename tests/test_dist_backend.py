"""Device-resident ``dist`` backend suite + the satellites that ride on
the control-plane split.

* **dist≡host equivalence** — the multi-device sweeps (labels AND the
  cached pair-d2 matrix, exact axis-byte CommMeter asserts, hypothesis
  ingest/evict orderings) need ``len(jax.devices()) >= shards``, so they
  run in a subprocess with the 8-device CPU override
  (tests/_dist_backend_script.py), mirroring the facade suite's pattern.
* **Shard-range validation** — ``ingest``/``evict_*`` (and the facade's
  ``partial_fit``) with an out-of-range shard index must raise a clear
  ``ValueError`` up front, not a raw IndexError deep in the ring write
  path.
* **Bbox query routing** — the control plane's per-shard live-point bbox
  mirrors route query chunks to the shards that could hold an
  ε-neighbour; routing must be invisible in the answers (exactness) and
  visible in the scanned-shard counters.
* **Config rules** — ``backend='dist'`` validates the mesh-vs-shards
  rule at construction (this pytest process sees one CPU device, so any
  multi-shard dist config must be rejected loudly here).
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.data import spatial
from repro.ddc import BACKENDS, ConfigError, DDC, DDCConfig
from repro.serve import ClusterService, StreamConfig

from test_serve_stream import build_service, layout_cfg, stream  # noqa: F401

SCRIPT = os.path.join(os.path.dirname(__file__), "_dist_backend_script.py")
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_script(arg: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(SRC)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, SCRIPT, arg],
        capture_output=True, text=True, timeout=1800, env=env)
    assert proc.returncode == 0, (
        f"{arg} failed:\n{proc.stdout}\n{proc.stderr}")
    return proc.stdout


class TestDistEqualsHost:
    """dist == stream bit-for-bit (labels AND pair-d2) == host clustering,
    with exact axis-crossing byte asserts — in an 8-device subprocess."""

    def test_dist_registered(self):
        assert "dist" in BACKENDS

    def test_equivalence_quick(self):
        out = run_script("linked_ovals")
        assert "ALL_OK" in out and out.count("PASS") == 3

    @pytest.mark.slow
    @pytest.mark.parametrize("layout", sorted(spatial.PHASE2_LAYOUTS))
    def test_equivalence_sweep(self, layout):
        out = run_script(layout)
        assert "ALL_OK" in out and out.count("PASS") == 3

    @pytest.mark.slow
    def test_ingest_evict_orderings(self):
        out = run_script("orderings")
        assert "ALL_OK" in out


class TestDistConfigRules:
    def test_rejects_more_shards_than_devices(self):
        # This pytest process initialised jax with a single CPU device,
        # so any multi-shard dist deployment must fail validate() with
        # the XLA_FLAGS fix spelled out.
        with pytest.raises(ConfigError, match="xla_force_host_platform"):
            DDCConfig(backend="dist", shards=8).validate()

    def test_rejects_capacity_below_max_batch(self):
        with pytest.raises(ConfigError, match="max_batch"):
            DDCConfig(backend="dist", shards=1, capacity=8,
                      max_batch=64).validate()

    def test_single_shard_dist_runs_in_process(self):
        # One shard fits the one-device pytest process: the full dist
        # data plane (shard_map over a 1-lane mesh) must work end to end.
        pts = spatial.PHASE2_LAYOUTS["rings"]["make"](512)
        cfg = DDCConfig(
            **{k: spatial.PHASE2_LAYOUTS["rings"][k]
               for k in ("eps", "min_pts", "grid", "max_verts",
                         "max_clusters")},
            backend="dist", shards=1, capacity=512).validate()
        model = DDC(cfg).fit(pts)
        ref = DDC(DDCConfig(
            **{k: spatial.PHASE2_LAYOUTS["rings"][k]
               for k in ("eps", "min_pts", "grid", "max_verts",
                         "max_clusters")},
            backend="stream", shards=1, capacity=512)).fit(pts)
        np.testing.assert_array_equal(model.labels_, ref.labels_)


class TestShardRangeValidation:
    """Out-of-range shard indices fail loudly at the entry points, not
    as IndexErrors deep in the ring write path."""

    def make_service(self, shards=2) -> ClusterService:
        return ClusterService(StreamConfig(
            shards=shards, capacity=64, max_batch=64,
            ddc=layout_cfg(spatial.PHASE2_LAYOUTS["rings"])))

    @pytest.mark.parametrize("shard", (-1, 2, 99))
    def test_ingest_rejects_out_of_range(self, shard):
        svc = self.make_service()
        with pytest.raises(ValueError, match="out of range"):
            svc.ingest(shard, np.zeros((4, 2), np.float32))

    @pytest.mark.parametrize("method,args", [
        ("evict_oldest", (5,)),
        ("evict_older_than", (0.0,)),
        ("clear", ()),
        ("local_set", ()),
        ("shard_bbox", ()),
    ])
    @pytest.mark.parametrize("shard", (-1, 2))
    def test_evict_entry_points_reject_out_of_range(self, method, args, shard):
        svc = self.make_service()
        with pytest.raises(ValueError, match="out of range"):
            getattr(svc, method)(shard, *args)

    def test_out_of_range_leaves_state_untouched(self):
        svc = self.make_service()
        svc.ingest(0, np.full((4, 2), 0.5, np.float32))
        before = svc.n_live()
        for call in (lambda: svc.ingest(7, np.zeros((2, 2))),
                     lambda: svc.evict_oldest(-3, 1),
                     lambda: svc.clear(2)):
            with pytest.raises(ValueError):
                call()
        assert svc.n_live() == before

    def test_facade_partial_fit_rejects_out_of_range(self):
        model = DDC(DDCConfig(
            backend="stream", shards=2, capacity=64, max_batch=64))
        with pytest.raises(ValueError, match="out of range"):
            model.partial_fit(9, np.zeros((4, 2), np.float32))
        # batch backends keep their (ConfigError, a ValueError) contract
        host = DDC(DDCConfig(backend="host", shards=2))
        with pytest.raises(ValueError, match="out of range"):
            host.partial_fit(9, np.zeros((4, 2), np.float32))


class TestBboxRouting:
    """Routing must be exact (same labels as an all-shard scan would
    give) and actually skip shards whose bbox cannot hold a neighbour."""

    def build(self, k=4):
        svc, pts, spec = build_service("rings", k)
        stream(svc, pts, k)
        return svc, pts, spec

    def test_far_probe_scans_zero_shards(self):
        svc, pts, _ = self.build()
        got = svc.query(np.array([[7.0, 7.0], [-2.0, 3.0]], np.float32))
        np.testing.assert_array_equal(got, [-1, -1])
        assert svc.query_chunks == 1
        assert svc.query_shards_scanned == 0

    def test_local_probe_skips_distant_shards(self):
        svc, pts, _ = self.build(k=4)
        # One live point's own coordinates: at most the shards whose
        # dilated bbox reaches it are scanned — never all four (the
        # rings layout is Morton-partitioned into compact blocks).
        live, _, labels = svc.live()
        probe = live[:1]
        got = svc.query(probe)
        assert got[0] == labels[0]
        assert 1 <= svc.query_shards_scanned < 4 * svc.query_chunks

    def test_routing_is_invisible_in_answers(self):
        svc, pts, _ = self.build(k=4)
        live, _, labels = svc.live()
        rng = np.random.default_rng(0)
        q = np.concatenate([live[rng.integers(0, len(live), 300)],
                            rng.uniform(-0.2, 1.2, (100, 2))]).astype(
                                np.float32)
        got = svc.query(q)
        # reference: brute-force nearest clustered live point within eps
        eps = svc.cfg.eps
        ref = np.full(len(q), -1, np.int32)
        keep = labels >= 0
        d2 = ((q[:, None, :].astype(np.float32)
               - live[None, keep, :]) ** 2).sum(-1)
        j = np.argmin(d2, axis=1)
        hit = d2[np.arange(len(q)), j] <= np.float32(eps) * np.float32(eps)
        ref = np.where(hit, labels[keep][j], -1)
        np.testing.assert_array_equal(got, ref)

    def test_bbox_mirror_tracks_ingest_and_evict(self):
        svc = ClusterService(StreamConfig(
            shards=1, capacity=64, max_batch=64,
            ddc=layout_cfg(spatial.PHASE2_LAYOUTS["rings"])))
        assert svc.shard_bbox(0) is None
        svc.ingest(0, np.array([[0.1, 0.2], [0.4, 0.9]]), t=0.0)
        assert svc.shard_bbox(0) == pytest.approx((0.1, 0.2, 0.4, 0.9))
        svc.ingest(0, np.array([[0.8, 0.05]]), t=1.0)
        assert svc.shard_bbox(0) == pytest.approx((0.1, 0.05, 0.8, 0.9))
        svc.evict_older_than(0, 0.5)      # drop the first two points
        assert svc.shard_bbox(0) == pytest.approx((0.8, 0.05, 0.8, 0.05))
        svc.clear(0)
        assert svc.shard_bbox(0) is None

    def test_counters_surface_in_stats(self):
        svc, pts, _ = self.build(k=2)
        svc.query(pts[:16])
        stats = svc.stats()
        assert stats["query_chunks"] >= 1
        assert 0 <= stats["query_shards_scanned"] \
            <= stats["query_shards_possible"]

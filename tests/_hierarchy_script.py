"""Hierarchical≡flat cells for the DEVICE-RESIDENT serve engine, run
under a 16-device CPU override by tests/test_hierarchy.py (the dist
data plane lays one shard per mesh device, and the sweep goes to 16
shards).

Each cell streams the same ingest schedule into a flat dist service and
an ``agg_degree`` twin, refreshing after every batch, then asserts the
§13 contract: per-shard global labels and slot maps bit-identical,
per-node caches equal to a from-scratch rebuild, the flat pair-d2 cache
absent in tree mode, and the delta path actually taken.

Modes (argv[1]): ``quick`` (two cells, tier-1) or ``all`` (every tuned
layout × {4, 8, 16} shards × degree {2, 4}).  Prints PASS lines; any
exception fails.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"

import numpy as np

from repro.data import spatial
from repro.ddc import DDC, DDCConfig

N = 1024
BATCH = 128


def build(layout: str, k: int, degree=None) -> DDC:
    spec = spatial.PHASE2_LAYOUTS[layout]
    cap = spatial.shard_capacity(N, k)
    cfg = DDCConfig(
        eps=spec["eps"], min_pts=spec["min_pts"], grid=spec["grid"],
        max_clusters=spec["max_clusters"], max_verts=spec["max_verts"],
        backend="dist", shards=k, capacity=cap,
        max_batch=min(BATCH, cap), agg_degree=degree).validate()
    return DDC(cfg)


def one(layout: str, k: int, degree: int):
    spec = spatial.PHASE2_LAYOUTS[layout]
    pts = spec["make"](N)
    flat, hier = build(layout, k), build(layout, k, degree)
    for shard, chunk in spatial.stream_batches(pts, k, BATCH):
        for model in (flat, hier):
            model.partial_fit(shard, chunk)
            model.service.refresh()

    np.testing.assert_array_equal(
        hier.labels_, flat.labels_,
        err_msg=f"{layout} k={k} d={degree}: labels diverged from flat")
    np.testing.assert_array_equal(
        np.asarray(hier.service._maps), np.asarray(flat.service._maps),
        err_msg=f"{layout} k={k} d={degree}: slot maps diverged from flat")
    tree = hier.service.hierarchy
    assert tree is not None and tree.ready
    assert hier.service.pair_d2 is None, "flat cache alive in tree mode"
    assert tree.cache_exact(), "a node cache diverged from scratch rebuild"
    assert hier.service.delta_refreshes > 0, "tree never took the delta path"
    print(f"PASS {layout} k={k} d={degree} depth={tree.depth} "
          f"nodes={tree.n_nodes} deltas={hier.service.delta_refreshes}")


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "quick"
    if which == "quick":
        one("linked_ovals", 4, 2)
        one("rings", 8, 4)
    elif which == "all":
        for layout in sorted(spatial.PHASE2_LAYOUTS):
            for k in (4, 8, 16):
                for degree in (2, 4):
                    one(layout, k, degree)
    else:
        for k in (4, 8, 16):
            for degree in (2, 4):
                one(which, k, degree)
    print("ALL_OK")

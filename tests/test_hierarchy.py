"""Hierarchical≡flat equivalence suite for the aggregator tree
(DESIGN.md §13).

The contract under test: a ``ClusterService`` running the
tree-of-aggregators (``agg_degree`` set) produces BIT-IDENTICAL
per-shard global labels and slot maps to the flat aggregator on the
same ingest schedule — for every tuned layout, shard count, and tree
degree, through quarantine/recovery and snapshot restore.  The root's
canonical relabel (size desc, min composed flat slot asc) is what makes
the slot ids line up; the per-node pair-d2 caches must always equal a
from-scratch rebuild of the node batch (``cache_exact``).

Scope note (same envelope as the ``merge_tree ≡ merge_sync`` suite):
internal nodes re-extract merged contours before folding upward, so a
*pathological* partition could change overlap reachability mid-tree.
The equivalence promised — and swept here — is over the engines' real
partition orders (round-robin / contiguous streaming).

The dist-engine cells need 16 CPU devices, so they run in a subprocess
(tests/_hierarchy_script.py) mirroring the chaos-suite pattern.  Big
sweeps are marked ``slow``.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ddc
from repro.data import spatial
from repro.ddc import DDC, DDCConfig
from repro.ddc.config import ConfigError
from repro.serve import ClusterService, StreamConfig
from repro.serve.hierarchy import AggregatorTree

from test_serve_stream import N, build_service, layout_cfg, stream

SCRIPT = os.path.join(os.path.dirname(__file__), "_hierarchy_script.py")
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def build_pair(layout: str, k: int, degree: int):
    """A flat service and a tree-of-aggregators twin on the same layout."""
    spec = spatial.PHASE2_LAYOUTS[layout]
    pts = spec["make"](N)
    cap = spatial.shard_capacity(N, k)
    flat = ClusterService(StreamConfig(
        shards=k, capacity=cap, max_batch=256, ddc=layout_cfg(spec)))
    hier = ClusterService(StreamConfig(
        shards=k, capacity=cap, max_batch=256, agg_degree=degree,
        ddc=layout_cfg(spec)))
    return flat, hier, pts, spec


def assert_equiv(flat, hier):
    """Bit-identical where the §13 contract promises it: per-shard global
    labels, slot maps, and the global set's occupancy (valid/sizes).
    Root contours are re-extracted per level, so their raw vertices may
    differ without changing any label — they are not compared."""
    _, _, lab_flat = flat.live()
    _, _, lab_hier = hier.live()
    np.testing.assert_array_equal(lab_hier, lab_flat)
    np.testing.assert_array_equal(
        np.asarray(hier._maps), np.asarray(flat._maps))
    np.testing.assert_array_equal(
        np.asarray(hier.global_set.valid), np.asarray(flat.global_set.valid))
    np.testing.assert_array_equal(
        np.asarray(hier.global_set.sizes), np.asarray(flat.global_set.sizes))
    tree = hier.hierarchy
    assert tree is not None and hier.pair_d2 is None
    assert tree.cache_exact(), "a node cache diverged from scratch rebuild"


class TestBatchedPairD2Patch:
    """The ``update_pair_d2_many`` rewrite of ``merge_delta``'s dirty
    loop must be bit-exact vs both the sequential per-shard patch and a
    from-scratch matrix (including the pow2 duplicate-index padding)."""

    def _batch_and_cfg(self):
        svc, pts, _ = build_service("rings", 8)
        stream(svc, pts, 8)
        return svc._batch, svc.cfg, np.asarray(svc.pair_d2)

    def test_many_equals_sequential_equals_scratch(self):
        batch, cfg, exact = self._batch_and_cfg()
        c = cfg.max_clusters
        dirty = [1, 3, 6]
        stale = exact.copy()
        for s in dirty:                      # poison the rows to be patched
            stale[s * c:(s + 1) * c, :] = 123.0
            stale[:, s * c:(s + 1) * c] = 123.0
        seq = jnp.asarray(stale)
        for s in dirty:
            seq = ddc.update_pair_d2(seq, batch, s, cfg)
        padded = dirty + [dirty[-1]]         # pow2 pad repeats the last shard
        many = ddc.update_pair_d2_many(
            jnp.asarray(stale), batch, jnp.asarray(padded, jnp.int32), cfg)
        np.testing.assert_array_equal(np.asarray(many), np.asarray(seq))
        np.testing.assert_array_equal(np.asarray(many), exact)

    def test_merge_delta_multi_dirty_equals_full(self):
        batch, cfg, exact = self._batch_and_cfg()
        c = cfg.max_clusters
        dirty = [0, 2, 5, 7]
        stale = exact.copy()
        for s in dirty:
            stale[s * c:(s + 1) * c, :] = -1.0
            stale[:, s * c:(s + 1) * c] = -1.0
        m_d, maps_d, d2_d = ddc.merge_delta(
            batch, jnp.asarray(stale), dirty, cfg, None)
        m_f, maps_f, d2_f = ddc.merge_delta(batch, None, None, cfg, None)
        np.testing.assert_array_equal(np.asarray(d2_d), np.asarray(d2_f))
        np.testing.assert_array_equal(np.asarray(maps_d), np.asarray(maps_f))
        for a, b in zip(jax.tree.leaves(m_d), jax.tree.leaves(m_f)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestTreeTopology:
    def test_shapes(self):
        cfg = layout_cfg(spatial.PHASE2_LAYOUTS["rings"])
        t = AggregatorTree(16, 2, cfg)
        assert (t.depth, t.n_nodes, t.internal_edges) == (4, 15, 14)
        t = AggregatorTree(16, 4, cfg)
        assert (t.depth, t.n_nodes) == (2, 5)
        t = AggregatorTree(5, 4, cfg)        # ragged last group
        assert [len(lvl) for lvl in t.levels] == [2, 1]
        t = AggregatorTree(1, 2, cfg)        # degenerate single shard
        assert (t.depth, t.n_nodes, t.internal_edges) == (1, 1, 0)
        assert not t.ready

    def test_rejects_bad_shapes(self):
        cfg = layout_cfg(spatial.PHASE2_LAYOUTS["rings"])
        with pytest.raises(ValueError):
            AggregatorTree(8, 1, cfg)
        with pytest.raises(ValueError):
            AggregatorTree(0, 2, cfg)


class TestHierEqualsFlatStream:
    @pytest.mark.parametrize("layout,k,degree", [
        ("rings", 4, 2), ("linked_ovals", 8, 4),
        ("worm", 4, 4), ("noise_heavy", 8, 2)])
    def test_stream_cells(self, layout, k, degree):
        flat, hier, pts, spec = build_pair(layout, k, degree)
        for svc in (flat, hier):
            stream(svc, pts, k)
        assert_equiv(flat, hier)
        assert hier.delta_refreshes > 0, "tree never took the delta path"

    def test_depth1_root_cache_is_the_flat_cache(self):
        """k == degree collapses the tree to one node whose batch IS the
        shard batch — its cache must literally equal flat ``pair_d2``."""
        flat, hier, pts, _ = build_pair("rings", 4, 4)
        for svc in (flat, hier):
            stream(svc, pts, 4)
        tree = hier.hierarchy
        assert (tree.depth, tree.n_nodes) == (1, 1)
        np.testing.assert_array_equal(
            tree.cache_arrays()[0], np.asarray(flat.pair_d2))
        assert_equiv(flat, hier)

    def test_quarantined_leaf_and_recovery(self):
        """Fencing a shard excludes it at its leaf node only; recovery
        is one ordinary delta patch — both states must match flat."""
        flat, hier, pts, _ = build_pair("linked_ovals", 8, 2)
        for svc in (flat, hier):
            stream(svc, pts, 8)
        for svc in (flat, hier):
            svc._quarantine(3, "test fence")
            svc.refresh(force=True)
        _, _, lab_flat = flat.live()
        _, _, lab_hier = hier.live()
        np.testing.assert_array_equal(lab_hier, lab_flat)
        for svc in (flat, hier):
            assert svc.recover(3)
            svc.refresh(force=True)
        assert_equiv(flat, hier)

    def test_state_roundtrip_keeps_tree_mode(self):
        _, hier, pts, _ = build_pair("rings", 4, 2)
        stream(hier, pts, 4)
        arrays, manifest = hier.state_dict()
        assert manifest["agg_degree"] == 2
        svc2 = ClusterService.from_state(hier.scfg, arrays, manifest)
        assert svc2.hierarchy is not None and svc2.pair_d2 is None
        _, _, lab = hier.live()
        _, _, lab2 = svc2.live()
        np.testing.assert_array_equal(lab2, lab)
        assert svc2.hierarchy.cache_exact()

    @pytest.mark.slow
    @pytest.mark.parametrize("layout", sorted(spatial.PHASE2_LAYOUTS))
    def test_hier_equals_flat_sweep(self, layout):
        """Every layout × {4, 8, 16} shards × degree {2, 4}."""
        for k in (4, 8, 16):
            for degree in (2, 4):
                flat, hier, pts, _ = build_pair(layout, k, degree)
                for svc in (flat, hier):
                    stream(svc, pts, k)
                assert_equiv(flat, hier)


class TestFacade:
    def test_validation_rejects_bad_degrees(self):
        for bad in (1, 3, 6):
            with pytest.raises(ConfigError):
                DDCConfig(backend="stream", agg_degree=bad).validate()
        with pytest.raises(ConfigError):
            DDCConfig(backend="host", agg_degree=2).validate()
        DDCConfig(backend="stream", agg_degree=4).validate()

    def test_manifest_roundtrip(self):
        cfg = DDCConfig(backend="stream", agg_degree=4).validate()
        assert DDCConfig.from_manifest(cfg.to_manifest()) == cfg

    def test_facade_labels_match_flat(self):
        spec = spatial.PHASE2_LAYOUTS["rings"]
        pts = spec["make"](512)
        kw = dict(eps=spec["eps"], min_pts=spec["min_pts"],
                  grid=spec["grid"], max_clusters=spec["max_clusters"],
                  max_verts=spec["max_verts"], backend="stream", shards=4)
        flat = DDC(DDCConfig(**kw).validate()).fit(pts)
        hier = DDC(DDCConfig(agg_degree=2, **kw).validate()).fit(pts)
        np.testing.assert_array_equal(hier.labels_, flat.labels_)
        assert hier.backend.service.hierarchy is not None


# -- dist engine (16 CPU devices -> subprocess) -----------------------------

def run_script(arg: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(SRC)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, SCRIPT, arg],
        capture_output=True, text=True, timeout=1800, env=env)
    assert proc.returncode == 0, (
        f"{arg} failed:\n{proc.stdout}\n{proc.stderr}")
    return proc.stdout


def test_dist_hier_quick():
    """Two cells on the device-resident engine: labels/maps equal flat,
    node caches exact, delta path actually taken."""
    out = run_script("quick")
    assert "ALL_OK" in out and out.count("PASS") == 2


@pytest.mark.slow
def test_dist_hier_sweep():
    """Every layout × {4, 8, 16} shards × degree {2, 4} on dist."""
    out = run_script("all")
    assert "ALL_OK" in out

"""Multi-device integration checks, run under XLA_FLAGS=8 host devices
by tests/test_distributed.py.  Prints PASS lines; any exception fails."""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import dbscan as db
from repro.core import ddc
from repro.data import spatial
from repro.launch import mesh as mesh_mod
from repro.parallel import api as par
from repro.parallel import compress
from repro.parallel import sharding as shard_rules
from repro import configs
from repro.train import optimizer as opt_mod
from repro.train import step as step_mod


def check_ddc_sync_async_identical():
    pts, _ = spatial.make_blobs(1024, 5, seed=3)
    mesh = mesh_mod.make_host_mesh(8)
    results = {}
    for sched, deg in (("sync", 2), ("async", 2), ("tree", 2), ("tree", 4)):
        cfg = ddc.DDCConfig(eps=0.05, min_pts=5, max_clusters=16, max_verts=64,
                            grid=96, schedule=sched, tree_degree=deg)
        run = ddc.make_ddc_fn(mesh, "data", cfg)
        glabels, gcs, _ = run(jnp.asarray(pts), jnp.ones(len(pts), bool))
        results[f"{sched}{deg}"] = (np.asarray(glabels), np.asarray(gcs.valid).sum())
    for name, (lab, nv) in results.items():
        la, _ = results["sync2"]
        co_x = (lab[:, None] == lab[None, :]) & (lab >= 0)[:, None]
        co_r = (la[:, None] == la[None, :]) & (la >= 0)[:, None]
        assert (co_x == co_r).all(), f"{name} disagrees with sync"
        assert nv == 5, (name, nv)
    a, b = results["sync2"], results["async2"]
    # identical global clustering from both schedules (paper claim)
    la, lb = a[0], b[0]
    co_a = (la[:, None] == la[None, :]) & (la >= 0)[:, None]
    co_b = (lb[:, None] == lb[None, :]) & (lb >= 0)[:, None]
    assert (co_a == co_b).all(), "sync/async disagree"
    assert a[1] == b[1] == 5, (a[1], b[1])
    # and both match sequential DBSCAN
    seq = db.dbscan_ref(pts, 0.05, 5)
    co_s = (seq[:, None] == seq[None, :]) & (seq >= 0)[:, None]
    assert (co_a == co_s).all(), "DDC != sequential DBSCAN"
    print("PASS ddc_sync_async_identical")


def check_ddc_collective_bytes():
    """Butterfly (async) moves log2(K)/(K-1) of the all-gather (sync) bytes."""
    pts, _ = spatial.make_blobs(512, 4, seed=1)
    mesh = mesh_mod.make_host_mesh(8)
    from repro.launch import hlo_cost
    byts = {}
    for sched in ("sync", "async"):
        cfg = ddc.DDCConfig(eps=0.05, min_pts=5, max_clusters=8, max_verts=32,
                            grid=64, schedule=sched)
        run = ddc.make_ddc_fn(mesh, "data", cfg)
        lowered = jax.jit(run.__wrapped__ if hasattr(run, "__wrapped__") else run
                          ).lower(jax.ShapeDtypeStruct((512, 2), jnp.float32),
                                  jax.ShapeDtypeStruct((512,), bool))
        res = hlo_cost.analyze_text(lowered.compile().as_text())
        byts[sched] = res["collectives"]
    ag_sync = byts["sync"]["all-gather"]
    cp_async = byts["async"]["collective-permute"]
    assert ag_sync > 0, byts
    assert cp_async > 0, byts
    assert cp_async < ag_sync, (cp_async, ag_sync)
    print(f"PASS ddc_collective_bytes sync_ag={ag_sync} async_cp={cp_async}")


def check_sharded_train_step():
    mesh = mesh_mod.make_mesh((4, 2), ("data", "model"))
    pctx = par.ParallelCtx(mesh=mesh, fsdp=True)
    cfg = configs.get_config("qwen3-8b").tiny()
    tcfg = step_mod.TrainConfig(opt=opt_mod.OptConfig(lr=1e-3), microbatches=2)
    with par.use(pctx):
        state = step_mod.make_train_state(cfg, tcfg)
    sh = shard_rules.param_shardings(state, pctx)
    state = jax.device_put(state, sh)
    step_fn = step_mod.build_train_step(cfg, tcfg, pctx)
    jit_step = jax.jit(step_fn, in_shardings=(sh, None), out_shardings=(sh, None),
                       donate_argnums=(0,))
    batch = {"tokens": jnp.ones((8, 32), jnp.int32)}
    l0 = None
    for i in range(3):
        state, metrics = jit_step(state, batch)
        assert np.isfinite(float(metrics["loss"]))
        l0 = l0 or float(metrics["loss"])
    assert float(metrics["loss"]) < l0, "loss did not decrease on repeated batch"
    # verify params are actually sharded across devices
    w = state.params["blocks"]["l0"]["mixer"]["wq"]
    assert len({s.device for s in w.addressable_shards}) > 1
    print("PASS sharded_train_step")


def check_moe_island_matches_local():
    mesh = mesh_mod.make_mesh((2, 4), ("data", "model"))
    cfg = configs.get_config("llama4-scout-17b-a16e").tiny()
    from repro.models import layers as L
    key = jax.random.PRNGKey(0)
    p = L.moe_init(cfg, key)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
    with par.use(par.ParallelCtx(mesh=None)):
        y_local, aux_local = L.moe_apply(cfg, p, x)
    for impl in ("epsum", "a2a"):
        with par.use(par.ParallelCtx(mesh=mesh, moe_impl=impl)):
            y_mesh, aux_mesh = jax.jit(lambda p, x: L.moe_apply(cfg, p, x))(p, x)
        np.testing.assert_allclose(np.asarray(y_local), np.asarray(y_mesh),
                                   rtol=2e-3, atol=2e-3, err_msg=impl)
        np.testing.assert_allclose(float(aux_local), float(aux_mesh),
                                   rtol=1e-3, err_msg=impl)
    # a2a with replicated tokens (tiny-batch decode path)
    x1 = x[:1]
    with par.use(par.ParallelCtx(mesh=None)):
        y1_local, _ = L.moe_apply(cfg, p, x1)
    with par.use(par.ParallelCtx(mesh=mesh, moe_impl="a2a")):
        y1_mesh, _ = jax.jit(lambda p, x: L.moe_apply(cfg, p, x))(p, x1)
    np.testing.assert_allclose(np.asarray(y1_local), np.asarray(y1_mesh),
                               rtol=2e-3, atol=2e-3, err_msg="a2a-replicated")
    print("PASS moe_island_matches_local")


def check_int8_allreduce():
    mesh = mesh_mod.make_host_mesh(8, axis="data")
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)), jnp.float32)}
    out = compress.shard_map_all_reduce(g, mesh, axes=("data",))
    # every lane had the same replicated grad -> mean == dequantised value
    err = float(jnp.max(jnp.abs(out["w"] - g["w"])))
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127
    assert err <= scale * 1.01, (err, scale)
    print("PASS int8_allreduce")


def check_elastic_restore():
    """Save under an 8-way mesh, restore onto a 4x2 mesh (elastic)."""
    import tempfile
    from repro.train import checkpoint as ck
    mesh8 = mesh_mod.make_host_mesh(8, axis="data")
    x = jnp.arange(64.0).reshape(8, 8)
    xs = jax.device_put(x, NamedSharding(mesh8, P("data", None)))
    state = {"x": xs}
    with tempfile.TemporaryDirectory() as d:
        ck.save(d, state, step=1)
        mesh42 = mesh_mod.make_mesh((4, 2), ("data", "model"))
        sh = {"x": NamedSharding(mesh42, P("model", "data"))}
        restored, _ = ck.restore(d, jax.eval_shape(lambda: state), shardings=sh)
        np.testing.assert_array_equal(np.asarray(restored["x"]), np.asarray(x))
        assert restored["x"].sharding == sh["x"]
    print("PASS elastic_restore")


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    fns = {
        "ddc": check_ddc_sync_async_identical,
        "coll": check_ddc_collective_bytes,
        "train": check_sharded_train_step,
        "moe": check_moe_island_matches_local,
        "int8": check_int8_allreduce,
        "elastic": check_elastic_restore,
    }
    if which == "all":
        for f in fns.values():
            f()
    else:
        fns[which]()
    print("ALL_OK")

"""Roofline tooling: the while-aware HLO analyzer against ground truth.

Also documents WHY hlo_cost exists: XLA's cost_analysis counts loop
bodies once (asserted below), which would misstate scanned-layer models
by ~n_layers.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_cost, roofline


def scanned_matmul(x, ws):
    def body(x, w):
        return jnp.tanh(x @ w), None
    x, _ = jax.lax.scan(body, x, ws)
    return x


N, L = 128, 7
X = jax.ShapeDtypeStruct((N, N), jnp.float32)
WS = jax.ShapeDtypeStruct((L, N, N), jnp.float32)


@pytest.fixture(scope="module")
def compiled():
    return jax.jit(scanned_matmul).lower(X, WS).compile()


def test_xla_cost_analysis_counts_loops_once(compiled):
    """The motivating defect: XLA reports 1 matmul, not L."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # older jax returns one dict per program
        ca = ca[0]
    flops = ca["flops"]
    assert abs(flops - 2 * N**3) / (2 * N**3) < 0.1


def test_hlo_cost_applies_trip_counts(compiled):
    res = hlo_cost.analyze_text(compiled.as_text())
    want = L * 2 * N**3
    assert abs(res["flops"] - want) / want < 0.01


def test_weight_bytes_counted_once_per_iteration(compiled):
    res = hlo_cost.analyze_text(compiled.as_text())
    weight_bytes = L * N * N * 4
    assert res["bytes"] > weight_bytes  # reads weights + activations
    assert res["bytes"] < 50 * weight_bytes


def test_collective_bytes_parse():
    hlo = """
HloModule m
ENTRY %main (a: f32[128,64]) -> f32[128,64] {
  %a = f32[128,64]{1,0} parameter(0)
  %ar = f32[128,64]{1,0} all-reduce(%a), replica_groups={}, to_apply=%add
  %ag = f32[256,64]{1,0} all-gather(%ar), dimensions={0}
  ROOT %cp = f32[128,64]{1,0} collective-permute(%ar), source_target_pairs={{0,1}}
}
"""
    res = hlo_cost.analyze_text(hlo)
    assert res["collectives"]["all-reduce"] == 128 * 64 * 4
    assert res["collectives"]["all-gather"] == 256 * 64 * 4
    assert res["collectives"]["collective-permute"] == 128 * 64 * 4


def test_vmem_kernel_scope_excluded_from_bytes():
    def attnish(q, k):
        with jax.named_scope("vmem_kernel_test"):
            s = q @ k.T
            return jax.nn.softmax(s, axis=-1) @ k
    q = jax.ShapeDtypeStruct((512, 64), jnp.float32)
    k = jax.ShapeDtypeStruct((512, 64), jnp.float32)
    txt = jax.jit(attnish).lower(q, k).compile().as_text()
    res = hlo_cost.analyze_text(txt)
    # flops still counted (2 matmuls)
    assert res["flops"] >= 2 * 2 * 512 * 512 * 64 * 0.9
    # but the (512,512) logits never count as HBM traffic
    assert res["bytes"] < 512 * 512 * 4 * 2


def test_roofline_terms_and_bottleneck():
    r = roofline.Roofline(
        flops=197e12, bytes_accessed=819e9 / 2, coll_bytes=0.0,
        chips=256, model_flops=197e12 * 256 * 0.5,
    ).finalize()
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 0.5) < 1e-9
    assert r.bottleneck == "compute"
    assert abs(r.useful_ratio - 0.5) < 1e-9


def test_spmd_costs_are_per_device():
    """Partitioned modules report per-device flops (documented invariant
    the roofline formulas rely on)."""
    if len(jax.devices()) < 2:
        pytest.skip("needs >1 host device")

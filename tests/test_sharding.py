"""Sharding-rule unit tests (AbstractMesh — no devices needed)."""
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.parallel import api as par
from repro.parallel import sharding as sr


def mesh2(multi_pod=False):
    if multi_pod:
        return compat.abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    return compat.abstract_mesh((16, 16), ("data", "model"))


def ctx(fsdp=False, multi_pod=False):
    return par.ParallelCtx(mesh=mesh2(multi_pod), fsdp=fsdp)


class TestParamSpecs:
    def test_attention_tp(self):
        c = ctx()
        assert sr.spec_for(("blocks", "l0", "mixer", "wq"), (62, 4096, 4096), c) \
            == P(None, None, "model")
        assert sr.spec_for(("blocks", "l0", "mixer", "wo"), (62, 4096, 4096), c) \
            == P(None, "model", None)

    def test_embed_vocab_sharded(self):
        c = ctx()
        assert sr.spec_for(("embed",), (151936, 4096), c) == P("model", None)

    def test_moe_expert_parallel(self):
        c = ctx()
        spec = sr.spec_for(("blocks", "l0", "ffn", "w1"), (61, 384, 7168, 2048), c)
        assert spec == P(None, "model", None, None)

    def test_dense_ffn_vs_moe_disambiguation(self):
        c = ctx()
        dense = sr.spec_for(("blocks", "l0", "ffn", "w1"), (36, 4096, 12288), c)
        assert dense == P(None, None, "model")

    def test_shared_expert_is_dense_tp(self):
        c = ctx()
        spec = sr.spec_for(("blocks", "l0", "ffn", "shared", "w2"), (61, 2048, 7168), c)
        assert spec == P(None, "model", None)

    def test_non_divisible_replicates(self):
        c = ctx()
        # 40 heads * 64 hd = 2560; 2560 % 16 == 0 so it shards...
        assert sr.spec_for(("blocks", "l0", "mixer", "wq"), (62, 2560, 2560), c) \
            == P(None, None, "model")
        # ...but a 61-dim can't shard over 16
        assert sr.spec_for(("blocks", "l0", "mixer", "wq"), (62, 2560, 61), c) \
            == P(None, None, None)

    def test_fsdp_adds_data_axis(self):
        c = ctx(fsdp=True)
        spec = sr.spec_for(("blocks", "l0", "mixer", "wq"), (62, 4096, 4096), c)
        assert spec == P(None, "data", "model")

    def test_fsdp_multipod_uses_both_dp_axes(self):
        c = ctx(fsdp=True, multi_pod=True)
        spec = sr.spec_for(("blocks", "l0", "mixer", "wq"), (62, 4096, 4096), c)
        assert spec == P(None, ("pod", "data"), "model")

    def test_norms_replicated_tp(self):
        c = ctx()
        spec = sr.spec_for(("blocks", "l0", "norm1", "w"), (62, 4096), c)
        assert spec == P(None, None) or spec == P(None, "data")


class TestCtxHelpers:
    def test_spec_drops_missing_axes(self):
        c = par.ParallelCtx(mesh=mesh2(multi_pod=False))
        assert c.spec("batch", None, "heads") == P(("data",), None, "model")

    def test_axis_size(self):
        c = par.ParallelCtx(mesh=mesh2(multi_pod=True))
        assert c.axis_size("batch") == 32
        assert c.axis_size("experts") == 16

    def test_no_mesh_no_op(self):
        x = jnp.ones((4, 4))
        assert par.constrain(x, "batch", None) is x

"""High-QPS query-tier suite (DESIGN.md §12): the QueryResult API
redesign, snapshot-versioned reads, coalesced pow2-bucketed launches,
the bounded queue, degraded reads, and the typed ServiceStats contract.

* **QueryResult shim** — the structured result must duck-type as its
  labels ndarray so every pre-redesign caller keeps working, and
  ``legacy=True`` must return the bare array outright.
* **Snapshot semantics** — versions are monotonic; reads from version V
  are bit-identical to a synchronous query on the state frozen at V
  (the multi-device layout × shards × engine sweep runs in a
  subprocess: tests/_query_tier_script.py); a racing refresh is seen in
  full or not at all.
* **Coalescing/bucketing** — overlapping scan sets share one kernel
  launch; the jit cache stays under the pow2 bucket-count bound no
  matter the request-width mix.
* **Host/jit snapshot path** — repeated queries must NOT re-run the
  clustering pipeline (the silent-recompute regression).
* **ServiceStats** — one typed contract over all four backends, with
  the legacy dict views derived from it.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.data import spatial
from repro.ddc import (
    DDC, DDCConfig, QueryResult, QueryTier, QueueFull, ServiceCounters,
    ServiceGauges, ServiceStats,
)
from repro.serve import query_tier as qt

from test_serve_stream import build_service, stream  # noqa: F401

SCRIPT = os.path.join(os.path.dirname(__file__), "_query_tier_script.py")
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_script(arg: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(SRC)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, SCRIPT, arg],
        capture_output=True, text=True, timeout=1800, env=env)
    assert proc.returncode == 0, (
        f"{arg} failed:\n{proc.stdout}\n{proc.stderr}")
    return proc.stdout


def fitted_host(n=512):
    spec = spatial.PHASE2_LAYOUTS["rings"]
    pts = spec["make"](n)
    cfg = DDCConfig(
        **{k: spec[k] for k in ("eps", "min_pts", "grid", "max_verts",
                                "max_clusters")},
        backend="host", shards=2)
    return DDC(cfg).fit(pts), pts


def streamed_service(layout="rings", k=4):
    svc, pts, spec = build_service(layout, k)
    stream(svc, pts, k)
    return svc, pts, spec


class TestQueryResultShim:
    """The structured result must be a drop-in for the old ndarray."""

    def test_fields_and_repr(self):
        model, pts = fitted_host()
        res = model.query(pts[:8])
        assert isinstance(res, QueryResult)
        assert res.version >= 1
        assert res.degraded is False
        assert isinstance(res.scanned_shards, tuple)
        assert res.latency_ms >= 0.0
        assert "version" in repr(res)

    def test_ndarray_duck_typing(self):
        model, pts = fitted_host()
        res = model.query(pts[:16])
        assert np.asarray(res).dtype == np.int32
        assert len(res) == 16 and res.shape == (16,)
        assert list(res) == res.tolist()
        assert res[0] == res.labels[0]
        # comparison dunders (the np.mean(labels >= 0) idiom)
        assert 0.0 <= float(np.mean(res >= 0)) <= 1.0
        np.testing.assert_array_equal(np.where(res >= 0, res.labels, -1),
                                      res.labels)

    def test_legacy_flag_returns_bare_ndarray(self):
        model, pts = fitted_host()
        bare = model.query(pts[:8], legacy=True)
        assert type(bare) is np.ndarray
        np.testing.assert_array_equal(bare, model.query(pts[:8]).labels)

    def test_service_return_stale_keeps_tuple_shape(self):
        svc, pts, _ = streamed_service()
        out, stale = svc.query(pts[:8], return_stale=True)
        assert isinstance(out, QueryResult) and stale is False

    def test_hashable_identity(self):
        """Regression: defining the ndarray-shim ``__eq__`` without
        ``__hash__`` made Python set ``__hash__ = None``, so any caller
        deduping results in a set (or keying a dict on them) got
        ``TypeError: unhashable type``.  The comparisons are elementwise
        shims, not value equality, so the contract is identity hashing."""
        model, pts = fitted_host()
        res = model.query(pts[:4])
        assert hash(res) == object.__hash__(res)
        assert res in {res}
        assert {res: "hit"}[res] == "hit"


class TestSharedRouting:
    """Satellite regression: the ε·(1+1e-6) bbox dilation lives in ONE
    helper (``routing_eps``/``bbox_route``) used by the sync control
    plane, the dist lane scan flags, and the snapshot router — a
    boundary query must never be routed differently by path."""

    def test_dilation_single_source(self):
        assert qt.routing_eps(1.0) == qt.ROUTE_EPS_DILATION
        assert qt.routing_eps(0.25) == 0.25 * qt.ROUTE_EPS_DILATION

    def test_exact_eps_boundary_is_scanned(self):
        """A query exactly ε beyond a bbox edge sits on the routing
        knife-edge — the dilation exists precisely so it still scans."""
        bboxes = [(0.2, 0.2, 0.4, 0.4), None]
        eps = 0.05
        on_edge = np.array([[0.4 + eps, 0.3]])
        scan = qt.bbox_route(bboxes, on_edge, eps)
        assert scan.tolist() == [True, False]
        beyond = np.array([[0.4 + eps * (1 + 2e-6), 0.3]])
        assert qt.bbox_route(bboxes, beyond, eps).tolist() == [False, False]

    def test_boundary_points_route_identically_sync_vs_snapshot(self):
        svc, pts, spec = streamed_service("rings", 4)
        snap = svc.snapshot()
        eps = float(spec["eps"])
        probes = []
        for s in range(4):
            box = svc.shard_bbox(s)
            if box is None:
                continue
            x0, y0, x1, y1 = box
            for d in (eps, eps * (1 + 5e-7), eps * (1 + 2e-6), 2 * eps):
                probes += [[x1 + d, (y0 + y1) / 2],
                           [(x0 + x1) / 2, y0 - d],
                           [x0 - d, y1 + d]]
        for row in np.asarray(probes, np.float64):
            chunk = row[None].astype(np.float32)
            sync_scan = svc._route(chunk)
            snap_scan, _ = qt.route_snapshot(snap, chunk)
            np.testing.assert_array_equal(sync_scan, snap_scan)


class TestSnapshotVersioning:
    def test_version_monotonic_over_refreshes(self):
        svc, pts, _ = streamed_service()
        v = svc.snapshot().version
        assert v >= 1
        svc.ingest(0, pts[:4])
        svc.refresh()
        assert svc.snapshot().version == v + 1

    def test_empty_service_short_circuits_at_version_zero(self):
        svc, pts, _ = build_service("rings", 2)
        res = svc.query(np.array([[0.5, 0.5]], np.float32))
        assert res.version == 0 and res[0] == -1
        assert svc.snapshot() is None and svc.read_snapshot() is None

    def test_snapshot_read_bit_equals_frozen_sync(self):
        """max_staleness=inf tier reads == the engine's own sync query
        on the same frozen state (the in-process single-device twin of
        the subprocess sweep)."""
        svc, pts, _ = streamed_service()
        tier = QueryTier(svc, max_staleness=float("inf"))
        rng = np.random.default_rng(0)
        q = np.concatenate([pts[rng.integers(0, len(pts), 100)],
                            rng.uniform(0, 1, (40, 2)).astype(np.float32)])
        res = tier.query(q)
        assert res.version == svc.snapshot().version
        np.testing.assert_array_equal(np.asarray(res),
                                      svc.query(q, legacy=True))

    def test_stale_snapshot_serves_pre_write_state(self):
        """Writes WITHOUT a refresh never move the published view: an
        inf-staleness tier keeps answering from the last version in
        full — stale but consistent, never torn."""
        svc, pts, _ = streamed_service()
        tier = QueryTier(svc, max_staleness=float("inf"))
        q = pts[:64]
        before = np.array(tier.query(q).labels)
        v = svc.snapshot().version
        svc.ingest(0, np.full((8, 2), 0.503, np.float32))   # dirty, unpublished
        res = tier.query(q)
        assert res.version == v
        np.testing.assert_array_equal(np.asarray(res), before)
        svc.refresh()
        assert svc.snapshot().version == v + 1
        assert tier.query(q).version == v + 1

    def test_fresh_policy_folds_pending_writes(self):
        """max_staleness=None (the facade default) refreshes dirty
        state before answering — the legacy read semantics."""
        svc, pts, _ = streamed_service()
        tier = QueryTier(svc, max_staleness=None)
        v = svc.snapshot().version
        svc.ingest(0, pts[:4])
        assert tier.query(pts[:16]).version == v + 1

    def test_restore_republishes_and_version_continues(self, tmp_path):
        model, pts = fitted_host()
        model.query(pts[:4])       # batch backends publish on first read
        v = model.backend.snapshot().version
        model.save(str(tmp_path / "ckpt"))
        restored = DDC.load(str(tmp_path / "ckpt"))
        res = restored.query(pts[:8])
        assert res.version >= 1
        np.testing.assert_array_equal(np.asarray(res),
                                      model.query(pts[:8], legacy=True))
        assert v >= 1


class TestCoalescing:
    def test_overlapping_requests_share_one_launch(self):
        svc, pts, _ = streamed_service()
        tier = QueryTier(svc, max_staleness=float("inf"))
        tier.query(pts[:4])                      # compile + warm routing
        launches0 = tier.query_launches
        for off in (0, 8, 16):                    # same region: scan overlap
            tier.submit(pts[off:off + 8])
        tier.drain()
        assert tier.query_launches == launches0 + 1
        assert tier.coalesced_requests >= 3

    def test_out_of_bounds_request_skips_the_kernel(self):
        svc, pts, _ = streamed_service()
        tier = QueryTier(svc, max_staleness=float("inf"))
        h1 = tier.submit(pts[:8])
        h2 = tier.submit(np.array([[9.0, 9.0]], np.float32))
        tier.drain()
        assert h2.result.scanned_shards == ()
        assert h2.result[0] == -1
        assert h1.result.scanned_shards != ()

    def test_coalesced_answers_equal_individual_sync(self):
        svc, pts, _ = streamed_service()
        tier = QueryTier(svc, max_staleness=float("inf"))
        rng = np.random.default_rng(3)
        chunks = [rng.uniform(0, 1, (n, 2)).astype(np.float32)
                  for n in (5, 33, 17, 64)]
        handles = [tier.submit(c) for c in chunks]
        tier.drain()
        for c, h in zip(chunks, handles):
            np.testing.assert_array_equal(np.asarray(h.result),
                                          svc.query(c, legacy=True))


class TestBucketing:
    def test_jit_cache_bounded_by_pow2_buckets(self):
        """Any mix of request widths compiles at most (#query buckets ×
        #shard-width buckets) kernel entries — the ISSUE's cache-bound
        assertion."""
        k = 4
        svc, pts, _ = streamed_service(k=k)
        tier = QueryTier(svc, max_queries=256, bucket_min=16,
                         max_staleness=float("inf"))
        qt.clear_snapshot_query_cache()
        rng = np.random.default_rng(7)
        for n in (1, 2, 3, 7, 15, 16, 17, 31, 40, 64, 100, 200, 256, 300):
            tier.query(rng.uniform(0, 1, (n, 2)).astype(np.float32))
        assert qt.snapshot_query_cache_entries() <= tier.cache_bound(k), (
            qt.snapshot_query_cache_entries(), tier.cache_bound(k))

    def test_pow2_bucket_maths(self):
        assert qt.pow2_bucket(1, 16, 256) == 16
        assert qt.pow2_bucket(16, 16, 256) == 16
        assert qt.pow2_bucket(17, 16, 256) == 32
        assert qt.pow2_bucket(300, 16, 256) == 256

    def test_bucketing_is_invisible_in_answers(self):
        svc, pts, _ = streamed_service()
        tier = QueryTier(svc, bucket_min=16, max_staleness=float("inf"))
        for n in (3, 17, 63):
            c = pts[:n]
            np.testing.assert_array_equal(np.asarray(tier.query(c)),
                                          svc.query(c, legacy=True))


class TestQueueAndDeadlines:
    def test_queue_full_backpressure(self):
        svc, pts, _ = streamed_service()
        tier = QueryTier(svc, queue_depth=3, max_staleness=float("inf"))
        for _ in range(3):
            tier.submit(pts[:4])
        with pytest.raises(QueueFull, match="drain"):
            tier.submit(pts[:4])
        tier.drain()
        tier.submit(pts[:4])                     # drained: accepts again
        assert tier.pending == 1

    def test_missed_deadline_still_answered_and_counted(self):
        svc, pts, _ = streamed_service()
        tier = QueryTier(svc, max_staleness=float("inf"))
        import time as _time
        h = tier.submit(pts[:8], deadline=_time.monotonic() - 1.0)
        tier.drain()
        assert tier.deadline_misses == 1
        np.testing.assert_array_equal(np.asarray(h.result),
                                      svc.query(pts[:8], legacy=True))

    def test_facade_tier_uses_config_knobs(self):
        spec = spatial.PHASE2_LAYOUTS["rings"]
        cfg = DDCConfig(
            **{k: spec[k] for k in ("eps", "min_pts", "grid", "max_verts",
                                    "max_clusters")},
            backend="stream", shards=2, capacity=512, queue_depth=5,
            query_bucket_min=32, max_staleness=float("inf"))
        model = DDC(cfg).fit(spec["make"](512))
        tier = model.query_tier
        assert tier.queue_depth == 5
        assert tier.bucket_min == 32
        assert tier.max_staleness == float("inf")
        assert model.query_tier is tier          # one tier per backend

    def test_config_rejects_bad_tier_knobs(self):
        from repro.ddc import ConfigError
        with pytest.raises(ConfigError, match="queue_depth"):
            DDCConfig(queue_depth=0).validate()
        with pytest.raises(ConfigError, match="power of two"):
            DDCConfig(query_bucket_min=24).validate()
        with pytest.raises(ConfigError, match="max_staleness"):
            DDCConfig(max_staleness=-1.0).validate()


class TestDegradedReads:
    """Quarantine semantics carried into the snapshot path (§11 ∘ §12)."""

    def test_stale_quarantine_serves_last_good_rows(self):
        svc, pts, _ = streamed_service()
        tier = QueryTier(svc, max_staleness=float("inf"))
        q = pts[:64]
        healthy = tier.query(q)
        assert healthy.degraded is False
        target = healthy.scanned_shards[0]
        svc._quarantine(target, "chaos drill")   # AFTER the publish
        stale = tier.query(q)
        assert stale.degraded is True
        assert stale.version == healthy.version
        np.testing.assert_array_equal(np.asarray(stale),
                                      np.asarray(healthy))

    def test_publish_time_quarantine_routes_around_like_sync(self):
        svc, pts, _ = streamed_service()
        q = pts[:64]
        target = svc.query(q).scanned_shards[0]
        svc._quarantine(target, "chaos drill")
        svc.refresh(force=True)                  # publish WITH the quarantine
        tier = QueryTier(svc, max_staleness=float("inf"))
        res = tier.query(q)
        assert res.degraded is True
        assert target not in res.scanned_shards
        np.testing.assert_array_equal(np.asarray(res),
                                      svc.query(q, legacy=True))


class TestHostJitSnapshotPath:
    """Satellite fix: DDC.query on the batch backends must answer from
    the published snapshot, not silently re-run the pipeline per call."""

    @pytest.mark.parametrize("backend", ("host", "jit"))
    def test_repeated_queries_do_not_recompute(self, backend):
        spec = spatial.PHASE2_LAYOUTS["rings"]
        pts = spec["make"](512)
        cfg = DDCConfig(
            **{k: spec[k] for k in ("eps", "min_pts", "grid", "max_verts",
                                    "max_clusters")},
            # this pytest process sees ONE device: jit runs single-shard
            backend=backend, shards=2 if backend == "host" else 1)
        model = DDC(cfg).fit(pts)
        r1 = model.query(pts[:32])
        for _ in range(5):
            r2 = model.query(pts[:32])
        assert model.backend.refits == 1, (
            "query() re-ran the clustering pipeline per call")
        assert r1.version == r2.version == 1
        np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))

    def test_query_matches_own_labels(self):
        model, pts = fitted_host()
        labels = model.labels_
        got = model.query(pts)
        clustered = labels >= 0
        np.testing.assert_array_equal(np.asarray(got)[clustered],
                                      labels[clustered])

    def test_refit_bumps_version(self):
        model, pts = fitted_host()
        v1 = model.query(pts[:8]).version
        model.partial_fit(0, pts[:4])
        v2 = model.query(pts[:8]).version
        assert v2 == v1 + 1


class TestServiceStats:
    BACKENDS = ("host", "jit", "stream")

    def make(self, backend):
        spec = spatial.PHASE2_LAYOUTS["rings"]
        pts = spec["make"](512)
        cfg = DDCConfig(
            **{k: spec[k] for k in ("eps", "min_pts", "grid", "max_verts",
                                    "max_clusters")},
            # one-device pytest process: jit runs single-shard
            backend=backend, shards=2 if backend != "jit" else 1,
            capacity=512 if backend in ("stream", "dist") else None)
        return DDC(cfg).fit(pts), pts

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_typed_contract(self, backend):
        model, pts = self.make(backend)
        model.query(pts[:16])
        stats = model.stats()
        assert isinstance(stats, ServiceStats)
        assert isinstance(stats.counters, ServiceCounters)
        assert isinstance(stats.gauges, ServiceGauges)
        assert stats.backend == backend
        assert stats.gauges.snapshot_version >= 1
        assert stats.counters.snapshots_published >= 1

    def test_identical_key_sets_across_backends(self):
        keys = set()
        for backend in self.BACKENDS:
            model, pts = self.make(backend)
            model.query(pts[:8])
            d = model.stats().as_dict(nest_comm=False)
            keys.add(frozenset(d))
        assert len(keys) == 1, "backends disagree on the stats dict keys"

    def test_dict_views_derive_from_typed(self):
        model, pts = self.make("stream")
        model.query(pts[:16])
        stats = model.stats()
        d = stats.as_dict()
        assert d["refreshes"] == stats.counters.refreshes
        assert d["snapshot_version"] == stats.gauges.snapshot_version
        assert d["quarantined_shards"] == stats.counters.quarantine_events
        comm = model.comm_stats()
        assert comm["backend"] == "stream"
        assert comm["snapshot_version"] == d["snapshot_version"]

    def test_counters_monotonic_gauges_not(self):
        model, pts = self.make("stream")
        model.query(pts[:16])
        c1 = model.stats().counters
        model.partial_fit(0, pts[:4])
        model.query(pts[:16])
        c2 = model.stats().counters
        import dataclasses as dc
        for f in dc.fields(ServiceCounters):
            assert getattr(c2, f.name) >= getattr(c1, f.name), f.name

    def test_tier_counters_fold_into_stats(self):
        model, pts = self.make("stream")
        tier = model.query_tier
        tier.query(pts[:16])
        tier.query(pts[:16])
        stats = model.stats()
        assert stats.counters.queries_served == 2
        assert stats.counters.query_launches >= 1


class TestSubprocessSweep:
    """The layout × {2,4,8} shards × both-engines frozen-twin
    bit-exactness sweep, in an 8-device subprocess."""

    def test_quick(self):
        out = run_script("linked_ovals")
        assert "ALL_OK" in out and out.count("PASS") == 6

    @pytest.mark.slow
    @pytest.mark.parametrize("layout", sorted(spatial.PHASE2_LAYOUTS))
    def test_sweep(self, layout):
        out = run_script(layout)
        assert "ALL_OK" in out and out.count("PASS") == 6

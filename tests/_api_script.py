"""Backend-equivalence property check for the `repro.ddc` facade, run
under an 8-device CPU override by tests/test_ddc_api.py (the device
count must be pinned before jax initialises, which pytest's process
already did with 1 device).

For one ``PHASE2_LAYOUTS`` layout (argv[1]) and every shard count in
{2, 4, 8}: the ``host``, ``jit``, ``stream``, and ``dist`` backends must
produce the IDENTICAL global clustering (same noise set, label bijection)
through the single ``DDC.fit`` surface, and the tuned layout must pass
the ``validate(sample=...)`` sizing probe.  Prints PASS lines; any
exception fails.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

from repro.data import spatial
from repro.ddc import DDC, DDCConfig, same_clustering

N = 2048
SHARD_COUNTS = (2, 4, 8)
BACKENDS = ("host", "jit", "stream", "dist")


def check_layout(name: str):
    spec = spatial.PHASE2_LAYOUTS[name]
    pts = spec["make"](N)
    base = dict(eps=spec["eps"], min_pts=spec["min_pts"], grid=spec["grid"],
                max_clusters=spec["max_clusters"], max_verts=spec["max_verts"])
    # Tuned layouts must clear the DESIGN §7 sizing probe.
    DDCConfig(**base).validate(sample=pts)
    for k in SHARD_COUNTS:
        labels = {}
        for backend in BACKENDS:
            model = DDC(DDCConfig(**base, backend=backend, shards=k))
            labels[backend] = model.fit(pts).labels_
            assert len(labels[backend]) == N, (
                f"{name} k={k} {backend}: labels_ misaligned with input")
        for backend in ("jit", "stream", "dist"):
            assert same_clustering(labels["host"], labels[backend]), (
                f"{name} k={k}: {backend} diverged from host")
        n = len(set(labels["host"][labels["host"] >= 0].tolist()))
        print(f"PASS {name} k={k} clusters={n}")


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    names = list(spatial.PHASE2_LAYOUTS) if which == "all" else [which]
    for n in names:
        check_layout(n)
    print("ALL_OK")

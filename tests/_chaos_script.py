"""Seeded chaos sweep for the serve failure model (DESIGN.md §11), run
under an 8-device CPU override by tests/test_chaos.py.

For random seeded ``FaultPlan``s over the tuned layouts × {2, 4, 8}
shards × both serve engines (``stream`` host-driven, ``dist``
device-resident), streamed against a fault-free twin fed the identical
ingest schedule:

1. **No plan corrupts the aggregator** — after every refresh under
   faults, the cached pair-d2 matrix is NaN/inf-free (mangled deltas
   must die at the validation gate, never in the cache).
2. **Healthy shards keep serving** — mid-outage queries answer (with
   the staleness flag raised when a quarantined shard mattered).
3. **Recovery converges bit-for-bit** — after ``recover_all`` +
   refresh, global labels AND the cached pair-d2 matrix equal the
   uninterrupted twin exactly; a from-scratch full re-merge agrees.
4. **Track histories survive the outage** (DESIGN.md §14) — tracking
   folds only post-gate merged generations (the engine skips the fold
   while any shard is quarantined), so a quarantined-then-recovered run
   yields tracker state bit-identical to the fault-free twin.  The twin
   is paused in lockstep (``refresh(track=...)``) and replays the
   faulted run's post-recovery tracked generations, mirroring how a
   real deployment's tracker only ever observes complete generations.

Modes (argv[1]): ``quick`` (one layout, fixed seeds), ``all`` (every
layout, hypothesis-drawn seeds when available), or a layout name.
Prints PASS lines; any exception fails.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np

from repro.data import spatial
from repro.ddc import DDC, DDCConfig
from repro.serve import FaultPlan

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from _hyp import HAVE_HYPOTHESIS, given, settings, st  # noqa: E402

N = 1024
BATCH = 64
SHARD_COUNTS = (2, 4, 8)
BACKENDS = ("stream", "dist")


def build(layout: str, k: int, backend: str, faults=None, agg=None):
    spec = spatial.PHASE2_LAYOUTS[layout]
    cap = spatial.shard_capacity(N, k)
    cfg = DDCConfig(
        eps=spec["eps"], min_pts=spec["min_pts"], grid=spec["grid"],
        max_clusters=spec["max_clusters"], max_verts=spec["max_verts"],
        backend=backend, shards=k, capacity=cap,
        max_batch=min(BATCH, cap), agg_degree=agg, track=True).validate()
    return DDC(cfg, faults=faults)


def assert_trackers_equal(faulted, twin):
    fa, fm = faulted.service.tracker.state_dict()
    ta, tm = twin.service.tracker.state_dict()
    assert fm == tm, \
        f"post-recovery tracker manifest diverged\n{fm}\nvs\n{tm}"
    assert set(fa) == set(ta)
    for key in sorted(fa):
        np.testing.assert_array_equal(
            fa[key], ta[key],
            err_msg=f"post-recovery track history diverged ({key})")


def assert_cache_clean(svc):
    tree = svc.hierarchy
    if tree is not None:
        for i, arr in enumerate(tree.cache_arrays()):
            assert np.isfinite(arr).all(), \
                f"NaN/inf reached tree node cache {i}"
        return
    d2 = svc.pair_d2
    if d2 is not None:
        assert np.isfinite(np.asarray(d2)).all(), \
            "NaN/inf reached the cached pair-d2 matrix"


def chaos_one(layout: str, k: int, backend: str, seed: int, agg=None):
    plan = FaultPlan.random(seed=seed, shards=k, n_faults=3, horizon=2)
    spec = spatial.PHASE2_LAYOUTS[layout]
    pts = spec["make"](N)
    faulted = build(layout, k, backend, faults=plan, agg=agg)
    twin = build(layout, k, backend, agg=agg)
    probes = pts[:: max(1, N // 32)].copy()

    for shard, chunk in spatial.stream_batches(pts, k, BATCH):
        # Faulted first: whether its tracker folded this generation
        # (post-gate only: the engine skips the fold under quarantine)
        # decides whether the twin's does, keeping both track histories
        # aligned generation-for-generation through the outage.
        faulted.partial_fit(shard, chunk)
        gen_before = faulted.service.tracker.generation
        faulted.service.refresh()
        tracked = faulted.service.tracker.generation > gen_before
        twin.partial_fit(shard, chunk)
        twin.service.refresh(track=tracked)
        # (1) the fault seam may quarantine, retry, fence — but the
        # aggregator cache must never see a mangled value
        assert_cache_clean(faulted.service)
        # (2) healthy shards answer mid-outage; stale iff a quarantined
        # shard could have mattered
        if faulted.service.quarantined:
            labels, stale = faulted.service.query(probes, return_stale=True)
            assert labels.shape == (len(probes),)

    # (3) recovery converges: rejoin everyone (a recovered shard's next
    # delivery may hit a later scheduled fault, so iterate to drain the
    # plan — it is finite and one-shot per event)
    for _ in range(8):
        if not faulted.service.quarantined:
            break
        faulted.service.recover_all()
        faulted.service.refresh()
    assert not faulted.service.quarantined, faulted.service.quarantined
    assert_cache_clean(faulted.service)

    # (4) the faulted run's recovery refreshes folded tracked
    # generations of the fully-merged state; replay as many forced
    # (bit-identical, already-converged) generations on the twin, then
    # the whole serialised tracker state must match — same IDs, same
    # events, same histories.  Checked BEFORE the remerge below, which
    # legitimately folds one more generation on the faulted side.
    while (twin.service.tracker.generation
           < faulted.service.tracker.generation):
        twin.service.refresh(force=True, track=True)
    assert_trackers_equal(faulted, twin)

    np.testing.assert_array_equal(
        faulted.labels_, twin.labels_,
        err_msg="post-recovery labels diverged from fault-free twin")
    if agg is not None:
        # Hierarchical arm: the per-node caches ARE the cache — each must
        # equal a from-scratch rebuild of its node batch, and a full tree
        # rebuild must reproduce the same labels.
        assert faulted.service.hierarchy.cache_exact(), \
            "post-recovery node cache != scratch rebuild"
        faulted.service.remerge_full()
        np.testing.assert_array_equal(
            faulted.labels_, twin.labels_,
            err_msg="post-recovery full tree rebuild diverged")
    else:
        d2 = np.asarray(faulted.service.pair_d2)
        np.testing.assert_array_equal(
            d2, np.asarray(twin.service.pair_d2),
            err_msg="post-recovery pair-d2 diverged from fault-free twin")
        # the delta-maintained cache still equals a from-scratch rebuild
        faulted.service.remerge_full()
        np.testing.assert_array_equal(
            d2, np.asarray(faulted.service.pair_d2),
            err_msg="post-recovery delta cache != full rebuild")

    st_ = faulted.service.stats()
    print(f"PASS {layout} {backend}{' hier' if agg else ''} k={k} "
          f"seed={seed} quarantines={st_['quarantined_shards']} "
          f"retries={st_['retries']} fenced={st_['fenced_deltas']}")


def sweep(layouts, seeds):
    for layout in layouts:
        for k in SHARD_COUNTS:
            for backend in BACKENDS:
                for agg in (None, 2):     # flat + hierarchical aggregator
                    for seed in seeds:
                        chaos_one(layout, k, backend, seed, agg=agg)


def sweep_hypothesis(layouts):
    if not HAVE_HYPOTHESIS:
        sweep(layouts, seeds=(0, 1, 2))
        return

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           k=st.sampled_from(SHARD_COUNTS),
           backend=st.sampled_from(BACKENDS),
           agg=st.sampled_from((None, 2, 4)),
           layout=st.sampled_from(tuple(layouts)))
    def run(seed, k, backend, agg, layout):
        chaos_one(layout, k, backend, seed, agg=agg)

    run()


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "quick"
    if which == "quick":
        sweep(["linked_ovals"], seeds=(0,))
    elif which == "all":
        sweep(sorted(spatial.PHASE2_LAYOUTS), seeds=(0, 1))
        sweep_hypothesis(sorted(spatial.PHASE2_LAYOUTS))
    else:
        sweep([which], seeds=(0, 1))
    print("ALL_OK")

"""End-to-end behaviour tests: the full train / serve / curate loops."""
import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.launch import train as train_mod
from repro.models import transformer as T
from repro.parallel import api as par
from repro.serve import engine


def test_training_reduces_loss(tmp_path):
    losses = train_mod.main([
        "--arch", "mamba2-1.3b", "--tiny", "--steps", "40", "--batch", "8",
        "--seq", "64", "--lr", "3e-3", "--log-every", "40",
    ])
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1


def test_training_with_curation_runs(tmp_path):
    losses = train_mod.main([
        "--arch", "qwen3-8b", "--tiny", "--steps", "6", "--batch", "4",
        "--seq", "32", "--curate", "--log-every", "6",
    ])
    assert np.isfinite(losses).all()


def test_checkpoint_restart_bitexact(tmp_path):
    """Fault-tolerance invariant: a run interrupted at step 10 and resumed
    must land exactly where an uninterrupted run does (same data stream,
    same state)."""
    common = ["--arch", "qwen3-8b", "--tiny", "--batch", "4", "--seq", "32",
              "--log-every", "100", "--seed", "5"]
    a = train_mod.main(common + ["--steps", "20"])
    ck = str(tmp_path / "ck")
    train_mod.main(common + ["--steps", "10", "--ckpt-dir", ck, "--ckpt-every", "10"])
    b = train_mod.main(common + ["--steps", "20", "--ckpt-dir", ck, "--resume"])
    assert abs(a[-1] - b[-1]) < 1e-4, (a[-1], b[-1])


def test_generation_deterministic_greedy():
    cfg = configs.get_config("qwen3-8b").tiny()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    scfg = engine.ServeConfig(max_len=48)
    pctx = par.ParallelCtx()
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    out1 = engine.greedy_generate(cfg, params, prompt, 8, scfg, pctx)
    out2 = engine.greedy_generate(cfg, params, prompt, 8, scfg, pctx)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert out1.shape == (2, 8)
    assert int(out1.max()) < cfg.vocab


def test_generation_overfit_recall():
    """Train a tiny model to memorise a sequence, then greedy-decode it."""
    cfg = configs.get_config("qwen3-8b").tiny(n_layers=2, d_model=32, d_ff=64,
                                              vocab=64)
    from repro.train import optimizer as opt_mod
    from repro.train import step as step_mod
    tcfg = step_mod.TrainConfig(opt=opt_mod.OptConfig(
        lr=2e-2, warmup=5, decay_steps=300, weight_decay=0.0))
    state = step_mod.make_train_state(cfg, tcfg)
    step_fn = jax.jit(step_mod.build_train_step(cfg, tcfg, par.ParallelCtx()),
                      donate_argnums=(0,))
    seq = jnp.asarray([[2, 7, 1, 8, 2, 8, 1, 8, 2, 7, 1, 8, 2, 8, 1, 8]] * 4,
                      jnp.int32)
    for _ in range(150):
        state, metrics = step_fn(state, {"tokens": seq})
    assert float(metrics["loss"]) < 0.3, float(metrics["loss"])
    scfg = engine.ServeConfig(max_len=16)
    out = engine.greedy_generate(cfg, state.params, seq[:1, :8], 4, scfg,
                                 par.ParallelCtx())
    np.testing.assert_array_equal(np.asarray(out)[0], np.asarray(seq)[0, 8:12])

"""Heterogeneous-cluster simulator vs the paper's qualitative claims."""
import dataclasses

import numpy as np
import pytest

from repro.core import partitioner, simulate as sim


MACH = sim.PAPER_MACHINES


class TestPaperScenarios:
    def test_sync_totals_equal_across_machines(self):
        """Tables 3-5: in the sync model every machine reports ~the same
        total (they all block until the global merge)."""
        r = sim.simulate(MACH, partitioner.scenario_sizes("I"), "sync")
        assert max(r.total) - min(r.total) < 1e-9

    def test_async_fast_machines_finish_early(self):
        """Table 3 async: M5 (1666 pts) finished in 618ms while M1 took
        ~22s — an order of magnitude spread."""
        r = sim.simulate(MACH, partitioner.scenario_sizes("I"), "async")
        assert min(r.total) < 0.05 * max(r.total)

    @pytest.mark.parametrize("scen", ["I", "II", "III"])
    def test_async_beats_sync_under_skew(self, scen):
        sizes = partitioner.scenario_sizes(scen)
        s = sim.simulate(MACH, sizes, "sync").makespan
        a = sim.simulate(MACH, sizes, "async").makespan
        assert a <= s

    def test_balanced_loads_close_gap_sync_wins_slightly(self):
        """Table 6: capacity-aware loads ⇒ sync ≈ async with a small edge
        to sync (async pays readiness-bookkeeping)."""
        sizes = partitioner.scenario_sizes("IV")
        s = sim.simulate(MACH, sizes, "sync").makespan
        a = sim.simulate(MACH, sizes, "async").makespan
        assert 0.9 < a / s < 1.15
        assert a >= s * 0.99  # sync not worse by much / async not better by much

    def test_sync_idle_dominates_under_skew(self):
        sizes = partitioner.scenario_sizes("II")
        s = sim.simulate(MACH, sizes, "sync")
        a = sim.simulate(MACH, sizes, "async")
        assert sum(s.idle) > 5 * sum(a.idle)


class TestSpeedup:
    def test_super_linear_speedup(self):
        """§5.5: O(n^2) local algorithm ⇒ speedup beyond machine count.
        Cleanest statement on a homogeneous 8-machine cluster; the paper
        measures 9x on its heterogeneous 8 (reproduced in
        benchmarks/speedup.py with their T1 convention)."""
        n = 10_000
        homog = [dataclasses.replace(MACH[0], name=f"m{i}") for i in range(8)]
        t1 = sim.sequential_time(MACH[0], n)
        tp = sim.simulate(homog, [n // 8] * 8, "async").makespan
        assert t1 / tp > len(homog), t1 / tp

    def test_capacity_aware_equalizes_phase1(self):
        n = 8_000
        speeds = [1.0 / m.step1_coeff for m in MACH]
        sizes = partitioner.capacity_aware_sizes(n, speeds, 2.0)
        t1s = [sim.phase1_time(m, s) for m, s in zip(MACH, sizes)]
        assert max(t1s) / min(t1s) < 1.6  # near-equal finish times


class TestScalability:
    def test_optimal_machine_count_exists(self):
        """Figs 4-5: total time dips then rises; optimum grows with data."""
        homo = [dataclasses.replace(MACH[0], name=f"m{i}") for i in range(64)]

        def makespan(n_machines, n_points):
            ms = homo[:n_machines]
            sizes = [n_points // n_machines] * n_machines
            return sim.simulate(ms, sizes, "async").makespan

        counts = [1, 2, 4, 8, 16, 32, 64]
        t_small = [makespan(c, 10_000) for c in counts]
        t_big = [makespan(c, 30_000) for c in counts]
        # decreasing then increasing (an interior optimum)
        opt_small = counts[int(np.argmin(t_small))]
        opt_big = counts[int(np.argmin(t_big))]
        assert 1 < opt_small < 64
        assert opt_big >= opt_small  # larger dataset ⇒ optimum at more machines

    def test_phase2_grows_with_machines(self):
        homo = [dataclasses.replace(MACH[0], name=f"m{i}") for i in range(64)]
        def phase2(c):
            r = sim.simulate(homo[:c], [10_000 // c] * c, "sync")
            return r.makespan - max(r.step1)
        assert phase2(32) > phase2(4)


class TestPartitioner:
    def test_sizes_sum(self):
        sizes = partitioner.capacity_aware_sizes(1000, [1, 2, 3, 4])
        assert sizes.sum() == 1000

    def test_spatial_split_compact(self):
        from repro.data import spatial
        pts = spatial.make_d1(2000, seed=0)
        parts = partitioner.split_spatial(pts, 4)
        # spatially compact shards: per-shard bbox area << full area
        areas = []
        for idx in parts:
            p = pts[idx]
            areas.append(float(np.ptp(p[:, 0])) * float(np.ptp(p[:, 1])))
        assert np.mean(areas) < 0.5
